//! Figure 5 regeneration: the N_init ablation (4 / 6 / 8) for SPEED-RLOO
//! on sim-1.5b over synth-dapo17k — validation accuracy on dapo1k (left),
//! average gradient norm (middle), average training pass rate (right).
//!
//!     cargo bench --bench bench_fig5_ninit
//!
//! Paper shape (§5.2): larger N_init => smaller gradient norms, training
//! accuracy drifting away from 0.5, slower accuracy rise.

use speed_rl::bench::Table;
use speed_rl::config::RunConfig;
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::driver;
use speed_rl::metrics::RunRecord;

fn main() {
    let n_total = 24;
    let mut recs: Vec<(usize, RunRecord)> = Vec::new();
    for n_init in [4usize, 6, 8] {
        let mut cfg = RunConfig::default();
        cfg.model = "sim-1.5b".into();
        cfg.curriculum = CurriculumKind::Speed;
        cfg.n_init = n_init;
        cfg.n_cont = n_total - n_init;
        cfg.max_steps = 150;
        cfg.eval_every = 10;
        cfg.dataset_size = 16_000;
        cfg.label = format!("N_init={n_init}");
        eprintln!("[fig5] {}", cfg.label);
        recs.push((n_init, driver::run_sim(&cfg).expect("run")));
    }

    println!("Figure 5 (left): dapo1k validation accuracy vs time\n");
    for (_, rec) in &recs {
        let pts: Vec<String> = rec
            .curve("dapo1k")
            .iter()
            .step_by(2)
            .map(|(t, a)| format!("({:.1}h,{a:.3})", t / 3600.0))
            .collect();
        println!("  {:<10} {}", rec.label, pts.join(" "));
    }

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    println!("\nFigure 5 (middle/right): averages over training\n");
    let mut t = Table::new(&[
        "N_init", "avg grad norm", "avg train acc", "|acc-0.5|", "accept rate", "dapo1k@0.30",
    ]);
    for (n_init, rec) in &recs {
        let g = mean(&rec.steps.iter().map(|s| s.grad_norm).collect::<Vec<_>>());
        let a = mean(&rec.steps.iter().map(|s| s.train_pass_rate).collect::<Vec<_>>());
        t.row(vec![
            n_init.to_string(),
            format!("{g:.3}"),
            format!("{a:.3}"),
            format!("{:.3}", (a - 0.5).abs()),
            format!("{:.2}", rec.counters.acceptance_rate()),
            rec.time_to_target("dapo1k", 0.30)
                .map(|x| format!("{:.2}h", x / 3600.0))
                .unwrap_or("t".into()),
        ]);
    }
    t.print();
}
