//! Table 1 regeneration: wall-clock training hours to reach each
//! benchmark's target accuracy, for every (model, dataset, algorithm) row
//! of the paper, on the simulated substrate — plus the Figure 1 (right)
//! summary bars (average accuracy at a fixed time budget).
//!
//!     cargo bench --bench bench_table1
//!
//! Paper shape to reproduce: SPEED variants reach targets 2-6x faster;
//! average speedup ~3x; DAPO baselines occasionally miss targets entirely
//! ("t" marks, like the paper's dagger).

use speed_rl::bench::Table;
use speed_rl::config::RunConfig;
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::data::dataset::DatasetKind;
use speed_rl::driver;
use speed_rl::metrics::RunRecord;
use speed_rl::rl::algo::BaseAlgo;

struct Row {
    model: &'static str,
    dataset: DatasetKind,
    algo_pairs: Vec<(&'static str, CurriculumKind, BaseAlgo)>,
}

fn run(
    model: &str,
    dataset: DatasetKind,
    curriculum: CurriculumKind,
    algo: BaseAlgo,
    label: &str,
) -> RunRecord {
    let mut cfg = RunConfig::default();
    cfg.model = model.to_string();
    cfg.dataset = dataset;
    cfg.dataset_size = 16_000;
    cfg.curriculum = curriculum;
    cfg.algo = algo;
    cfg.label = label.to_string();
    cfg.max_steps = 250;
    cfg.eval_every = 5;
    driver::run_sim(&cfg).expect("sim run")
}

fn main() {
    let rloo_pair = |_: ()| {
        vec![
            ("RLOO", CurriculumKind::Uniform, BaseAlgo::Rloo),
            ("SPEED-RLOO", CurriculumKind::Speed, BaseAlgo::Rloo),
        ]
    };
    let all_pairs = |_: ()| {
        vec![
            ("RLOO", CurriculumKind::Uniform, BaseAlgo::Rloo),
            ("SPEED-RLOO", CurriculumKind::Speed, BaseAlgo::Rloo),
            ("DAPO", CurriculumKind::DapoFilter, BaseAlgo::Dapo),
            ("SPEED-DAPO", CurriculumKind::Speed, BaseAlgo::Dapo),
        ]
    };
    let rows = vec![
        Row { model: "sim-1.5b", dataset: DatasetKind::SynthNumina, algo_pairs: all_pairs(()) },
        Row { model: "sim-1.5b", dataset: DatasetKind::SynthDapo17k, algo_pairs: rloo_pair(()) },
        Row { model: "sim-7b", dataset: DatasetKind::SynthDapo17k, algo_pairs: all_pairs(()) },
        Row { model: "sim-7b", dataset: DatasetKind::SynthDeepScale, algo_pairs: all_pairs(()) },
    ];

    let benches = ["dapo1k", "math500", "amc2023", "aime"];
    let mut table = Table::new(&[
        "model", "data", "algorithm", "dapo1k", "math500", "amc2023", "aime", "avg speedup",
    ]);
    let mut all_speedups: Vec<f64> = Vec::new();
    let mut fig1: Vec<(String, f64)> = Vec::new(); // label -> avg accuracy @ budget

    for row in &rows {
        let targets = driver::paper_targets(row.model);
        let mut records: Vec<(&str, RunRecord)> = Vec::new();
        for (label, curriculum, algo) in &row.algo_pairs {
            eprintln!("[table1] {} {} {label}", row.model, row.dataset.name());
            records.push((label, run(row.model, row.dataset, *curriculum, *algo, label)));
        }
        // fixed-budget average accuracy for Fig 1 (right)
        let budget = records
            .iter()
            .map(|(_, r)| r.total_time())
            .fold(f64::INFINITY, f64::min);
        for (label, rec) in &records {
            let accs: Vec<f64> = benches
                .iter()
                .map(|b| {
                    rec.curve(b)
                        .iter()
                        .take_while(|(t, _)| *t <= budget)
                        .last()
                        .map(|(_, a)| *a)
                        .unwrap_or(0.0)
                })
                .collect();
            fig1.push((
                format!("{}/{}/{}", row.model, row.dataset.name(), label),
                accs.iter().sum::<f64>() / accs.len() as f64,
            ));
        }

        for pair in records.chunks(2) {
            let (base_label, base) = &pair[0];
            let (speed_label, speed) = &pair[1];
            let fmt_cell =
                |rec: &RunRecord, bench: &str, target: f64| match rec.time_to_target(bench, target)
                {
                    Some(t) => format!("{:.1}", t / 3600.0),
                    None => "t".to_string(),
                };
            let mut speedups = Vec::new();
            let mut base_cells = vec![
                row.model.to_string(),
                row.dataset.name().to_string(),
                base_label.to_string(),
            ];
            let mut speed_cells = vec![String::new(), String::new(), speed_label.to_string()];
            for (bench, target) in benches.iter().zip(targets.iter().map(|(_, t)| *t)) {
                base_cells.push(fmt_cell(base, bench, target));
                let cell = match (
                    base.time_to_target(bench, target),
                    speed.time_to_target(bench, target),
                ) {
                    (Some(b), Some(s)) => {
                        let f = b / s;
                        speedups.push(f);
                        format!("{} ({:.1}x)", fmt_cell(speed, bench, target), f)
                    }
                    (None, Some(_)) => format!("{} (t)", fmt_cell(speed, bench, target)),
                    _ => fmt_cell(speed, bench, target),
                };
                speed_cells.push(cell);
            }
            base_cells.push(String::new());
            let avg = if speedups.is_empty() {
                "-".to_string()
            } else {
                let a = speedups.iter().sum::<f64>() / speedups.len() as f64;
                all_speedups.extend(&speedups);
                format!("{a:.1}x")
            };
            speed_cells.push(avg);
            table.row(base_cells);
            table.row(speed_cells);
        }
    }

    println!("\nTable 1 (simulated substrate; hours to target accuracy; 't' = not reached):");
    println!("targets: 1.5b {:?}", driver::paper_targets("sim-1.5b"));
    println!("targets: 7b   {:?}\n", driver::paper_targets("sim-7b"));
    table.print();
    if !all_speedups.is_empty() {
        let avg = all_speedups.iter().sum::<f64>() / all_speedups.len() as f64;
        let min = all_speedups.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = all_speedups.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "\noverall: {} speedup measurements, avg {avg:.1}x, range {min:.1}x-{max:.1}x \
             (paper: avg 3.3x, range 1.1x-6.1x)",
            all_speedups.len()
        );
    }

    println!("\nFigure 1 (right) — average accuracy across benchmarks at a fixed time budget:");
    let mut f1 = Table::new(&["configuration", "avg accuracy"]);
    for (label, acc) in &fig1 {
        f1.row(vec![label.clone(), format!("{acc:.3}")]);
    }
    f1.print();
}
