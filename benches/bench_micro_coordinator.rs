//! L3 micro-benchmarks: the coordinator's own hot paths (everything that
//! runs between PJRT calls). Used by the §Perf pass — the coordinator must
//! stay <5% of a real step's budget.
//!
//!     cargo bench --bench bench_micro_coordinator

use std::collections::VecDeque;

use speed_rl::bench::BenchRunner;
use speed_rl::coordinator::batcher::{plan_call, PendingContinuation};
use speed_rl::coordinator::screening::ScreeningRule;
use speed_rl::data::dataset::{Dataset, DatasetKind};
use speed_rl::data::tasks::{generate, ALL_FAMILIES};
use speed_rl::data::tokenizer::{Tokenizer, EOS};
use speed_rl::data::verifier::verify;
use speed_rl::policy::sampler::pack_requests;
use speed_rl::policy::GenRequest;
use speed_rl::rl::advantage::{grpo, rloo};
use speed_rl::rl::theory::{phi, snr_bound_exact};
use speed_rl::rl::update::{PromptGroup, Rollout, TrainBatch};
use speed_rl::rl::AdvantageEstimator;
use speed_rl::util::rng::Rng;

fn mk_groups(rng: &mut Rng, n_groups: usize, n_rollouts: usize, glen: usize) -> Vec<PromptGroup> {
    (0..n_groups)
        .map(|i| {
            let task = generate(rng, ALL_FAMILIES[i % 7], 4, 20);
            PromptGroup {
                prompt_idx: i,
                task,
                rollouts: (0..n_rollouts)
                    .map(|_| {
                        let mut toks: Vec<i32> =
                            (0..glen).map(|_| rng.range_i64(3, 12) as i32).collect();
                        toks[glen / 2] = EOS;
                        Rollout {
                            gen_tokens: toks,
                            gen_logprobs: vec![-0.7; glen],
                            reward: if rng.bool(0.5) { 1.0 } else { 0.0 },
                        }
                    })
                    .collect(),
            }
        })
        .collect()
}

fn main() {
    let r = BenchRunner::new(3, 25);
    let mut rng = Rng::new(0);
    let tok = Tokenizer::new();

    // --- task generation + tokenization + verification ---
    r.run("task-generate x1000", || {
        let mut g = Rng::new(1);
        for i in 0..1000 {
            std::hint::black_box(generate(&mut g, ALL_FAMILIES[i % 7], (i % 10 + 1) as u8, 20));
        }
    });
    let tasks: Vec<_> = (0..1000).map(|i| generate(&mut rng, ALL_FAMILIES[i % 7], 5, 20)).collect();
    r.run("tokenize x1000 prompts", || {
        for t in &tasks {
            std::hint::black_box(tok.encode(&t.prompt).unwrap());
        }
    });
    let gen: Vec<i32> = {
        let mut ids = tok.encode("1234").unwrap();
        ids.push(EOS);
        ids
    };
    r.run("verify x1000 rollouts", || {
        for t in &tasks {
            std::hint::black_box(verify(&tok, t, &gen));
        }
    });

    // --- dataset generation (startup cost) ---
    r.run("dataset synth-dapo17k 16k", || {
        std::hint::black_box(Dataset::training(DatasetKind::SynthDapo17k, 16_000, 1, 20));
    });

    // --- advantage estimators ---
    let rewards: Vec<f32> = (0..24).map(|i| (i % 2) as f32).collect();
    r.run("rloo x10000 groups of 24", || {
        for _ in 0..10_000 {
            std::hint::black_box(rloo(&rewards));
        }
    });
    r.run("grpo x10000 groups of 24", || {
        for _ in 0..10_000 {
            std::hint::black_box(grpo(&rewards));
        }
    });

    // --- theory kernels ---
    r.run("snr_bound_exact x100k", || {
        for i in 0..100_000 {
            std::hint::black_box(snr_bound_exact(24, (i % 99 + 1) as f64 / 100.0));
        }
    });
    r.run("phi x100k", || {
        for i in 0..100_000 {
            std::hint::black_box(phi((i % 99 + 1) as f64 / 100.0, 8, 16));
        }
    });

    // --- pre-fetch batcher ---
    let mut grng = Rng::new(3);
    r.run("plan_call 384-row capacity x1000", || {
        let rule = ScreeningRule::new(4, 20);
        for _ in 0..1000 {
            let mut pending: VecDeque<PendingContinuation> = (0..8)
                .map(|i| PendingContinuation {
                    prompt_idx: i,
                    task: tasks[i].clone(),
                    screening: vec![],
                    born_step: 0,
                    n_cont: rule.n_cont,
                    forecast_var: 0.25,
                })
                .collect();
            let mut k = 0usize;
            let plan = plan_call(
                &mut pending,
                || {
                    k += 1;
                    (k, tasks[k % tasks.len()].clone())
                },
                &rule,
                384,
                usize::MAX,
            );
            std::hint::black_box(plan);
        }
    });

    // --- train batch assembly (the pre-PJRT hot path) ---
    let groups = mk_groups(&mut grng, 16, 24, 24);
    r.run("TrainBatch::assemble 384x48", || {
        std::hint::black_box(
            TrainBatch::assemble(&groups, &tok, AdvantageEstimator::Rloo, 0.0, 384, 48).unwrap(),
        );
    });

    // --- prompt packing for rollout calls ---
    let requests: Vec<GenRequest> = tasks[..16]
        .iter()
        .enumerate()
        .map(|(i, t)| GenRequest { prompt_idx: i, task: t.clone(), n_samples: 24 })
        .collect();
    r.run("pack_requests 384 rows", || {
        std::hint::black_box(pack_requests(&tok, &requests, 384, 24).unwrap());
    });

    // --- SimPolicy end-to-end step throughput (drives all figure benches) ---
    {
        use speed_rl::config::RunConfig;
        use speed_rl::coordinator::curriculum::CurriculumKind;
        let mut cfg = RunConfig::default();
        cfg.max_steps = 20;
        cfg.eval_every = 0;
        cfg.dataset_size = 8000;
        cfg.curriculum = CurriculumKind::Speed;
        r.run("sim SPEED 20 train steps", || {
            std::hint::black_box(speed_rl::driver::run_sim(&cfg).unwrap());
        });
    }

    // --- serial vs pipelined coordinator (real wall-clock, SimPolicy) ---
    //
    // The pipelined trainer overlaps rollout collection (K workers) with
    // the learner's updates; on the simulator the collection CPU work
    // dominates, so steps/sec should scale with workers until the learner
    // or the shared loader becomes the bottleneck. Reported per worker
    // count: steps/sec, speedup over serial, and rollout-engine utilization
    // (engine-busy seconds / (wall seconds * workers)).
    {
        use speed_rl::coordinator::curriculum::{self, CurriculumKind, CurriculumSpec};
        use speed_rl::coordinator::pipeline::{PipelineConfig, PipelinedTrainer};
        use speed_rl::coordinator::trainer::{Trainer, TrainerConfig};
        use speed_rl::metrics::RunRecord;
        use speed_rl::policy::sim::{SimCostModel, SimModelSpec, SimPolicy};
        use speed_rl::rl::algo::{AlgoConfig, BaseAlgo};

        let steps = 60usize;
        let batch = 32usize;
        let rule = ScreeningRule::new(8, 16);
        let dataset = Dataset::training(DatasetKind::SynthDapo17k, 16_000, 1, 20);
        let mk_policy = || {
            SimPolicy::new(SimModelSpec::qwen_7b(), SimCostModel::default(), 7).with_shapes(
                batch * rule.n_total(),
                batch * rule.n_total(),
                512,
            )
        };
        let tcfg = |label: &str| TrainerConfig {
            batch_size: batch,
            eval_every: 0,
            max_steps: steps,
            label: label.to_string(),
            seed: 7,
            ..Default::default()
        };
        let spec = CurriculumSpec::fixed(CurriculumKind::Speed, rule);

        let run_serial = || -> (f64, RunRecord) {
            let mut policy = mk_policy();
            let mut cur = curriculum::make(CurriculumKind::Speed, rule, 4);
            let trainer = Trainer::new(tcfg("serial"), AlgoConfig::new(BaseAlgo::Rloo));
            let t0 = std::time::Instant::now();
            let rec = trainer.run(&mut policy, cur.as_mut(), &dataset, &[]).unwrap();
            (t0.elapsed().as_secs_f64(), rec)
        };
        // One closure for all pipelined modes so the serial-vs-pipelined-
        // vs-service-vs-pool comparison can never drift onto different
        // configs. `engines` > 1 shards the service across E data-parallel
        // replicas (ignored with `service` off).
        let run_pipelined = |workers: usize, service: bool, engines: usize| -> (f64, RunRecord) {
            let mut policy = mk_policy();
            let trainer = PipelinedTrainer::new(
                tcfg(if service { "pipelined+service" } else { "pipelined" }),
                AlgoConfig::new(BaseAlgo::Rloo),
                PipelineConfig {
                    workers,
                    enabled: true,
                    buffer_cap: 4 * batch,
                    service,
                    ..Default::default()
                },
            )
            .with_engines(engines);
            let t0 = std::time::Instant::now();
            let rec = trainer.run(&mut policy, spec.clone(), &dataset, &[]).unwrap();
            (t0.elapsed().as_secs_f64(), rec)
        };

        let _ = run_serial(); // warmup
        let serial_best = (0..3).map(|_| run_serial().0).fold(f64::INFINITY, f64::min);
        println!(
            "coordinator serial        : {:7.1} steps/s",
            steps as f64 / serial_best
        );
        for workers in [1usize, 2, 4, 8] {
            let _ = run_pipelined(workers, false, 1); // warmup
            let mut best = f64::INFINITY;
            let mut util_of_best = 0.0;
            for _ in 0..3 {
                let (secs, rec) = run_pipelined(workers, false, 1);
                std::hint::black_box(&rec);
                if secs < best {
                    best = secs;
                    util_of_best = rec.counters.busy_s / (secs * workers as f64);
                }
            }
            println!(
                "coordinator pipelined K={workers}: {:7.1} steps/s ({:.2}x serial, engine util {:.0}%)",
                steps as f64 / best,
                serial_best / best,
                100.0 * util_of_best
            );
        }
        // The coalescing service: one engine, K request producers.
        for workers in [2usize, 4, 8] {
            let (secs, rec) = run_pipelined(workers, true, 1);
            let svc = rec.service.expect("service counters on the serviced path");
            println!(
                "coordinator service   K={workers}: {:7.1} steps/s ({} calls from {} submissions, \
                 fill {:.0}%, {:.1} coalesced/call)",
                steps as f64 / secs,
                svc.calls,
                svc.submissions,
                100.0 * svc.mean_fill(),
                svc.mean_coalesced()
            );
        }
        // The engine pool: K producers x E replicas behind the same service.
        // (`speed-rl bench --mode pool` is the figure-quality version of this
        // grid; these rows exist so a perf pass sees the pooled hot path.)
        for workers in [4usize, 8] {
            for engines in [1usize, 2, 4] {
                let (secs, rec) = run_pipelined(workers, true, engines);
                let svc = rec.service.expect("service counters on the pooled path");
                println!(
                    "coordinator pool K={workers} E={engines}: {:7.1} steps/s ({} calls, fill {:.0}%, \
                     balance {:.2}, {} steals)",
                    steps as f64 / secs,
                    svc.calls,
                    100.0 * svc.mean_fill(),
                    svc.pool_balance(),
                    svc.steals
                );
            }
        }
    }
}
