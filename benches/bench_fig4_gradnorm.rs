//! Figure 4 regeneration: average training accuracy (left) and gradient
//! norm (right) during training — RLOO vs SPEED-RLOO, sim-7b on
//! synth-dapo17k.
//!
//!     cargo bench --bench bench_fig4_gradnorm
//!
//! Paper shape: SPEED keeps training pass rates much closer to 0.5
//! (especially early) and produces substantially larger gradient norms.

use speed_rl::bench::Table;
use speed_rl::config::RunConfig;
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::driver;
use speed_rl::util::stats::ema_curve;

fn main() {
    let mut recs = Vec::new();
    for (label, kind) in [("RLOO", CurriculumKind::Uniform), ("SPEED-RLOO", CurriculumKind::Speed)] {
        let mut cfg = RunConfig::default();
        cfg.curriculum = kind;
        cfg.label = label.to_string();
        cfg.max_steps = 150;
        cfg.eval_every = 0;
        cfg.dataset_size = 16_000;
        eprintln!("[fig4] {label}");
        recs.push(driver::run_sim(&cfg).expect("run"));
    }

    println!("Figure 4 (left): average training pass rate (EMA, every 10 steps)\n");
    let mut t = Table::new(&["step", "RLOO", "SPEED-RLOO", "|RLOO-0.5|", "|SPEED-0.5|"]);
    let curves: Vec<Vec<f64>> = recs
        .iter()
        .map(|r| ema_curve(&r.steps.iter().map(|s| s.train_pass_rate).collect::<Vec<_>>(), 0.2))
        .collect();
    for i in (0..curves[0].len()).step_by(10) {
        t.row(vec![
            i.to_string(),
            format!("{:.3}", curves[0][i]),
            format!("{:.3}", curves[1][i]),
            format!("{:.3}", (curves[0][i] - 0.5).abs()),
            format!("{:.3}", (curves[1][i] - 0.5).abs()),
        ]);
    }
    t.print();

    println!("\nFigure 4 (right): gradient norm (EMA, every 10 steps)\n");
    let mut t = Table::new(&["step", "RLOO", "SPEED-RLOO", "ratio"]);
    let gcurves: Vec<Vec<f64>> = recs
        .iter()
        .map(|r| ema_curve(&r.steps.iter().map(|s| s.grad_norm).collect::<Vec<_>>(), 0.2))
        .collect();
    for i in (0..gcurves[0].len()).step_by(10) {
        t.row(vec![
            i.to_string(),
            format!("{:.3}", gcurves[0][i]),
            format!("{:.3}", gcurves[1][i]),
            format!("{:.2}x", gcurves[1][i] / gcurves[0][i].max(1e-9)),
        ]);
    }
    t.print();

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let d_rloo = mean(&recs[0].steps.iter().map(|s| (s.train_pass_rate - 0.5).abs()).collect::<Vec<_>>());
    let d_speed = mean(&recs[1].steps.iter().map(|s| (s.train_pass_rate - 0.5).abs()).collect::<Vec<_>>());
    let g_rloo = mean(&recs[0].steps.iter().map(|s| s.grad_norm).collect::<Vec<_>>());
    let g_speed = mean(&recs[1].steps.iter().map(|s| s.grad_norm).collect::<Vec<_>>());
    println!(
        "\nsummary: mean |train acc - 0.5|: RLOO {d_rloo:.3} vs SPEED {d_speed:.3}; \
         mean grad norm: RLOO {g_rloo:.3} vs SPEED {g_speed:.3} ({:.1}x)",
        g_speed / g_rloo
    );
}
