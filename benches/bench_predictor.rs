//! Rollouts-to-target-accuracy: `speed` vs `predictive-speed` on the sim
//! substrate — the headline number for the difficulty-predictor subsystem.
//!
//! Each run early-stops at the Table-1-style dapo1k bar; the honest cost
//! axis is total rollouts spent to get there (screening + continuation).
//! `predictive-speed` should arrive with measurably fewer because the
//! predictor refuses to spend `N_init` screening rollouts on prompts whose
//! rejection is forecast with >= `skip_confidence` probability. The
//! `never-skip` row is the sanity rail: `--skip-confidence 1.0` must
//! reproduce the plain speed numbers exactly.
//!
//!     cargo bench --bench bench_predictor

use speed_rl::bench::Table;
use speed_rl::config::RunConfig;
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::coordinator::trainer::Trainer;
use speed_rl::data::dataset::Dataset;
use speed_rl::driver;
use speed_rl::eval::benchmark_suite;
use speed_rl::metrics::RunRecord;

const TARGET_BENCH: &str = "dapo1k";
const TARGET_ACC: f64 = 0.5;

fn scenario(kind: CurriculumKind, skip_confidence: f64, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.curriculum = kind;
    cfg.label = format!("{}-s{}", kind.name(), seed);
    cfg.model = "sim-7b".into();
    cfg.dataset_size = 800; // several epochs inside the budget: the
                            // predictor sees identities again
    cfg.n_init = 8;
    cfg.n_cont = 16;
    cfg.batch_size = 16;
    cfg.eval_every = 5;
    cfg.max_steps = 150;
    cfg.seed = seed;
    cfg.skip_confidence = skip_confidence;
    cfg
}

fn run_to_target(cfg: &RunConfig) -> RunRecord {
    let dataset =
        Dataset::training(cfg.dataset, cfg.dataset_size, cfg.seed, driver::MAX_PROMPT_CHARS);
    let mut policy = driver::build_sim_policy(cfg).expect("sim policy");
    let evals = benchmark_suite(driver::BENCH_SEED, driver::MAX_PROMPT_CHARS);
    let mut tcfg = driver::trainer_config(cfg);
    tcfg.stop_at_target = Some((TARGET_BENCH.to_string(), TARGET_ACC));
    let mut curriculum = driver::build_curriculum(cfg);
    let trainer = Trainer::new(tcfg, driver::build_algo(cfg));
    trainer.run(&mut policy, curriculum.as_mut(), &dataset, &evals).expect("run")
}

fn main() {
    println!(
        "rollouts to {TARGET_ACC} on {TARGET_BENCH} (sim-7b, dapo17k-synth, N_init 8 / N_cont 16)\n"
    );
    let mut table = Table::new(&[
        "curriculum",
        "seed",
        "steps",
        "time-to-target (s)",
        "rollouts",
        "skipped",
        "saved rollouts",
        "brier",
        "precision",
        "recall",
    ]);

    let mut speed_rollouts = Vec::new();
    let mut pred_rollouts = Vec::new();
    for seed in [7u64, 19] {
        let variants = [
            ("speed", scenario(CurriculumKind::Speed, 0.9, seed)),
            ("predictive-speed", scenario(CurriculumKind::PredictiveSpeed, 0.9, seed)),
            ("  (never-skip)", scenario(CurriculumKind::PredictiveSpeed, 1.0, seed)),
        ];
        for (name, cfg) in variants {
            let rec = run_to_target(&cfg);
            let reached = rec.time_to_target(TARGET_BENCH, TARGET_ACC);
            match name {
                "speed" => speed_rollouts.push(rec.counters.rollouts),
                "predictive-speed" => pred_rollouts.push(rec.counters.rollouts),
                _ => {}
            }
            table.row(vec![
                name.to_string(),
                seed.to_string(),
                rec.steps.len().to_string(),
                reached.map(|t| format!("{t:.0}")).unwrap_or_else(|| "not reached".into()),
                rec.counters.rollouts.to_string(),
                rec.counters.prompts_skipped.to_string(),
                rec.counters.rollouts_saved.to_string(),
                format!("{:.3}", rec.counters.predictor_brier()),
                format!("{:.2}", rec.counters.predictor_precision()),
                format!("{:.2}", rec.counters.predictor_recall()),
            ]);
        }
    }
    table.print();

    let mean = |xs: &[u64]| xs.iter().sum::<u64>() as f64 / xs.len().max(1) as f64;
    let s = mean(&speed_rollouts);
    let p = mean(&pred_rollouts);
    if s > 0.0 {
        println!(
            "\nmean rollouts to target: speed {s:.0}  predictive-speed {p:.0}  ({:+.1}% vs speed)",
            100.0 * (p - s) / s
        );
    }
}
