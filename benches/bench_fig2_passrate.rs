//! Figure 2 regeneration:
//!   (left / middle) pass-rate histograms of 1000 synth-dapo17k prompts at
//!   50 samples per prompt, for the sim-1.5b and sim-7b base models;
//!   (right) average per-step inference vs training time for RLOO.
//!
//!     cargo bench --bench bench_fig2_passrate
//!
//! Paper shape: a dominant spike at pass rate exactly 0 (34% / 25.8%), a
//! smaller spike near 1, mass spread over the middle; inference time ~2x
//! training time per step.

use speed_rl::bench::Table;
use speed_rl::config::RunConfig;
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::data::dataset::{Dataset, DatasetKind};
use speed_rl::driver;
use speed_rl::policy::sim::{SimCostModel, SimModelSpec, SimPolicy};
use speed_rl::util::rng::Rng;

fn histogram(spec: SimModelSpec) -> ([usize; 11], f64) {
    let data = Dataset::training(DatasetKind::SynthDapo17k, 1000, 0, 20);
    let policy = SimPolicy::new(spec, SimCostModel::default(), 7);
    let mut rng = Rng::new(99);
    let mut bins = [0usize; 11]; // bin i: pass rate in [i/10-0.05, i/10+0.05); bin 0 = exactly 0 handled below
    let mut zero = 0usize;
    for t in &data.instances {
        let p = policy.pass_prob(t);
        // 50-sample empirical pass rate, like the paper's protocol
        let hits = (0..50).filter(|_| rng.bool(p)).count();
        if hits == 0 {
            zero += 1;
        }
        let rate = hits as f64 / 50.0;
        let bin = ((rate * 10.0).round() as usize).min(10);
        bins[bin] += 1;
    }
    (bins, zero as f64 / data.len() as f64)
}

fn main() {
    println!("Figure 2 (left/middle): pass-rate histograms, 1000 prompts x 50 samples\n");
    for (spec, paper_zero) in
        [(SimModelSpec::qwen_15b(), 0.34), (SimModelSpec::qwen_7b(), 0.258)]
    {
        let (bins, zero) = histogram(spec);
        println!("{} (paper zero-pass mass: {paper_zero}):", spec.name);
        let max = *bins.iter().max().unwrap();
        for (i, n) in bins.iter().enumerate() {
            let bar = "#".repeat((n * 50 / max.max(1)).max(usize::from(*n > 0)));
            println!("  {:>4.1} | {:<50} {}", i as f64 / 10.0, bar, n);
        }
        println!("  zero-pass mass (exactly 0/50): {:.1}%\n", zero * 100.0);
    }

    println!("Figure 2 (right): average per-step inference vs training time (RLOO)\n");
    let mut cfg = RunConfig::default();
    cfg.curriculum = CurriculumKind::Uniform;
    cfg.max_steps = 40;
    cfg.eval_every = 0;
    cfg.dataset_size = 8000;
    cfg.label = "RLOO".into();
    let rec = driver::run_sim(&cfg).expect("run");
    let last = rec.steps.last().unwrap();
    let n = rec.steps.len() as f64;
    let mut t = Table::new(&["phase", "s/step", "share"]);
    let inf = last.inference_s / n;
    let upd = last.update_s / n;
    t.row(vec!["inference".into(), format!("{inf:.1}"), format!("{:.0}%", 100.0 * inf / (inf + upd))]);
    t.row(vec!["training".into(), format!("{upd:.1}"), format!("{:.0}%", 100.0 * upd / (inf + upd))]);
    t.print();
    println!("\npaper shape: inference ~2x training per step (Fig 2 right). ratio here: {:.1}x", inf / upd);
}
