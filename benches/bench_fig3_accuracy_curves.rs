//! Figure 3 + Figure 6 regeneration: validation-accuracy-vs-wall-clock
//! curves for SPEED variants against their base algorithms.
//!
//! Fig 3: sim-7b on synth-deepscale, RLOO vs SPEED-RLOO (top) and DAPO vs
//! SPEED-DAPO (bottom), across all four benchmarks.
//! Fig 6 (grid mode): all seven paper configuration rows.
//!
//!     cargo bench --bench bench_fig3_accuracy_curves [--grid]

use speed_rl::config::RunConfig;
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::data::dataset::DatasetKind;
use speed_rl::driver;
use speed_rl::metrics::RunRecord;
use speed_rl::rl::algo::BaseAlgo;
use speed_rl::util::stats::ema_curve;

fn run(model: &str, dataset: DatasetKind, curriculum: CurriculumKind, algo: BaseAlgo, label: &str) -> RunRecord {
    let mut cfg = RunConfig::default();
    cfg.model = model.to_string();
    cfg.dataset = dataset;
    cfg.dataset_size = 16_000;
    cfg.curriculum = curriculum;
    cfg.algo = algo;
    cfg.label = label.to_string();
    cfg.max_steps = 200;
    cfg.eval_every = 10;
    driver::run_sim(&cfg).expect("sim run")
}

fn print_curves(recs: &[RunRecord]) {
    for bench in ["dapo1k", "math500", "amc2023", "aime"] {
        println!("  benchmark {bench}:");
        for rec in recs {
            let curve = rec.curve(bench);
            let accs: Vec<f64> = curve.iter().map(|(_, a)| *a).collect();
            let smooth = ema_curve(&accs, 0.5); // bold EMA curves like Fig 6
            let pts: Vec<String> = curve
                .iter()
                .zip(&smooth)
                .step_by(2)
                .map(|((t, _), a)| format!("({:.1}h,{a:.3})", t / 3600.0))
                .collect();
            println!("    {:<12} {}", rec.label, pts.join(" "));
        }
    }
}

fn main() {
    let grid = std::env::args().any(|a| a == "--grid");

    println!("Figure 3: sim-7b on synth-deepscale\n");
    let rows = [
        ("RLOO", CurriculumKind::Uniform, BaseAlgo::Rloo),
        ("SPEED-RLOO", CurriculumKind::Speed, BaseAlgo::Rloo),
        ("DAPO", CurriculumKind::DapoFilter, BaseAlgo::Dapo),
        ("SPEED-DAPO", CurriculumKind::Speed, BaseAlgo::Dapo),
    ];
    let recs: Vec<RunRecord> = rows
        .iter()
        .map(|(l, c, a)| {
            eprintln!("[fig3] {l}");
            run("sim-7b", DatasetKind::SynthDeepScale, *c, *a, l)
        })
        .collect();
    print_curves(&recs);

    if grid {
        println!("\nFigure 6: full configuration grid\n");
        let configs: [(&str, DatasetKind, BaseAlgo); 7] = [
            ("sim-7b", DatasetKind::SynthDeepScale, BaseAlgo::Rloo),
            ("sim-7b", DatasetKind::SynthDeepScale, BaseAlgo::Dapo),
            ("sim-7b", DatasetKind::SynthDapo17k, BaseAlgo::Rloo),
            ("sim-7b", DatasetKind::SynthDapo17k, BaseAlgo::Dapo),
            ("sim-1.5b", DatasetKind::SynthNumina, BaseAlgo::Rloo),
            ("sim-1.5b", DatasetKind::SynthNumina, BaseAlgo::Dapo),
            ("sim-1.5b", DatasetKind::SynthDapo17k, BaseAlgo::Rloo),
        ];
        for (model, dataset, algo) in configs {
            let base_kind = match algo {
                BaseAlgo::Dapo => CurriculumKind::DapoFilter,
                _ => CurriculumKind::Uniform,
            };
            println!("\nconfig: {model} + {} + {}", dataset.name(), algo.name());
            let recs = vec![
                run(model, dataset, base_kind, algo, algo.name()),
                run(model, dataset, CurriculumKind::Speed, algo, &format!("SPEED-{}", algo.name())),
            ];
            print_curves(&recs);
        }
    }
}
