//! Runtime (L2/L3 boundary) benchmarks on the real PJRT artifacts: rollout
//! call latency, train/sft step latency, eval throughput, literal
//! marshalling. Skips gracefully when artifacts are absent.
//!
//!     cargo bench --bench bench_runtime

use std::path::PathBuf;

use speed_rl::bench::BenchRunner;
use speed_rl::data::dataset::{Dataset, DatasetKind};
use speed_rl::policy::real::RealPolicy;
use speed_rl::policy::{GenRequest, RolloutEngine, Trainable};
use speed_rl::rl::algo::{AlgoConfig, BaseAlgo};
use speed_rl::rl::update::PromptGroup;
use speed_rl::runtime::Tensor;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let mut policy = RealPolicy::load(&dir, 0).expect("load policy");
    let plan = policy.runtime.manifest.plan.clone();
    let r = BenchRunner::new(2, 10);

    let data = Dataset::training(DatasetKind::SynthDapo17k, 64, 5, plan.prompt_len.min(20));
    let n_prompts = plan.rollout_rows / 4;
    let requests: Vec<GenRequest> = data.instances[..n_prompts]
        .iter()
        .enumerate()
        .map(|(i, t)| GenRequest { prompt_idx: i, task: t.clone(), n_samples: 4 })
        .collect();

    // --- rollout call (the request-path hot spot) ---
    let res = r.run(
        &format!("rollout call {} rows x {} tokens", plan.rollout_rows, plan.gen_len),
        || {
            std::hint::black_box(policy.generate(&requests, 1.0).unwrap());
        },
    );
    println!(
        "    -> {:.0} rollouts/s, {:.0} tokens/s decode",
        res.throughput(plan.rollout_rows as f64),
        res.throughput((plan.rollout_rows * plan.gen_len) as f64)
    );

    // --- train step ---
    let gen = policy.generate(&requests, 1.0).unwrap();
    let groups: Vec<PromptGroup> = requests
        .iter()
        .zip(gen.groups)
        .map(|(req, rollouts)| PromptGroup {
            prompt_idx: req.prompt_idx,
            task: req.task.clone(),
            rollouts,
        })
        .collect();
    let mut algo = AlgoConfig::new(BaseAlgo::Rloo);
    algo.lr = 0.0; // keep weights frozen while timing
    let res = r.run(&format!("train step {} rows", plan.train_rows), || {
        std::hint::black_box(policy.train(&groups, &algo).unwrap());
    });
    println!("    -> {:.0} rows/s", res.throughput(plan.train_rows as f64));

    // --- sft step ---
    let easy: Vec<_> = data.instances.iter().take(plan.sft_rows).cloned().collect();
    let res = r.run(&format!("sft step {} rows", plan.sft_rows), || {
        std::hint::black_box(policy.sft_step(&easy, 0.0).unwrap());
    });
    println!("    -> {:.0} rows/s", res.throughput(plan.sft_rows as f64));

    // --- greedy eval ---
    let tasks: Vec<_> = data.instances[..plan.rollout_rows.min(64)].to_vec();
    let res = r.run(&format!("greedy eval {} tasks", tasks.len()), || {
        std::hint::black_box(policy.evaluate(&tasks).unwrap());
    });
    println!("    -> {:.0} tasks/s", res.throughput(tasks.len() as f64));

    // --- rollout size variants (the §Perf optimization): a 12-row call on
    // the smallest fitting artifact vs. the full-batch artifact ---
    {
        let small_reqs: Vec<GenRequest> = data.instances[..3]
            .iter()
            .enumerate()
            .map(|(i, t)| GenRequest { prompt_idx: i, task: t.clone(), n_samples: 4 })
            .collect();
        let opts = policy.runtime.manifest.rollout_row_options();
        println!("\n    rollout variants compiled: {opts:?}");
        let res_small = r.run("rollout 12 rows -> smallest variant", || {
            std::hint::black_box(policy.generate(&small_reqs, 1.0).unwrap());
        });
        // Force the full-batch artifact by padding the request list with a
        // throwaway request so rows_needed exceeds the smaller variants.
        let mut full_reqs = small_reqs.clone();
        if let Some(&max_rows) = opts.last() {
            let filler = max_rows - 12;
            full_reqs.push(GenRequest {
                prompt_idx: 99,
                task: data.instances[10].clone(),
                n_samples: filler,
            });
        }
        let res_full = r.run("rollout 12+filler rows -> full batch", || {
            std::hint::black_box(policy.generate(&full_reqs, 1.0).unwrap());
        });
        println!(
            "    -> small-call speedup {:.2}x (before: every call paid the full batch)",
            res_full.median_s / res_small.median_s
        );
    }

    // --- literal marshalling (host <-> device boundary) ---
    let t = Tensor::f32(vec![64, 48], vec![0.5; 64 * 48]);
    r.run("tensor->literal 64x48 f32 x1000", || {
        for _ in 0..1000 {
            std::hint::black_box(t.to_literal().unwrap());
        }
    });
    // What every rollout call paid before the ParamStore borrowed its
    // cached literal sequence straight into PJRT: a deep clone per tensor.
    let store_params = policy.store.param_literals();
    r.run("clone param literals (28 tensors)", || {
        std::hint::black_box(store_params.to_vec());
    });
}
