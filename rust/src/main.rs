//! `speed-rl` — the leader binary.
//!
//! Subcommands:
//!   simulate   run a paper-scale simulated training run (Table 1 configs)
//!   train      RL-train the real AOT transformer through PJRT
//!   sft        supervised warmup of the real transformer ("base model")
//!   eval       score a (checkpointed) real model on the benchmark suite
//!   info       print the artifact manifest summary
//!   report     ASCII accuracy-vs-time charts from run records
//!   bench      coalescing / allocation / pool smoke benches
//!   trace      summarize or re-export a --trace timeline
//!   lint       run the repo's invariant linter over its own source tree
//!
//! Run `speed-rl <subcommand> --help` for options.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use speed_rl::checkpoint::{CheckpointIo, CheckpointSpec};
use speed_rl::config::{RunConfig, Substrate};
use speed_rl::coordinator::curriculum::CurriculumKind;
use speed_rl::data::dataset::{Dataset, DatasetKind};
use speed_rl::driver;
use speed_rl::eval::benchmark_suite;
use speed_rl::info;
use speed_rl::metrics::RunRecord;
use speed_rl::policy::real::RealPolicy;
use speed_rl::policy::RolloutEngine;
use speed_rl::rl::algo::BaseAlgo;
use speed_rl::util::cli::Cli;
use speed_rl::util::json::Json;
use speed_rl::util::logging::{self, level_from_str};

fn main() {
    if let Err(e) = run() {
        // Through the leveled logger (never filtered: Error is the top
        // level) so failures carry the same timestamped format as the
        // run's other diagnostics.
        logging::log(logging::Level::Error, "main", &format!("{e:#}"));
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    // Pin the shared log/trace epoch at process start, not at first use:
    // every timestamp in every sink is measured from here.
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "simulate" => cmd_simulate(rest),
        "train" => cmd_train(rest),
        "sft" => cmd_sft(rest),
        "eval" => cmd_eval(rest),
        "info" => cmd_info(rest),
        "report" => cmd_report(rest),
        "bench" => cmd_bench(rest),
        "trace" => cmd_trace(rest),
        "lint" => cmd_lint(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (see --help)"),
    }
}

fn print_usage() {
    println!(
        "speed-rl — SPEED-RL reproduction (online curriculum RL for reasoning models)\n\n\
         Subcommands:\n\
         \x20 simulate   paper-scale simulated run (Table 1 configs)\n\
         \x20 train      RL-train the real AOT transformer via PJRT\n\
         \x20 sft        supervised warmup of the real transformer\n\
         \x20 eval       score a real model checkpoint on the benchmarks\n\
         \x20 info       print the artifact manifest summary\n\
         \x20 report     ASCII accuracy-vs-time charts from run records\n\
         \x20 bench      smoke benches: --mode coalesce (service) | alloc (budgets) | pool (engine scaling) | slots (continuous batching)\n\
         \x20 trace      summarize a --trace timeline (per-phase breakdown, latency percentiles)\n\
         \x20 lint       check the repo's own invariants: lock discipline, counter schemas,\n\
         \x20            harness registration, wall-clock hygiene, metric tables (DESIGN.md 15)\n"
    );
}

fn common_cli(name: &'static str, about: &'static str) -> Cli {
    Cli::new(name, about)
        .opt("log-level", Some("info"), "debug|info|warn|error")
        .opt("seed", Some("0"), "PRNG seed")
        .opt("out", None, "write the run record JSON to this path")
}

fn write_record(args_out: Option<&str>, record: &RunRecord) -> Result<()> {
    if let Some(path) = args_out {
        std::fs::write(path, record.to_json().to_string_pretty())
            .with_context(|| format!("write {path}"))?;
        info!("main", "run record written to {path}");
    }
    Ok(())
}

fn print_summary(record: &RunRecord, model: &str) {
    println!("\n== {} ==", record.label);
    println!(
        "steps {}  time {:.1}s (inference {:.1}s / update {:.1}s)  calls {}  rollouts {}",
        record.steps.len(),
        record.total_time(),
        record.steps.last().map(|s| s.inference_s).unwrap_or(0.0),
        record.steps.last().map(|s| s.update_s).unwrap_or(0.0),
        record.counters.calls,
        record.counters.rollouts,
    );
    if record.counters.prompts_screened > 0 {
        println!(
            "screened {}  accepted {} ({:.0}%)",
            record.counters.prompts_screened,
            record.counters.prompts_accepted,
            100.0 * record.counters.acceptance_rate()
        );
    }
    if record.mean_staleness() > 0.0 {
        println!("mean buffer staleness {:.2} steps", record.mean_staleness());
    }
    if let Some(svc) = &record.service {
        println!(
            "service: {} calls from {} submissions ({:.1} coalesced/call, fill {:.0}%, \
             queue wait {:.2} ms, {} installs, {} deadline dispatches)",
            svc.calls,
            svc.submissions,
            svc.mean_coalesced(),
            100.0 * svc.mean_fill(),
            1e3 * svc.mean_queue_wait_s(),
            svc.installs,
            svc.deadline_dispatches,
        );
        println!(
            "batching: {} mode  mean slot occupancy {:.2}  {} admissions / {} retires  {} steals",
            if svc.slots_mode > 0 { "slots" } else { "deadline" },
            svc.mean_slot_occupancy(),
            svc.slot_admissions,
            svc.slot_retires,
            svc.steals,
        );
        if svc.engines > 1 {
            let e = (svc.engines as usize).min(svc.replica_calls.len());
            println!(
                "pool: {} engines  balance {:.2}  {} steals  per-replica calls {:?}",
                svc.engines,
                svc.pool_balance(),
                svc.steals,
                &svc.replica_calls[..e],
            );
        }
        if svc.faults_injected > 0 || svc.quarantines > 0 {
            println!(
                "faults: {} injected  {} retries  {} redispatches  {} quarantines  {} respawns",
                svc.faults_injected,
                svc.retries,
                svc.redispatches,
                svc.quarantines,
                svc.respawns,
            );
        }
    }
    if record.counters.prompts_skipped > 0 || record.counters.brier_n > 0 {
        println!(
            "predictor: skipped {} prompts ({} rollouts saved, {} explored)  brier {:.3}  precision {:.2}  recall {:.2}",
            record.counters.prompts_skipped,
            record.counters.rollouts_saved,
            record.counters.prompts_explored,
            record.counters.predictor_brier(),
            record.counters.predictor_precision(),
            record.counters.predictor_recall(),
        );
    }
    for (bench, target) in driver::paper_targets(model) {
        let acc = record.final_accuracy(bench).unwrap_or(0.0);
        match record.time_to_target(bench, target) {
            Some(t) => println!("  {bench:<8} final {acc:.3}  target {target} reached at {t:.0}s"),
            None => println!("  {bench:<8} final {acc:.3}  target {target} not reached"),
        }
    }
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let cli = common_cli("speed-rl simulate", "paper-scale simulated training run")
        .opt("preset", None, "paper setup, e.g. 7b-deepscale-speed-rloo")
        .opt("config", None, "JSON RunConfig file (overrides preset)")
        .opt("model", Some("sim-7b"), "sim-1.5b | sim-7b")
        .opt("dataset", Some("dapo17k"), "numina | dapo17k | deepscale")
        .opt("dataset-size", None, "training prompts to generate (default: dataset-derived)")
        .opt(
            "curriculum",
            Some("speed"),
            "uniform | dapo | speed | speed-naive | predictive-speed | variance-max",
        )
        .opt("algo", Some("rloo"), "rloo | dapo | grpo | reinforce | reinforce++")
        .opt("n-init", Some("8"), "screening rollouts per prompt")
        .opt("n-cont", Some("16"), "continuation rollouts per prompt (adaptive: the reference)")
        .opt("alloc", None, "continuation-budget allocator: fixed | adaptive")
        .opt("n-cont-min", None, "adaptive allocation floor (0 = auto: n-cont/2)")
        .opt("n-cont-max", None, "adaptive allocation ceiling (0 = auto: 2*n-cont)")
        .opt("batch-size", Some("16"), "training batch size B")
        .opt("steps", Some("400"), "max training steps")
        .opt("max-hours", None, "stop after this much simulated time")
        .opt("eval-every", Some("10"), "evaluation cadence (steps)")
        .opt("workers", None, "rollout workers for the pipelined coordinator")
        .opt("buffer-cap", None, "shared buffer capacity in groups (0 = auto)")
        .opt(
            "skip-confidence",
            None,
            "predictive-speed: skip screening at this predicted-reject confidence (1.0 = never)",
        )
        .opt(
            "predictor-discount",
            None,
            "predictive-speed: per-rollout discount of the difficulty posterior",
        )
        .opt(
            "explore-rate",
            None,
            "predictive-speed: probability of screening a confidently-skipped prompt anyway",
        )
        .opt(
            "batching",
            None,
            "service dispatch mode: deadline (micro-batch) | slots (continuous batching)",
        )
        .opt(
            "coalesce-wait-ms",
            None,
            "service: micro-batch deadline before a partially-filled call executes",
        )
        .opt(
            "fill-waterline",
            None,
            "service: fraction of engine capacity that dispatches a call immediately",
        )
        .opt(
            "save",
            None,
            "write a run-state checkpoint to dir:tag (final, and periodic with --save-every)",
        )
        .opt("save-every", None, "checkpoint cadence in steps (0 = final save only; needs --save)")
        .opt("resume", None, "warm-resume from a run-state checkpoint dir:tag")
        .opt(
            "engines",
            None,
            "data-parallel engine replicas behind the shared service (implies --service when > 1)",
        )
        .opt(
            "trace",
            None,
            "write a Chrome trace-event JSON timeline to this path (Perfetto-loadable; \
             see 'speed-rl trace')",
        )
        .opt(
            "fault-plan",
            None,
            "scripted engine faults, kind@replica:call[:millis] comma-separated \
             (kinds: err, stall, die; 'none' arms recovery with an empty script)",
        )
        .opt(
            "exec-timeout-ms",
            None,
            "quarantine a replica whose engine call exceeds this and redispatch its work \
             (0 = no watchdog)",
        )
        .flag("pipeline", "overlap inference with updates (producer/consumer)")
        .flag("service", "coalesce all rollout requests through one shared inference service")
        .flag(
            "coalesce-adaptive",
            "scale the service's micro-batch deadline with the observed submission gap",
        )
        .flag("respawn", "pre-fork spare engines and activate one when a replica is quarantined");
    let args = cli.parse(argv)?;
    logging::set_level(level_from_str(args.get("log-level").unwrap_or("info")));

    let mut cfg = if let Some(path) = args.get("config") {
        RunConfig::load(Path::new(path))?
    } else if let Some(preset) = args.get("preset") {
        RunConfig::paper_preset(preset)?
    } else {
        let mut c = RunConfig::default();
        c.model = args.string("model")?;
        c.dataset = DatasetKind::parse(args.get("dataset").unwrap()).context("dataset")?;
        c.dataset_size = c.dataset.default_size().min(40_000);
        c.curriculum = CurriculumKind::parse_or_err(args.get("curriculum").unwrap())?;
        c.algo = BaseAlgo::parse(args.get("algo").unwrap()).context("algo")?;
        c.label = format!(
            "{}-{}-{}-{}",
            c.model,
            c.dataset.name(),
            c.curriculum.name(),
            c.algo.name()
        );
        c
    };
    cfg.substrate = Substrate::Sim;
    if let Some(v) = args.get("dataset-size") {
        cfg.dataset_size = v.parse::<usize>().context("--dataset-size")?;
    }
    cfg.n_init = args.usize("n-init")?;
    cfg.n_cont = args.usize("n-cont")?;
    cfg.batch_size = args.usize("batch-size")?;
    cfg.max_steps = args.usize("steps")?;
    cfg.eval_every = args.usize("eval-every")?;
    cfg.seed = args.u64("seed")?;
    // No defaults here: absent flags leave config-file values intact.
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse::<usize>().context("--workers")?;
    }
    if let Some(c) = args.get("buffer-cap") {
        cfg.buffer_cap = c.parse::<usize>().context("--buffer-cap")?;
    }
    if let Some(v) = args.get("skip-confidence") {
        cfg.skip_confidence = v.parse::<f64>().context("--skip-confidence")?;
    }
    if let Some(v) = args.get("predictor-discount") {
        cfg.predictor_discount = v.parse::<f64>().context("--predictor-discount")?;
    }
    if let Some(v) = args.get("explore-rate") {
        cfg.explore_rate = v.parse::<f64>().context("--explore-rate")?;
    }
    if let Some(v) = args.get("alloc") {
        cfg.alloc = speed_rl::coordinator::alloc::AllocKind::parse_or_err(v)?;
    }
    if let Some(v) = args.get("n-cont-min") {
        cfg.n_cont_min = v.parse::<usize>().context("--n-cont-min")?;
    }
    if let Some(v) = args.get("n-cont-max") {
        cfg.n_cont_max = v.parse::<usize>().context("--n-cont-max")?;
    }
    if args.has_flag("pipeline") || cfg.workers > 1 {
        cfg.pipeline = true;
    }
    if args.has_flag("service") {
        cfg.service = true;
    }
    if let Some(v) = args.get("engines") {
        cfg.engines = v.parse::<usize>().context("--engines")?;
        if cfg.engines > 1 {
            cfg.service = true;
        }
    }
    if args.has_flag("coalesce-adaptive") {
        cfg.coalesce_adaptive = true;
    }
    if let Some(v) = args.get("coalesce-wait-ms") {
        cfg.coalesce_wait_ms = v.parse::<u64>().context("--coalesce-wait-ms")?;
    }
    if let Some(v) = args.get("fill-waterline") {
        cfg.fill_waterline = v.parse::<f64>().context("--fill-waterline")?;
    }
    if let Some(v) = args.get("batching") {
        cfg.batching = speed_rl::policy::service::BatchingMode::parse_or_err(v)?;
    }
    if let Some(h) = args.get("max-hours") {
        cfg.max_seconds = h.parse::<f64>().context("--max-hours")? * 3600.0;
    }
    if let Some(v) = args.get("trace") {
        cfg.trace = Some(v.to_string());
    }
    if let Some(v) = args.get("fault-plan") {
        cfg.fault_plan = Some(v.to_string());
    }
    if let Some(v) = args.get("exec-timeout-ms") {
        cfg.exec_timeout_ms = v.parse::<u64>().context("--exec-timeout-ms")?;
    }
    if args.has_flag("respawn") {
        cfg.respawn = true;
    }
    // Reject a bad --fault-plan here (with the grammar quoted) instead of
    // deep inside the spawn path; also catches plan/engine-count mismatch.
    cfg.validate()?;
    let io = checkpoint_io(&args)?;

    let record = driver::run_sim_with(&cfg, &io)?;
    print_summary(&record, &cfg.model);
    write_record(args.get("out"), &record)
}

/// The `--resume` / `--save` / `--save-every` triple shared by `simulate`
/// and `train`.
fn checkpoint_io(args: &speed_rl::util::cli::Args) -> Result<CheckpointIo> {
    let io = CheckpointIo {
        resume: args.get("resume").map(CheckpointSpec::parse).transpose()?,
        save: args.get("save").map(CheckpointSpec::parse).transpose()?,
        save_every: match args.get("save-every") {
            Some(v) => v.parse::<usize>().context("--save-every")?,
            None => 0,
        },
    };
    io.validate()?;
    Ok(io)
}

fn artifacts_arg(args: &speed_rl::util::cli::Args) -> PathBuf {
    PathBuf::from(args.get("artifacts").unwrap_or("artifacts"))
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cli = common_cli("speed-rl train", "RL-train the real AOT transformer")
        .opt("artifacts", Some("artifacts"), "artifact directory (make artifacts)")
        .opt("checkpoint", None, "start from checkpoint dir:tag (e.g. ckpts:warm)")
        .opt("dataset", Some("dapo17k"), "numina | dapo17k | deepscale")
        .opt("dataset-size", Some("4000"), "training prompts to generate")
        .opt(
            "curriculum",
            Some("speed"),
            "uniform | dapo | speed | speed-naive | predictive-speed | variance-max",
        )
        .opt("algo", Some("rloo"), "rloo | dapo | grpo | reinforce | reinforce++")
        .opt("n-init", Some("4"), "screening rollouts")
        .opt("n-cont", Some("12"), "continuation rollouts (adaptive: the reference)")
        .opt("alloc", None, "continuation-budget allocator: fixed | adaptive")
        .opt("n-cont-min", None, "adaptive allocation floor (0 = auto: n-cont/2)")
        .opt("n-cont-max", None, "adaptive allocation ceiling (0 = auto: 2*n-cont)")
        .opt(
            "skip-confidence",
            None,
            "predictive-speed: skip screening at this predicted-reject confidence (1.0 = never)",
        )
        .opt(
            "predictor-discount",
            None,
            "predictive-speed: per-rollout discount of the difficulty posterior",
        )
        .opt(
            "explore-rate",
            None,
            "predictive-speed: probability of screening a confidently-skipped prompt anyway",
        )
        .opt("batch-size", Some("4"), "training batch size B (prompts)")
        .opt("lr", Some("3e-4"), "learning rate")
        .opt("steps", Some("50"), "max training steps")
        .opt("eval-every", Some("10"), "evaluation cadence")
        .opt("save", None, "write a run-state checkpoint (weights + curriculum state) to dir:tag")
        .opt("save-every", None, "checkpoint cadence in steps (0 = final save only; needs --save)")
        .opt("resume", None, "warm-resume from a run-state checkpoint dir:tag")
        .opt("trace", None, "write a Chrome trace-event JSON timeline to this path");
    let args = cli.parse(argv)?;
    logging::set_level(level_from_str(args.get("log-level").unwrap_or("info")));

    let mut cfg = RunConfig::default();
    cfg.substrate = Substrate::Real;
    cfg.dataset = DatasetKind::parse(args.get("dataset").unwrap()).context("dataset")?;
    cfg.dataset_size = args.usize("dataset-size")?;
    cfg.curriculum = CurriculumKind::parse_or_err(args.get("curriculum").unwrap())?;
    cfg.algo = BaseAlgo::parse(args.get("algo").unwrap()).context("algo")?;
    cfg.n_init = args.usize("n-init")?;
    cfg.n_cont = args.usize("n-cont")?;
    cfg.batch_size = args.usize("batch-size")?;
    cfg.lr = args.f64("lr")?;
    cfg.max_steps = args.usize("steps")?;
    cfg.eval_every = args.usize("eval-every")?;
    cfg.seed = args.u64("seed")?;
    if let Some(v) = args.get("skip-confidence") {
        cfg.skip_confidence = v.parse::<f64>().context("--skip-confidence")?;
    }
    if let Some(v) = args.get("predictor-discount") {
        cfg.predictor_discount = v.parse::<f64>().context("--predictor-discount")?;
    }
    if let Some(v) = args.get("explore-rate") {
        cfg.explore_rate = v.parse::<f64>().context("--explore-rate")?;
    }
    if let Some(v) = args.get("alloc") {
        cfg.alloc = speed_rl::coordinator::alloc::AllocKind::parse_or_err(v)?;
    }
    if let Some(v) = args.get("n-cont-min") {
        cfg.n_cont_min = v.parse::<usize>().context("--n-cont-min")?;
    }
    if let Some(v) = args.get("n-cont-max") {
        cfg.n_cont_max = v.parse::<usize>().context("--n-cont-max")?;
    }
    cfg.label = format!("real-{}-{}", cfg.curriculum.name(), cfg.algo.name());
    if let Some(v) = args.get("trace") {
        cfg.trace = Some(v.to_string());
    }

    let dir = artifacts_arg(&args);
    let mut policy = RealPolicy::load(&dir, cfg.seed)?;
    if let Some(spec) = args.get("checkpoint") {
        // Weights-only warm start (e.g. the SFT "base model"); full
        // run-state resume is --resume.
        let ck = CheckpointSpec::parse(spec).context("--checkpoint")?;
        policy.store.load(&ck.dir, &ck.tag)?;
        info!("main", "loaded checkpoint weights from {ck}");
    }
    let io = checkpoint_io(&args)?;
    let max_chars = policy.runtime.manifest.plan.prompt_len.min(20);
    let dataset = Dataset::training(cfg.dataset, cfg.dataset_size, cfg.seed, max_chars);
    let evals = benchmark_suite(driver::BENCH_SEED, max_chars);
    let record = driver::run_with_policy_io(&cfg, &mut policy, &dataset, &evals, &io)?;
    print_summary(&record, "real");
    write_record(args.get("out"), &record)
}

fn cmd_sft(argv: &[String]) -> Result<()> {
    let cli = common_cli("speed-rl sft", "supervised warmup (the 'base model' phase)")
        .opt("artifacts", Some("artifacts"), "artifact directory")
        .opt("steps", Some("300"), "SFT steps")
        .opt("lr", Some("3e-3"), "learning rate")
        .opt("max-level", Some("4"), "only train on tasks up to this difficulty")
        .opt("save", Some("ckpts:warm"), "checkpoint dir:tag to write");
    let args = cli.parse(argv)?;
    logging::set_level(level_from_str(args.get("log-level").unwrap_or("info")));

    let dir = artifacts_arg(&args);
    let mut policy = RealPolicy::load(&dir, args.u64("seed")?)?;
    let steps = args.usize("steps")?;
    let lr = args.f64("lr")?;
    let max_level = args.usize("max-level")? as u8;
    let max_chars = policy.runtime.manifest.plan.prompt_len.min(20);
    let rows = policy.runtime.manifest.plan.sft_rows;
    let corpus = Dataset::training(DatasetKind::SynthNumina, 20_000, args.u64("seed")?, max_chars);
    let easy: Vec<_> = corpus.instances.iter().filter(|t| t.level <= max_level).cloned().collect();
    anyhow::ensure!(easy.len() >= rows, "not enough easy instances");
    let mut rng = speed_rl::util::rng::Rng::new(args.u64("seed")? ^ 0x5f7);
    for step in 0..steps {
        let idx = rng.sample_indices(easy.len(), rows);
        let batch: Vec<_> = idx.into_iter().map(|i| easy[i].clone()).collect();
        let loss = policy.sft_step(&batch, lr)?;
        if step % 20 == 0 || step + 1 == steps {
            info!("sft", "step {step}: loss {loss:.4}");
        }
    }
    let ck = CheckpointSpec::parse(args.get("save").unwrap()).context("--save")?;
    policy.store.save(&ck.dir, &ck.tag)?;
    info!("main", "warm checkpoint saved to {ck}");
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = common_cli("speed-rl eval", "score a real model on the benchmark suite")
        .opt("artifacts", Some("artifacts"), "artifact directory")
        .opt("checkpoint", None, "checkpoint dir:tag (defaults to init params)");
    let args = cli.parse(argv)?;
    logging::set_level(level_from_str(args.get("log-level").unwrap_or("info")));

    let dir = artifacts_arg(&args);
    let mut policy = RealPolicy::load(&dir, args.u64("seed")?)?;
    if let Some(spec) = args.get("checkpoint") {
        let ck = CheckpointSpec::parse(spec).context("--checkpoint")?;
        policy.store.load(&ck.dir, &ck.tag)?;
    }
    let max_chars = policy.runtime.manifest.plan.prompt_len.min(20);
    for set in benchmark_suite(driver::BENCH_SEED, max_chars) {
        let res = policy.evaluate(&set.tasks)?;
        println!(
            "{:<10} {:.3}  ({} tasks, {:.1}s)",
            set.name,
            res.accuracy,
            set.tasks.len(),
            res.cost_s
        );
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let cli = common_cli("speed-rl info", "artifact manifest summary")
        .opt("artifacts", Some("artifacts"), "artifact directory");
    let args = cli.parse(argv)?;
    let dir = artifacts_arg(&args);
    let manifest = speed_rl::runtime::Manifest::load(&dir)?;
    println!("preset      {}", manifest.preset);
    println!(
        "model       d={} L={} H={} ff={} maxseq={} vocab={} ({} params)",
        manifest.model.d_model,
        manifest.model.n_layers,
        manifest.model.n_heads,
        manifest.model.d_ff,
        manifest.model.max_seq,
        manifest.model.vocab_size,
        manifest.model.num_params
    );
    println!(
        "plan        rollout {}x{} (+{} gen), train {} rows, sft {} rows",
        manifest.plan.rollout_rows,
        manifest.plan.prompt_len,
        manifest.plan.gen_len,
        manifest.plan.train_rows,
        manifest.plan.sft_rows
    );
    for (name, art) in &manifest.artifacts {
        println!(
            "artifact    {:<14} {} args, {} outputs ({})",
            name,
            art.args.len(),
            art.outputs.len(),
            art.file
        );
    }
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new("speed-rl report", "render run-record JSONs as ASCII charts")
        .opt("bench", Some("dapo1k"), "benchmark to chart (or 'all')")
        .opt(
            "metric",
            Some("accuracy"),
            "accuracy | skip-rate | explore-rate | service-fill | pool-balance | staleness | \
             alloc-rows | alloc-calibration | queue-wait-p95 | exec-p95 | faults | retries | \
             slot-occupancy (per-step charts)",
        )
        .opt("width", Some("72"), "chart width")
        .opt("height", Some("16"), "chart height");
    let args = cli.parse(argv)?;
    anyhow::ensure!(!args.positional.is_empty(), "usage: speed-rl report <run1.json> [run2.json ...]");
    let records: Vec<RunRecord> = args
        .positional
        .iter()
        .map(|p| -> Result<RunRecord> {
            let j = speed_rl::util::json::Json::parse_file(Path::new(p))?;
            speed_rl::metrics::report::record_from_json(&j)
        })
        .collect::<Result<_>>()?;
    let refs: Vec<&RunRecord> = records.iter().collect();
    let width = args.usize("width")?;
    let height = args.usize("height")?;
    let metric = args.string("metric")?;
    if metric != "accuracy" {
        println!("{}", speed_rl::metrics::report::step_chart(&refs, &metric, width, height)?);
        return Ok(());
    }
    let benches: Vec<String> = if args.get("bench") == Some("all") {
        let mut b: Vec<String> = records
            .iter()
            .flat_map(|r| r.evals.iter().map(|e| e.benchmark.clone()))
            .collect();
        b.sort();
        b.dedup();
        b
    } else {
        vec![args.string("bench")?]
    };
    for b in benches {
        println!("{}", speed_rl::metrics::report::ascii_chart(&refs, &b, width, height));
    }
    Ok(())
}

/// `speed-rl trace summarize <trace.json>` — analyze a Chrome trace-event
/// timeline written by `--trace`: per-phase wall-clock breakdown with
/// p50/p95/p99 span latencies, instant-event counts, and drop accounting.
/// `--format chrome` re-exports the parsed document instead (normalized
/// key order; handy for piping a validated copy elsewhere).
fn cmd_trace(argv: &[String]) -> Result<()> {
    let cli = Cli::new("speed-rl trace", "summarize or re-export a --trace timeline")
        .opt("format", Some("summary"), "summary | chrome (re-export the trace JSON)")
        .opt("out", None, "with --format chrome: write the re-export here (default: stdout)");
    let args = cli.parse(argv)?;
    // Both `trace summarize out.json` and `trace out.json` are accepted:
    // the action word is optional sugar for the default format.
    let mut files: Vec<&str> = args.positional.iter().map(|s| s.as_str()).collect();
    if files.first() == Some(&"summarize") {
        files.remove(0);
    }
    anyhow::ensure!(
        files.len() == 1,
        "usage: speed-rl trace summarize <trace.json> [--format summary|chrome]"
    );
    let path = files[0];
    let doc = Json::parse_file(Path::new(path)).with_context(|| format!("read {path}"))?;
    // Validates the document shape either way (bails on a non-trace JSON).
    let s = speed_rl::trace::summarize_chrome(&doc)?;
    match args.string("format")?.as_str() {
        "chrome" => match args.get("out") {
            Some(out) => {
                std::fs::write(out, doc.to_string()).with_context(|| format!("write {out}"))?;
                info!("trace", "re-exported {} events to {out}", s.events);
            }
            None => println!("{doc}"),
        },
        "summary" => {
            println!(
                "trace {path}: {} threads, {} events ({} dropped), wall {:.3}s",
                s.threads, s.events, s.dropped_events, s.wall_s
            );
            println!(
                "{:<18} {:>7} {:>10} {:>10} {:>10} {:>10} {:>7}",
                "phase", "count", "total s", "p50 ms", "p95 ms", "p99 ms", "% wall"
            );
            for p in &s.phases {
                println!(
                    "{:<18} {:>7} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>7.1}",
                    p.name,
                    p.count,
                    p.total_s,
                    1e3 * p.p50_s,
                    1e3 * p.p95_s,
                    1e3 * p.p99_s,
                    100.0 * p.total_s / s.wall_s.max(1e-12)
                );
            }
            if !s.instants.is_empty() {
                println!("instant events:");
                for (name, count) in &s.instants {
                    println!("  {name:<16} {count}");
                }
            }
        }
        other => bail!("unknown trace format '{other}' (valid: summary, chrome)"),
    }
    Ok(())
}

/// `speed-rl lint` — run the L1–L5 invariant lints (DESIGN.md §15) over
/// the repository's own source tree. Exit status is the gate: any
/// violation prints as `file:line: [Lx] message` and fails the command,
/// which is how `rust/ci.sh` hard-gates the invariants ahead of
/// fmt/clippy.
fn cmd_lint(argv: &[String]) -> Result<()> {
    let cli = Cli::new("speed-rl lint", "run the repo's invariant linter")
        .opt("root", Some("."), "repository root (the directory holding Cargo.toml)");
    let args = cli.parse(argv)?;
    let root = PathBuf::from(args.string("root")?);
    anyhow::ensure!(
        root.join("Cargo.toml").is_file(),
        "{} does not look like the repository root (no Cargo.toml)",
        root.display()
    );
    let report = speed_rl::analysis::run_lints(&root)?;
    for v in &report.violations {
        println!("{v}");
    }
    if !report.violations.is_empty() {
        bail!("{} invariant violation(s) (see DESIGN.md 15)", report.violations.len());
    }
    info!("lint", "clean: {} source files scanned, 5 lint passes", report.files_scanned);
    Ok(())
}

/// The smoke benches `rust/ci.sh` runs, selected by `--mode`:
///
/// * `coalesce` — the same sim scenario executed serial, pipelined (K
///   private engines), and pipelined through the shared service
///   (`BENCH_coalesce.json`);
/// * `alloc` — fixed vs adaptive continuation-budget allocation on the
///   serial SPEED curriculum: rollouts spent to reach the same target
///   accuracy (`BENCH_alloc.json`);
/// * `pool` — K pipelined workers submitting through an engine pool of E
///   data-parallel replicas, swept over E (`BENCH_pool.json`);
/// * `slots` — the same pipelined+service scenario run twice from one
///   seed, `--batching deadline` vs `slots`: fill, queue-wait p95 and
///   steps/s at matched accuracy (`BENCH_slots.json`).
fn cmd_bench(argv: &[String]) -> Result<()> {
    let cli = common_cli("speed-rl bench", "coalescing / allocation / pool smoke benches")
        .opt("mode", Some("coalesce"), "coalesce | alloc | pool | slots")
        .opt("steps", Some("12"), "training steps per mode")
        .opt("workers", Some("4"), "rollout workers for the pipelined modes")
        .opt("batch-size", Some("8"), "training batch size B")
        .opt("dataset-size", Some("4000"), "training prompts to generate")
        .opt("target", Some("0.5"), "alloc mode: dapo1k accuracy bar for the rollout comparison")
        .opt("engines", Some("1,2,4"), "pool mode: comma-separated replica counts to sweep");
    let args = cli.parse(argv)?;
    logging::set_level(level_from_str(args.get("log-level").unwrap_or("warn")));
    match args.string("mode")?.as_str() {
        "alloc" => return cmd_bench_alloc(&args),
        "pool" => return cmd_bench_pool(&args),
        "slots" => return cmd_bench_slots(&args),
        "coalesce" => {}
        other => bail!("unknown bench mode '{other}' (valid: coalesce, alloc, pool, slots)"),
    }
    let steps = args.usize("steps")?;
    let workers = args.usize("workers")?;
    let batch_size = args.usize("batch-size")?;
    let dataset_size = args.usize("dataset-size")?;
    let seed = args.u64("seed")?;

    let base = |label: &str| -> RunConfig {
        let mut c = RunConfig::default();
        c.label = label.to_string();
        c.batch_size = batch_size;
        c.dataset_size = dataset_size;
        c.max_steps = steps;
        c.eval_every = steps; // one mid/final eval point, cheap
        c.seed = seed;
        c
    };
    let serial = base("serial");
    let mut pipelined = base("pipelined");
    pipelined.pipeline = true;
    pipelined.workers = workers;
    let mut serviced = base("pipelined+service");
    serviced.pipeline = true;
    serviced.workers = workers;
    serviced.service = true;

    let mut table = speed_rl::bench::Table::new(&[
        "mode",
        "steps/s",
        "engine calls",
        "mean fill %",
        "rollouts",
        "virtual time s",
    ]);
    let mut modes = Vec::new();
    for cfg in [serial, pipelined, serviced] {
        let t0 = std::time::Instant::now();
        let rec = driver::run_sim(&cfg)?;
        let wall_s = t0.elapsed().as_secs_f64();
        let steps_per_sec = rec.steps.len() as f64 / wall_s.max(1e-9);
        // Engine-call accounting: with the service on, worker counters
        // count SUBMISSIONS; the executed calls live in the service stats.
        let (engine_calls, mean_fill) = match &rec.service {
            Some(svc) => (svc.calls, svc.mean_fill()),
            None => (rec.counters.calls, rec.counters.utilization()),
        };
        table.row(vec![
            cfg.label.clone(),
            format!("{steps_per_sec:.1}"),
            engine_calls.to_string(),
            format!("{:.1}", 100.0 * mean_fill),
            rec.counters.rollouts.to_string(),
            format!("{:.1}", rec.total_time()),
        ]);
        modes.push(Json::obj(vec![
            ("label", Json::str(cfg.label.clone())),
            ("steps", Json::num(rec.steps.len() as f64)),
            ("wall_s", Json::num(wall_s)),
            ("steps_per_sec", Json::num(steps_per_sec)),
            ("engine_calls", Json::num(engine_calls as f64)),
            ("submissions", Json::num(rec.counters.calls as f64)),
            ("mean_fill", Json::num(mean_fill)),
            ("rollouts", Json::num(rec.counters.rollouts as f64)),
            ("virtual_time_s", Json::num(rec.total_time())),
            ("final_dapo1k", Json::num(rec.final_accuracy("dapo1k").unwrap_or(0.0))),
        ]));
    }
    table.print();
    let out = args.get("out").unwrap_or("BENCH_coalesce.json");
    let j = Json::obj(vec![
        ("bench", Json::str("coalesce")),
        ("steps", Json::num(steps as f64)),
        ("workers", Json::num(workers as f64)),
        ("modes", Json::Arr(modes)),
    ]);
    std::fs::write(out, j.to_string_pretty()).with_context(|| format!("write {out}"))?;
    info!("bench", "results written to {out}");
    Ok(())
}

/// `speed-rl bench --mode pool`: K pipelined workers coalescing through an
/// engine pool, swept over the replica count E. All sweep points share the
/// seed and dataset, so the virtual-time and accuracy columns measure the
/// same training run while wall-clock steps/s and the per-replica counters
/// show how the pool spreads the load.
fn cmd_bench_pool(args: &speed_rl::util::cli::Args) -> Result<()> {
    let steps = args.usize("steps")?;
    let workers = args.usize("workers")?;
    let batch_size = args.usize("batch-size")?;
    let dataset_size = args.usize("dataset-size")?;
    let seed = args.u64("seed")?;
    let engines: Vec<usize> = args
        .string("engines")?
        .split(',')
        .map(|s| s.trim().parse::<usize>().context("--engines"))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!engines.is_empty(), "--engines needs at least one replica count");

    let mut table = speed_rl::bench::Table::new(&[
        "engines",
        "steps/s",
        "engine calls",
        "mean fill %",
        "pool balance",
        "steals",
        "virtual time s",
        "final dapo1k",
    ]);
    let mut modes = Vec::new();
    for e in engines {
        let mut cfg = RunConfig::default();
        cfg.label = format!("{workers}w-{e}e");
        cfg.batch_size = batch_size;
        cfg.dataset_size = dataset_size;
        cfg.max_steps = steps;
        cfg.eval_every = steps; // one final eval point, cheap
        cfg.seed = seed;
        cfg.pipeline = true;
        cfg.workers = workers;
        cfg.service = true;
        cfg.engines = e;
        let t0 = std::time::Instant::now();
        let rec = driver::run_sim(&cfg)?;
        let wall_s = t0.elapsed().as_secs_f64();
        let steps_per_sec = rec.steps.len() as f64 / wall_s.max(1e-9);
        let svc = rec.service.unwrap_or_default();
        table.row(vec![
            e.to_string(),
            format!("{steps_per_sec:.1}"),
            svc.calls.to_string(),
            format!("{:.1}", 100.0 * svc.mean_fill()),
            format!("{:.2}", svc.pool_balance()),
            svc.steals.to_string(),
            format!("{:.1}", rec.total_time()),
            format!("{:.3}", rec.final_accuracy("dapo1k").unwrap_or(0.0)),
        ]);
        modes.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("engines", Json::num(e as f64)),
            ("steps", Json::num(rec.steps.len() as f64)),
            ("wall_s", Json::num(wall_s)),
            ("steps_per_sec", Json::num(steps_per_sec)),
            ("engine_calls", Json::num(svc.calls as f64)),
            ("submissions", Json::num(svc.submissions as f64)),
            ("mean_fill", Json::num(svc.mean_fill())),
            ("pool_balance", Json::num(svc.pool_balance())),
            ("steals", Json::num(svc.steals as f64)),
            ("installs", Json::num(svc.installs as f64)),
            ("rollouts", Json::num(rec.counters.rollouts as f64)),
            ("virtual_time_s", Json::num(rec.total_time())),
            ("final_dapo1k", Json::num(rec.final_accuracy("dapo1k").unwrap_or(0.0))),
        ]));
    }
    table.print();
    let out = args.get("out").unwrap_or("BENCH_pool.json");
    let j = Json::obj(vec![
        ("bench", Json::str("pool")),
        ("steps", Json::num(steps as f64)),
        ("workers", Json::num(workers as f64)),
        ("modes", Json::Arr(modes)),
    ]);
    std::fs::write(out, j.to_string_pretty()).with_context(|| format!("write {out}"))?;
    info!("bench", "results written to {out}");
    Ok(())
}

/// `speed-rl bench --mode slots`: deadline coalescing vs slot-level
/// admission on one pipelined+service scenario. Both legs share the seed,
/// dataset and replica count (the first value of `--engines`), so the
/// accuracy column is the matched-accuracy check; the comparison axes are
/// mean fill, queue-wait p95 and wall-clock steps/s.
fn cmd_bench_slots(args: &speed_rl::util::cli::Args) -> Result<()> {
    use speed_rl::policy::service::BatchingMode;
    let steps = args.usize("steps")?;
    let workers = args.usize("workers")?;
    let batch_size = args.usize("batch-size")?;
    let dataset_size = args.usize("dataset-size")?;
    let seed = args.u64("seed")?;
    let engines = args
        .string("engines")?
        .split(',')
        .next()
        .unwrap_or("1")
        .trim()
        .parse::<usize>()
        .context("--engines")?;

    let mut table = speed_rl::bench::Table::new(&[
        "batching",
        "steps/s",
        "engine calls",
        "mean fill %",
        "queue-wait p95 ms",
        "slot occupancy",
        "steals",
        "final dapo1k",
    ]);
    let mut modes = Vec::new();
    for batching in [BatchingMode::Deadline, BatchingMode::Slots] {
        let mut cfg = RunConfig::default();
        cfg.label = format!("{workers}w-{engines}e-{}", batching.name());
        cfg.batch_size = batch_size;
        cfg.dataset_size = dataset_size;
        cfg.max_steps = steps;
        cfg.eval_every = steps; // one final eval point, cheap
        cfg.seed = seed;
        cfg.pipeline = true;
        cfg.workers = workers;
        cfg.service = true;
        cfg.engines = engines;
        cfg.batching = batching;
        let t0 = std::time::Instant::now();
        let rec = driver::run_sim(&cfg)?;
        let wall_s = t0.elapsed().as_secs_f64();
        let steps_per_sec = rec.steps.len() as f64 / wall_s.max(1e-9);
        let svc = rec.service.unwrap_or_default();
        let queue_wait_p95_s = speed_rl::trace::hist_quantile(&svc.queue_wait_hist, 0.95);
        table.row(vec![
            batching.name().to_string(),
            format!("{steps_per_sec:.1}"),
            svc.calls.to_string(),
            format!("{:.1}", 100.0 * svc.mean_fill()),
            format!("{:.3}", 1e3 * queue_wait_p95_s),
            format!("{:.2}", svc.mean_slot_occupancy()),
            svc.steals.to_string(),
            format!("{:.3}", rec.final_accuracy("dapo1k").unwrap_or(0.0)),
        ]);
        modes.push(Json::obj(vec![
            ("batching", Json::str(batching.name().to_string())),
            ("workers", Json::num(workers as f64)),
            ("engines", Json::num(engines as f64)),
            ("steps", Json::num(rec.steps.len() as f64)),
            ("wall_s", Json::num(wall_s)),
            ("steps_per_sec", Json::num(steps_per_sec)),
            ("engine_calls", Json::num(svc.calls as f64)),
            ("submissions", Json::num(svc.submissions as f64)),
            ("mean_fill", Json::num(svc.mean_fill())),
            ("queue_wait_p95_s", Json::num(queue_wait_p95_s)),
            ("mean_slot_occupancy", Json::num(svc.mean_slot_occupancy())),
            ("slot_admissions", Json::num(svc.slot_admissions as f64)),
            ("steals", Json::num(svc.steals as f64)),
            ("rollouts", Json::num(rec.counters.rollouts as f64)),
            ("virtual_time_s", Json::num(rec.total_time())),
            ("final_dapo1k", Json::num(rec.final_accuracy("dapo1k").unwrap_or(0.0))),
        ]));
    }
    table.print();
    let out = args.get("out").unwrap_or("BENCH_slots.json");
    let j = Json::obj(vec![
        ("bench", Json::str("slots")),
        ("steps", Json::num(steps as f64)),
        ("workers", Json::num(workers as f64)),
        ("engines", Json::num(engines as f64)),
        ("modes", Json::Arr(modes)),
    ]);
    std::fs::write(out, j.to_string_pretty()).with_context(|| format!("write {out}"))?;
    info!("bench", "results written to {out}");
    Ok(())
}

/// `speed-rl bench --mode alloc`: fixed vs adaptive continuation-budget
/// allocation at matched accuracy. Both runs share the seed, dataset and
/// rollout batch target; the comparison axis is rollouts spent by the time
/// the `dapo1k` curve first clears `--target` (fewer = better allocation).
fn cmd_bench_alloc(args: &speed_rl::util::cli::Args) -> Result<()> {
    use speed_rl::coordinator::alloc::AllocKind;
    let steps = args.usize("steps")?;
    let target = args.f64("target")?;
    let batch_size = args.usize("batch-size")?;
    let dataset_size = args.usize("dataset-size")?;
    let seed = args.u64("seed")?;
    let base = |label: &str, alloc: AllocKind| -> RunConfig {
        let mut c = RunConfig::default();
        c.label = label.to_string();
        c.curriculum = CurriculumKind::Speed;
        c.alloc = alloc;
        c.batch_size = batch_size;
        c.dataset_size = dataset_size;
        c.max_steps = steps;
        c.eval_every = 2; // fine-grained curve: the rollouts-at-target axis
        c.seed = seed;
        c
    };
    let mut table = speed_rl::bench::Table::new(&[
        "alloc",
        "rollouts",
        "rollouts@target",
        "time@target s",
        "final dapo1k",
        "mean n_cont",
        "calibration",
    ]);
    let mut modes = Vec::new();
    for cfg in [base("fixed", AllocKind::Fixed), base("adaptive", AllocKind::Adaptive)] {
        let rec = driver::run_sim(&cfg)?;
        let reached = rec.rollouts_to_target("dapo1k", target);
        let t_target = rec.time_to_target("dapo1k", target);
        table.row(vec![
            cfg.label.clone(),
            rec.counters.rollouts.to_string(),
            reached.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
            t_target.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
            format!("{:.3}", rec.final_accuracy("dapo1k").unwrap_or(0.0)),
            format!("{:.1}", rec.counters.mean_cont_alloc()),
            format!("{:.4}", rec.counters.alloc_calibration()),
        ]);
        modes.push(Json::obj(vec![
            ("label", Json::str(cfg.label.clone())),
            ("steps", Json::num(rec.steps.len() as f64)),
            ("rollouts", Json::num(rec.counters.rollouts as f64)),
            ("rollouts_to_target", reached.map(|r| Json::num(r as f64)).unwrap_or(Json::Null)),
            ("time_to_target_s", t_target.map(Json::num).unwrap_or(Json::Null)),
            ("virtual_time_s", Json::num(rec.total_time())),
            ("final_dapo1k", Json::num(rec.final_accuracy("dapo1k").unwrap_or(0.0))),
            ("mean_cont_alloc", Json::num(rec.counters.mean_cont_alloc())),
            ("alloc_calibration", Json::num(rec.counters.alloc_calibration())),
            (
                "alloc_hist",
                Json::arr(rec.counters.alloc_hist.iter().map(|c| Json::num(*c as f64))),
            ),
        ]));
    }
    table.print();
    let out = args.get("out").unwrap_or("BENCH_alloc.json");
    let j = Json::obj(vec![
        ("bench", Json::str("alloc")),
        ("steps", Json::num(steps as f64)),
        ("target", Json::num(target)),
        ("benchmark", Json::str("dapo1k")),
        ("modes", Json::Arr(modes)),
    ]);
    std::fs::write(out, j.to_string_pretty()).with_context(|| format!("write {out}"))?;
    info!("bench", "results written to {out}");
    Ok(())
}
