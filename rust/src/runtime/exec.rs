//! Executable cache + typed execution over the PJRT CPU client.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifacts::{ArtifactSig, DType, Manifest};
use crate::util::sync::plock;
use super::tensor::{DTypeKind, Tensor};

/// A compiled artifact with its signature; validates inputs before execute.
pub struct Executable {
    pub sig: ArtifactSig,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execution stats (for the §Perf accounting).
    pub calls: Mutex<(u64, f64)>, // (count, total seconds)
}

impl Executable {
    /// Execute with host tensors; returns decomposed output tensors in the
    /// signature's order. The compiled module returns a single tuple
    /// (`return_tuple=True` at lowering), decomposed here.
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        self.validate(args)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (the parameter store keeps literals
    /// around between steps to skip re-marshalling).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literal_refs(&refs)
    }

    /// Execute with borrowed literals: state literals flow straight from
    /// the [`super::ParamStore`] cache into the PJRT call without being
    /// cloned per step (`execute` is generic over `Borrow<Literal>`).
    pub fn run_literal_refs(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .with_context(|| format!("execute {}", self.sig.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.sig.name))?;
        let parts = tuple.to_tuple().context("decompose output tuple")?;
        anyhow::ensure!(
            parts.len() == self.sig.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.sig.name,
            self.sig.outputs.len(),
            parts.len()
        );
        let out: Vec<Tensor> = parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<_>>()
            .with_context(|| format!("read outputs of {}", self.sig.name))?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = plock(&self.calls);
        stats.0 += 1;
        stats.1 += dt;
        Ok(out)
    }

    /// Mixed-mode execute: literals for the leading stateful args (params /
    /// optimizer), host tensors for the per-step data args. The state
    /// literals are borrowed, never cloned — the per-call cost is
    /// marshalling the handful of small data tensors only.
    pub fn run_state_and_data(
        &self,
        state: &[xla::Literal],
        data: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.run_state_groups(&[state], data)
    }

    /// [`run_state_and_data`](Executable::run_state_and_data) with the
    /// state literals in several groups (params ++ m ++ v straight from the
    /// [`super::ParamStore`]'s own vectors), so callers never concatenate —
    /// and therefore never clone — device state to build a call.
    pub fn run_state_groups(
        &self,
        state: &[&[xla::Literal]],
        data: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let state_len: usize = state.iter().map(|s| s.len()).sum();
        anyhow::ensure!(
            state_len + data.len() == self.sig.args.len(),
            "{}: expected {} args, got {}+{}",
            self.sig.name,
            self.sig.args.len(),
            state_len,
            data.len()
        );
        for (i, t) in data.iter().enumerate() {
            let sig = &self.sig.args[state_len + i];
            anyhow::ensure!(
                t.shape() == sig.shape.as_slice() && kind_matches(t.kind(), sig.dtype),
                "{}: data arg {} ('{}') expects {:?} {:?}, got {:?} {:?}",
                self.sig.name,
                i,
                sig.name,
                sig.dtype,
                sig.shape,
                t.kind(),
                t.shape()
            );
        }
        let data_literals: Vec<xla::Literal> =
            data.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut literals: Vec<&xla::Literal> = Vec::with_capacity(self.sig.args.len());
        for group in state {
            literals.extend(group.iter());
        }
        literals.extend(data_literals.iter());
        self.run_literal_refs(&literals)
    }

    fn validate(&self, args: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            args.len() == self.sig.args.len(),
            "{}: expected {} args, got {}",
            self.sig.name,
            self.sig.args.len(),
            args.len()
        );
        for (t, sig) in args.iter().zip(&self.sig.args) {
            anyhow::ensure!(
                t.shape() == sig.shape.as_slice(),
                "{}: arg '{}' expects shape {:?}, got {:?}",
                self.sig.name,
                sig.name,
                sig.shape,
                t.shape()
            );
            anyhow::ensure!(
                kind_matches(t.kind(), sig.dtype),
                "{}: arg '{}' expects dtype {:?}, got {:?}",
                self.sig.name,
                sig.name,
                sig.dtype,
                t.kind()
            );
        }
        Ok(())
    }

    /// (call count, total seconds) since creation.
    pub fn stats(&self) -> (u64, f64) {
        *plock(&self.calls)
    }
}

fn kind_matches(kind: DTypeKind, dtype: DType) -> bool {
    matches!(
        (kind, dtype),
        (DTypeKind::F32, DType::F32) | (DTypeKind::I32, DType::I32) | (DTypeKind::U32, DType::U32)
    )
}

/// The PJRT runtime: client + manifest + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &std::path::Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        crate::info!(
            "runtime",
            "PJRT client up: platform={} devices={} preset={} ({} params)",
            client.platform_name(),
            client.device_count(),
            manifest.preset,
            manifest.model.num_params
        );
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Fetch (compiling + caching on first use) the artifact named `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = plock(&self.cache).get(name) {
            return Ok(Arc::clone(e));
        }
        let sig = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.manifest.dir.join(&sig.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        crate::info!("runtime", "compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let executable =
            Arc::new(Executable { sig, exe, calls: Mutex::new((0, 0.0)) });
        plock(&self.cache).insert(name.to_string(), Arc::clone(&executable));
        Ok(executable)
    }

    /// Fetch by unique prefix (e.g. "rollout", "train", "sft").
    pub fn executable_by_prefix(&self, prefix: &str) -> Result<Arc<Executable>> {
        let name = self.manifest.artifact_by_prefix(prefix)?.name.clone();
        self.executable(&name)
    }
}
