//! Parameter store: model weights + AdamW state, kept as XLA literals so
//! they feed straight into `train_step` / `sft_step` / `rollout` calls.
//!
//! Layout contract: `manifest.param_specs` order, f32 little-endian raw
//! concatenation — the same format `aot.py` uses for `init_params_*.bin`
//! and the checkpoint format used by `save`/`load`.

use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::Manifest;
use super::tensor::Tensor;
use crate::util::json::Json;

pub struct ParamStore {
    /// (name, shape) in manifest order.
    pub specs: Vec<(String, Vec<usize>)>,
    /// Current model parameters, one literal per spec.
    pub params: Vec<xla::Literal>,
    /// AdamW first/second moments.
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    /// Optimizer step counter (bias correction), mirrors the i32 the graphs
    /// take/return.
    pub step: i32,
}

fn zeros_like(specs: &[(String, Vec<usize>)]) -> Result<Vec<xla::Literal>> {
    specs
        .iter()
        .map(|(_, shape)| Tensor::zeros_f32(shape.clone()).to_literal())
        .collect()
}

impl ParamStore {
    /// Load initial parameters from the raw f32 file `aot.py` exported.
    pub fn from_init_file(manifest: &Manifest) -> Result<ParamStore> {
        let path = manifest.dir.join(&manifest.init_params_file);
        Self::from_raw_file(manifest, &path)
    }

    pub fn from_raw_file(manifest: &Manifest, path: &Path) -> Result<ParamStore> {
        let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        let expect = manifest.param_numel() * 4;
        anyhow::ensure!(
            bytes.len() == expect,
            "param file {} is {} bytes, expected {}",
            path.display(),
            bytes.len(),
            expect
        );
        let mut params = Vec::with_capacity(manifest.param_specs.len());
        let mut offset = 0usize;
        for (_, shape) in &manifest.param_specs {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = bytes[offset..offset + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            offset += n * 4;
            params.push(Tensor::f32(shape.clone(), data).to_literal()?);
        }
        Ok(ParamStore {
            specs: manifest.param_specs.clone(),
            m: zeros_like(&manifest.param_specs)?,
            v: zeros_like(&manifest.param_specs)?,
            params,
            step: 0,
        })
    }

    pub fn n(&self) -> usize {
        self.specs.len()
    }

    /// Literals for a rollout/forward call: params only, borrowed straight
    /// from the store (no per-call clones — the marshalled sequence is the
    /// store itself).
    pub fn param_literals(&self) -> &[xla::Literal] {
        &self.params
    }

    /// State literal groups for a train/sft call: params ++ m ++ v, each
    /// borrowed straight from the store (step appended by the caller as a
    /// data arg). The store's own vectors ARE the marshalled-literal cache;
    /// [`absorb_update`](ParamStore::absorb_update) and
    /// [`load`](ParamStore::load) replacing them is the invalidation — no
    /// concatenation, no per-step clones
    /// ([`Executable::run_state_groups`](super::Executable::run_state_groups)
    /// chains the groups into one call).
    pub fn opt_groups(&self) -> [&[xla::Literal]; 3] {
        [&self.params, &self.m, &self.v]
    }

    /// Absorb the leading `3n+1` outputs of a train/sft step (new params, m,
    /// v, step); returns the remaining stat tensors.
    pub fn absorb_update(&mut self, outputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let n = self.n();
        anyhow::ensure!(outputs.len() > 3 * n, "train outputs too short: {}", outputs.len());
        let mut it = outputs.into_iter();
        let mut new_params = Vec::with_capacity(n);
        for _ in 0..n {
            new_params.push(it.next().unwrap().to_literal()?);
        }
        let mut new_m = Vec::with_capacity(n);
        for _ in 0..n {
            new_m.push(it.next().unwrap().to_literal()?);
        }
        let mut new_v = Vec::with_capacity(n);
        for _ in 0..n {
            new_v.push(it.next().unwrap().to_literal()?);
        }
        let step_t = it.next().unwrap();
        self.step = step_t.as_i32()?[0];
        self.params = new_params;
        self.m = new_m;
        self.v = new_v;
        Ok(it.collect())
    }

    /// Save a checkpoint: raw f32 params (+ optimizer state) and JSON meta.
    pub fn save(&self, dir: &Path, tag: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let write_group = |name: &str, lits: &[xla::Literal]| -> Result<()> {
            let mut bytes = Vec::new();
            for lit in lits {
                let t = Tensor::from_literal(lit)?;
                for x in t.as_f32()? {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            std::fs::write(dir.join(format!("{tag}.{name}.bin")), bytes)?;
            Ok(())
        };
        write_group("params", &self.params)?;
        write_group("adam_m", &self.m)?;
        write_group("adam_v", &self.v)?;
        let meta = Json::obj(vec![
            ("tag", Json::str(tag)),
            ("step", Json::num(self.step as f64)),
            ("num_tensors", Json::num(self.n() as f64)),
        ]);
        std::fs::write(dir.join(format!("{tag}.meta.json")), meta.to_string_pretty())?;
        Ok(())
    }

    /// Load a checkpoint previously written by [`ParamStore::save`].
    pub fn load(&mut self, dir: &Path, tag: &str) -> Result<()> {
        let read_group = |name: &str| -> Result<Vec<xla::Literal>> {
            let bytes = std::fs::read(dir.join(format!("{tag}.{name}.bin")))?;
            let mut lits = Vec::with_capacity(self.specs.len());
            let mut offset = 0usize;
            for (_, shape) in &self.specs {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = bytes[offset..offset + n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                offset += n * 4;
                lits.push(Tensor::f32(shape.clone(), data).to_literal()?);
            }
            anyhow::ensure!(offset == bytes.len(), "checkpoint group {name} size mismatch");
            Ok(lits)
        };
        self.params = read_group("params")?;
        self.m = read_group("adam_m")?;
        self.v = read_group("adam_v")?;
        let meta = Json::parse_file(&dir.join(format!("{tag}.meta.json")))?;
        self.step = meta.get("step").and_then(|x| x.as_i64()).unwrap_or(0) as i32;
        Ok(())
    }
}
