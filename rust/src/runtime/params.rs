//! Parameter store: model weights + AdamW state, kept as XLA literals so
//! they feed straight into `train_step` / `sft_step` / `rollout` calls.
//!
//! Layout contract: `manifest.param_specs` order, f32 little-endian raw
//! concatenation — the same format `aot.py` uses for `init_params_*.bin`
//! and the checkpoint format used by `save`/`load`.

use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::Manifest;
use super::tensor::Tensor;
use crate::util::json::Json;

pub struct ParamStore {
    /// (name, shape) in manifest order.
    pub specs: Vec<(String, Vec<usize>)>,
    /// Current model parameters, one literal per spec.
    pub params: Vec<xla::Literal>,
    /// AdamW first/second moments.
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    /// Optimizer step counter (bias correction), mirrors the i32 the graphs
    /// take/return.
    pub step: i32,
}

fn zeros_like(specs: &[(String, Vec<usize>)]) -> Result<Vec<xla::Literal>> {
    specs
        .iter()
        .map(|(_, shape)| Tensor::zeros_f32(shape.clone()).to_literal())
        .collect()
}

/// FNV-1a over a byte buffer — the cheap content fingerprint the
/// checkpoint meta records per group so a mixed-generation (torn) set of
/// files is detected at load.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

impl ParamStore {
    /// Load initial parameters from the raw f32 file `aot.py` exported.
    pub fn from_init_file(manifest: &Manifest) -> Result<ParamStore> {
        let path = manifest.dir.join(&manifest.init_params_file);
        Self::from_raw_file(manifest, &path)
    }

    pub fn from_raw_file(manifest: &Manifest, path: &Path) -> Result<ParamStore> {
        let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        let expect = manifest.param_numel() * 4;
        anyhow::ensure!(
            bytes.len() == expect,
            "param file {} is {} bytes, expected {}",
            path.display(),
            bytes.len(),
            expect
        );
        let mut params = Vec::with_capacity(manifest.param_specs.len());
        let mut offset = 0usize;
        for (_, shape) in &manifest.param_specs {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = bytes[offset..offset + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            offset += n * 4;
            params.push(Tensor::f32(shape.clone(), data).to_literal()?);
        }
        Ok(ParamStore {
            specs: manifest.param_specs.clone(),
            m: zeros_like(&manifest.param_specs)?,
            v: zeros_like(&manifest.param_specs)?,
            params,
            step: 0,
        })
    }

    pub fn n(&self) -> usize {
        self.specs.len()
    }

    /// Literals for a rollout/forward call: params only, borrowed straight
    /// from the store (no per-call clones — the marshalled sequence is the
    /// store itself).
    pub fn param_literals(&self) -> &[xla::Literal] {
        &self.params
    }

    /// State literal groups for a train/sft call: params ++ m ++ v, each
    /// borrowed straight from the store (step appended by the caller as a
    /// data arg). The store's own vectors ARE the marshalled-literal cache;
    /// [`absorb_update`](ParamStore::absorb_update) and
    /// [`load`](ParamStore::load) replacing them is the invalidation — no
    /// concatenation, no per-step clones
    /// ([`Executable::run_state_groups`](super::Executable::run_state_groups)
    /// chains the groups into one call).
    pub fn opt_groups(&self) -> [&[xla::Literal]; 3] {
        [&self.params, &self.m, &self.v]
    }

    /// Absorb the leading `3n+1` outputs of a train/sft step (new params, m,
    /// v, step); returns the remaining stat tensors.
    pub fn absorb_update(&mut self, outputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let n = self.n();
        anyhow::ensure!(outputs.len() > 3 * n, "train outputs too short: {}", outputs.len());
        let mut it = outputs.into_iter();
        let mut new_params = Vec::with_capacity(n);
        for _ in 0..n {
            new_params.push(it.next().unwrap().to_literal()?);
        }
        let mut new_m = Vec::with_capacity(n);
        for _ in 0..n {
            new_m.push(it.next().unwrap().to_literal()?);
        }
        let mut new_v = Vec::with_capacity(n);
        for _ in 0..n {
            new_v.push(it.next().unwrap().to_literal()?);
        }
        let step_t = it.next().unwrap();
        self.step = step_t.as_i32()?[0];
        self.params = new_params;
        self.m = new_m;
        self.v = new_v;
        Ok(it.collect())
    }

    /// Save a checkpoint: raw f32 params (+ optimizer state) and versioned
    /// JSON meta. Warm-resume run state (difficulty posteriors, feature
    /// model, run progress) lives in a sidecar next to these files — see
    /// `crate::checkpoint::RunState`.
    pub fn save(&self, dir: &Path, tag: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        // Temp-file + rename per file: periodic saves reuse one tag, and
        // an in-place rewrite would clobber the only good checkpoint if
        // the process died mid-write. The meta goes LAST and carries each
        // group's checksum, so a crash between group renames (a
        // mixed-generation set on disk) is detected at load instead of
        // silently training on torn state.
        let mut checksums = Vec::new();
        let mut write_group = |name: &'static str, lits: &[xla::Literal]| -> Result<()> {
            let mut bytes = Vec::new();
            for lit in lits {
                let t = Tensor::from_literal(lit)?;
                for x in t.as_f32()? {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            checksums.push((name, crate::checkpoint::ju64(fnv1a(&bytes))));
            crate::checkpoint::atomic_write(&dir.join(format!("{tag}.{name}.bin")), &bytes)
        };
        write_group("params", &self.params)?;
        write_group("adam_m", &self.m)?;
        write_group("adam_v", &self.v)?;
        let numel: usize = self.specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        let meta = Json::obj(vec![
            ("format_version", Json::num(1.0)),
            ("tag", Json::str(tag)),
            ("step", Json::num(self.step as f64)),
            ("num_tensors", Json::num(self.n() as f64)),
            ("numel", Json::num(numel as f64)),
            ("checksums", Json::obj(checksums)),
        ]);
        crate::checkpoint::atomic_write(
            &dir.join(format!("{tag}.meta.json")),
            meta.to_string_pretty().as_bytes(),
        )
    }

    /// Load a checkpoint previously written by [`ParamStore::save`].
    ///
    /// Sizes are validated up front: a truncated or wrong-model group file
    /// is a loud error naming file and byte counts, not a slice panic
    /// halfway through deserialization (the bug any resume work trips on
    /// first).
    pub fn load(&mut self, dir: &Path, tag: &str) -> Result<()> {
        let expect: usize = self.specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        let read_group = |name: &str| -> Result<(Vec<xla::Literal>, u64)> {
            let path = dir.join(format!("{tag}.{name}.bin"));
            let bytes =
                std::fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            anyhow::ensure!(
                bytes.len() == expect * 4,
                "checkpoint group {} is {} bytes, expected {} ({} f32s) — truncated file or \
                 checkpoint from a different model shape",
                path.display(),
                bytes.len(),
                expect * 4,
                expect
            );
            let mut lits = Vec::with_capacity(self.specs.len());
            let mut offset = 0usize;
            for (_, shape) in &self.specs {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = bytes[offset..offset + n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                offset += n * 4;
                lits.push(Tensor::f32(shape.clone(), data).to_literal()?);
            }
            Ok((lits, fnv1a(&bytes)))
        };
        let (params, h_params) = read_group("params")?;
        let (m, h_m) = read_group("adam_m")?;
        let (v, h_v) = read_group("adam_v")?;
        let meta = Json::parse_file(&dir.join(format!("{tag}.meta.json")))?;
        // Cross-file consistency: each group must hash to what the meta
        // (written last) recorded — a crash between group renames leaves a
        // mixed-generation set that must fail here, not train silently.
        // Absent checksums = pre-versioning checkpoint, accepted as-is.
        if let Some(sums) = meta.get("checksums") {
            for (name, have) in [("params", h_params), ("adam_m", h_m), ("adam_v", h_v)] {
                if let Some(want) = sums.get(name) {
                    let want = crate::checkpoint::pu64(want)
                        .with_context(|| format!("checkpoint {tag} meta checksum {name}"))?;
                    anyhow::ensure!(
                        want == have,
                        "checkpoint {tag} group {name} does not match its meta checksum — \
                         torn checkpoint (crash mid-save?); restore from an older tag"
                    );
                }
            }
        }
        // Absent = pre-versioning checkpoints (still layout-compatible);
        // anything other than v1 is a loud incompatibility.
        if let Some(v) = meta.get("format_version").and_then(|x| x.as_usize()) {
            anyhow::ensure!(
                v == 1,
                "param checkpoint {tag} has format v{v}; this binary reads v1 — \
                 checkpoint from an incompatible version"
            );
        }
        if let Some(n) = meta.get("num_tensors").and_then(|x| x.as_usize()) {
            anyhow::ensure!(
                n == self.n(),
                "checkpoint {tag} holds {n} tensors, this model has {} — wrong artifacts?",
                self.n()
            );
        }
        // All groups validated: only now replace the store's state, so a
        // failed load leaves the previous parameters intact.
        self.params = params;
        self.m = m;
        self.v = v;
        self.step = meta.get("step").and_then(|x| x.as_i64()).unwrap_or(0) as i32;
        Ok(())
    }
}
