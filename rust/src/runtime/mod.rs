//! PJRT runtime: loads the AOT artifacts `python/compile/aot.py` produced
//! and executes them from the L3 hot path.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are compiled once and
//! cached; the parameter store keeps model + Adam state as literals that
//! flow straight back in on the next step.

pub mod artifacts;
pub mod exec;
pub mod params;
pub mod tensor;

pub use artifacts::{ArgSig, ArtifactSig, DType, Manifest};
pub use exec::{Executable, Runtime};
pub use params::ParamStore;
pub use tensor::Tensor;
