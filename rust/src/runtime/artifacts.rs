//! Artifact manifest: the typed contract between `python/compile/aot.py`
//! and the Rust runtime (argument order, shapes, dtypes, param layout).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element dtype in the manifest ("f32" / "i32" / "u32").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unknown dtype '{other}' in manifest"),
        })
    }
}

/// One argument or output of an artifact.
#[derive(Clone, Debug)]
pub struct ArgSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<ArgSig> {
        Ok(ArgSig {
            name: j.get("name").and_then(|x| x.as_str()).context("arg name")?.to_string(),
            shape: j.get("shape").and_then(|x| x.as_usize_vec()).context("arg shape")?,
            dtype: DType::parse(j.get("dtype").and_then(|x| x.as_str()).context("arg dtype")?)?,
        })
    }
}

/// One compiled entrypoint (a `.hlo.txt` file plus its signature).
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSig>,
    pub outputs: Vec<ArgSig>,
    pub meta: BTreeMap<String, f64>,
}

/// Model hyper-parameters recorded by the compile path.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub vocab_size: usize,
    pub num_params: usize,
}

/// Shape plan the artifacts were compiled for.
#[derive(Clone, Debug)]
pub struct Plan {
    pub rollout_rows: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub train_rows: usize,
    pub sft_rows: usize,
    /// Additional smaller rollout row-counts compiled alongside the
    /// primary one (perf: lightly-filled calls pick the smallest fit).
    pub rollout_variants: Vec<usize>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    pub model: ModelMeta,
    pub vocab: Vec<String>,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub param_specs: Vec<(String, Vec<usize>)>,
    pub init_params_file: String,
    pub plan: Plan,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

fn req_usize(j: &Json, path: &str) -> Result<usize> {
    j.path(path).and_then(|x| x.as_usize()).with_context(|| format!("manifest field {path}"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Manifest> {
        let model = ModelMeta {
            d_model: req_usize(j, "model.d_model")?,
            n_layers: req_usize(j, "model.n_layers")?,
            n_heads: req_usize(j, "model.n_heads")?,
            d_ff: req_usize(j, "model.d_ff")?,
            max_seq: req_usize(j, "model.max_seq")?,
            vocab_size: req_usize(j, "model.vocab_size")?,
            num_params: req_usize(j, "model.num_params")?,
        };
        let plan = Plan {
            rollout_rows: req_usize(j, "plan.rollout_rows")?,
            prompt_len: req_usize(j, "plan.prompt_len")?,
            gen_len: req_usize(j, "plan.gen_len")?,
            train_rows: req_usize(j, "plan.train_rows")?,
            sft_rows: req_usize(j, "plan.sft_rows")?,
            rollout_variants: j
                .path("plan.rollout_variants")
                .and_then(|x| x.as_usize_vec())
                .unwrap_or_default(),
        };
        let vocab: Vec<String> = j
            .path("vocab")
            .and_then(|x| x.as_arr())
            .context("manifest vocab")?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        let param_specs = j
            .path("param_specs")
            .and_then(|x| x.as_arr())
            .context("manifest param_specs")?
            .iter()
            .map(|p| -> Result<(String, Vec<usize>)> {
                Ok((
                    p.get("name").and_then(|x| x.as_str()).context("param name")?.to_string(),
                    p.get("shape").and_then(|x| x.as_usize_vec()).context("param shape")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        for (name, art) in j.path("artifacts").and_then(|x| x.as_obj()).context("artifacts")? {
            let args = art
                .get("args")
                .and_then(|x| x.as_arr())
                .context("artifact args")?
                .iter()
                .map(ArgSig::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = art
                .get("outputs")
                .and_then(|x| x.as_arr())
                .context("artifact outputs")?
                .iter()
                .map(ArgSig::parse)
                .collect::<Result<Vec<_>>>()?;
            let mut meta = BTreeMap::new();
            if let Some(m) = art.get("meta").and_then(|x| x.as_obj()) {
                for (k, v) in m {
                    if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    name: name.clone(),
                    file: art.get("file").and_then(|x| x.as_str()).context("artifact file")?.to_string(),
                    args,
                    outputs,
                    meta,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            preset: j.path("preset").and_then(|x| x.as_str()).context("preset")?.to_string(),
            model,
            vocab,
            pad: j.path("special.pad").and_then(|x| x.as_i64()).context("pad")? as i32,
            bos: j.path("special.bos").and_then(|x| x.as_i64()).context("bos")? as i32,
            eos: j.path("special.eos").and_then(|x| x.as_i64()).context("eos")? as i32,
            param_specs,
            init_params_file: j
                .path("init_params_file")
                .and_then(|x| x.as_str())
                .context("init_params_file")?
                .to_string(),
            plan,
            artifacts,
        })
    }

    /// Find the unique artifact whose name starts with `prefix`.
    pub fn artifact_by_prefix(&self, prefix: &str) -> Result<&ArtifactSig> {
        let mut matches = self.artifacts.values().filter(|a| a.name.starts_with(prefix));
        let first = matches.next().with_context(|| format!("no artifact named {prefix}*"))?;
        if matches.next().is_some() {
            bail!("ambiguous artifact prefix {prefix}");
        }
        Ok(first)
    }

    /// All rollout artifact row-counts, ascending (variants + primary).
    pub fn rollout_row_options(&self) -> Vec<usize> {
        let mut rows: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.name.starts_with("rollout"))
            .filter_map(|a| a.meta.get("rows").map(|&r| r as usize))
            .collect();
        rows.sort_unstable();
        rows.dedup();
        rows
    }

    /// The rollout artifact compiled for exactly `rows` rows.
    pub fn rollout_artifact_for(&self, rows: usize) -> Result<&ArtifactSig> {
        self.artifacts
            .values()
            .find(|a| {
                a.name.starts_with("rollout")
                    && a.meta.get("rows").map(|&r| r as usize) == Some(rows)
            })
            .with_context(|| format!("no rollout artifact with {rows} rows"))
    }

    /// Total number of parameter scalars (must match init file size / 4).
    pub fn param_numel(&self) -> usize {
        self.param_specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> Json {
        Json::parse(
            r#"{
            "preset": "nano",
            "model": {"d_model": 64, "n_layers": 2, "n_heads": 2, "d_ff": 256,
                      "max_seq": 96, "vocab_size": 32, "num_params": 10},
            "vocab": ["<pad>", "<bos>", "<eos>", "0"],
            "special": {"pad": 0, "bos": 1, "eos": 2},
            "param_specs": [{"name": "embed", "shape": [32, 64]},
                            {"name": "pos", "shape": [96, 64]}],
            "init_params_file": "init_params_nano.bin",
            "plan": {"rollout_rows": 64, "prompt_len": 24, "gen_len": 24,
                     "train_rows": 64, "sft_rows": 64},
            "artifacts": {
                "rollout_r64": {
                    "file": "rollout_r64.hlo.txt",
                    "args": [{"name": "x", "shape": [64, 24], "dtype": "i32"}],
                    "outputs": [{"name": "y", "shape": [64, 24], "dtype": "i32"}],
                    "meta": {"rows": 64}
                }
            }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(Path::new("/tmp"), &mini_manifest_json()).unwrap();
        assert_eq!(m.preset, "nano");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.param_specs.len(), 2);
        assert_eq!(m.param_numel(), 32 * 64 + 96 * 64);
        let art = m.artifact_by_prefix("rollout").unwrap();
        assert_eq!(art.args[0].dtype, DType::I32);
        assert_eq!(art.meta["rows"], 64.0);
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"preset": "x"}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }
}
