//! Host-side tensors: the boundary type between L3 data structures and XLA
//! literals. Only the three dtypes the artifact interface uses.

use anyhow::{bail, Context, Result};

/// Element type of an artifact argument/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DTypeKind {
    F32,
    I32,
    U32,
}

/// A dense host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Tensor::I32 { shape, data }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Tensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Tensor::U32 { shape, data }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor::I32 { shape: vec![], data: vec![x] }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = numel(&shape);
        Tensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::U32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        numel(self.shape())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn kind(&self) -> DTypeKind {
        match self {
            Tensor::F32 { .. } => DTypeKind::F32,
            Tensor::I32 { .. } => DTypeKind::I32,
            Tensor::U32 { .. } => DTypeKind::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Tensor::U32 { data, .. } => Ok(data),
            _ => bail!("tensor is not u32"),
        }
    }

    pub fn scalar(&self) -> Result<f64> {
        anyhow::ensure!(self.len() == 1, "not a scalar: shape {:?}", self.shape());
        Ok(match self {
            Tensor::F32 { data, .. } => data[0] as f64,
            Tensor::I32 { data, .. } => data[0] as f64,
            Tensor::U32 { data, .. } => data[0] as f64,
        })
    }

    /// Convert to an XLA literal (host copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
            Tensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims).context("reshape literal")?)
    }

    /// Read an XLA literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let t = match shape.ty() {
            xla::ElementType::F32 => Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? },
            xla::ElementType::S32 => Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? },
            xla::ElementType::U32 => Tensor::U32 { shape: dims, data: lit.to_vec::<u32>()? },
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_consistency() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.kind(), DTypeKind::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatched_lengths_panic() {
        Tensor::i32(vec![2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn scalar_accessor() {
        assert_eq!(Tensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert!(Tensor::zeros_f32(vec![2]).scalar().is_err());
    }
}
