//! RealPolicy: the AOT-compiled transformer behind the
//! `RolloutEngine`/`Trainable` traits.
//!
//! Everything on the request path is Rust + PJRT: generation runs the
//! `rollout_*` artifact (prefill + Pallas-decode scan compiled from L2),
//! verification is the Rust verifier, updates run the `train_*` artifact
//! (clipped PG + AdamW compiled from L2), and parameters/optimizer state
//! cycle through [`ParamStore`] literals without ever touching Python.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::tasks::TaskInstance;
use crate::data::tokenizer::Tokenizer;
use crate::data::verifier::{verify, VerifyOutcome};
use crate::policy::sampler::pack_requests;
use crate::policy::{
    EvalResult, GenRequest, GenResult, RolloutEngine, TrainResult, Trainable, WeightSnapshot,
};
use crate::rl::algo::AlgoConfig;
use crate::rl::update::{PromptGroup, Rollout, TrainBatch};
use crate::runtime::{ParamStore, Runtime, Tensor};
use crate::util::rng::Rng;

pub struct RealPolicy {
    pub runtime: Runtime,
    pub store: ParamStore,
    pub tok: Tokenizer,
    rng: Rng,
    label: String,
    /// Cumulative SFT steps (warmup phase).
    pub sft_steps: usize,
    /// Weight version: bumped by every RL update.
    version: u64,
}

impl RealPolicy {
    /// Load artifacts + init params from `dir` (see `make artifacts`).
    pub fn load(dir: &std::path::Path, seed: u64) -> Result<RealPolicy> {
        let runtime = Runtime::load(dir)?;
        let tok = Tokenizer::new();
        tok.validate_against(&runtime.manifest.vocab)
            .context("tokenizer/manifest vocab mismatch — rebuild artifacts")?;
        let store = ParamStore::from_init_file(&runtime.manifest)?;
        let label = format!("real-{}", runtime.manifest.preset);
        Ok(RealPolicy {
            runtime,
            store,
            tok,
            rng: Rng::new(seed ^ 0x6ea1),
            label,
            sft_steps: 0,
            version: 0,
        })
    }

    /// Load from a saved checkpoint instead of init params.
    pub fn load_checkpoint(dir: &std::path::Path, ckpt_dir: &std::path::Path, tag: &str, seed: u64) -> Result<RealPolicy> {
        let mut p = Self::load(dir, seed)?;
        p.store.load(ckpt_dir, tag)?;
        Ok(p)
    }

    fn plan(&self) -> &crate::runtime::artifacts::Plan {
        &self.runtime.manifest.plan
    }

    /// Pick the smallest compiled rollout variant that fits `rows_needed`
    /// (§Perf: lightly-filled calls stop paying full-batch decode compute).
    fn rollout_rows_for(&self, rows_needed: usize) -> usize {
        self.runtime
            .manifest
            .rollout_row_options()
            .into_iter()
            .find(|&r| r >= rows_needed)
            .unwrap_or(self.plan().rollout_rows)
    }

    /// Run one batched rollout call; returns per-request rollouts with
    /// verified rewards.
    fn rollout_call(
        &mut self,
        requests: &[GenRequest],
        temperature: f32,
    ) -> Result<(Vec<Vec<Rollout>>, f64, usize)> {
        let plan = self.plan().clone();
        let rows_needed: usize = requests.iter().map(|r| r.n_samples).sum();
        let rows = self.rollout_rows_for(rows_needed);
        let packed = pack_requests(&self.tok, requests, rows, plan.prompt_len)?;
        let art_name = self.runtime.manifest.rollout_artifact_for(rows)?.name.clone();
        let exe = self.runtime.executable(&art_name)?;
        let key = self.rng.jax_key();
        let t0 = Instant::now();
        let out = exe.run_state_and_data(
            self.store.param_literals(),
            &[
                Tensor::i32(vec![rows, plan.prompt_len], packed.tokens),
                Tensor::i32(vec![rows], packed.lens),
                Tensor::u32(vec![2], key.to_vec()),
                Tensor::scalar_f32(temperature),
            ],
        )?;
        let cost_s = t0.elapsed().as_secs_f64();
        let gen_tokens = out[0].as_i32()?;
        let gen_logprobs = out[1].as_f32()?;
        let g = plan.gen_len;
        let mut groups = Vec::with_capacity(requests.len());
        let mut row = 0usize;
        for req in requests {
            let mut rollouts = Vec::with_capacity(req.n_samples);
            for _ in 0..req.n_samples {
                let toks = gen_tokens[row * g..(row + 1) * g].to_vec();
                let lps = gen_logprobs[row * g..(row + 1) * g].to_vec();
                let outcome = verify(&self.tok, &req.task, &toks);
                rollouts.push(Rollout {
                    gen_tokens: toks,
                    gen_logprobs: lps,
                    reward: outcome.reward(),
                });
                row += 1;
            }
            groups.push(rollouts);
        }
        Ok((groups, cost_s, packed.rows_used))
    }

    /// Supervised warmup step on (prompt, answer) pairs — the "base model"
    /// phase standing in for Qwen pretraining (DESIGN.md §3).
    pub fn sft_step(&mut self, examples: &[TaskInstance], lr: f64) -> Result<f64> {
        let plan = self.plan().clone();
        let rows = plan.sft_rows;
        let t = plan.prompt_len + plan.gen_len;
        anyhow::ensure!(examples.len() <= rows, "sft batch too large");
        let mut tokens = vec![0i32; rows * t];
        let mut mask = vec![0f32; rows * t];
        for (r, ex) in examples.iter().enumerate() {
            let prompt = self.tok.encode(&ex.prompt)?;
            let mut answer = self.tok.encode(&ex.answer_text())?;
            answer.push(crate::data::tokenizer::EOS);
            anyhow::ensure!(prompt.len() + answer.len() <= t, "sft row overflow");
            let base = r * t;
            tokens[base..base + prompt.len()].copy_from_slice(&prompt);
            let abase = base + prompt.len();
            tokens[abase..abase + answer.len()].copy_from_slice(&answer);
            for j in 0..answer.len() {
                mask[abase + j] = 1.0;
            }
        }
        let exe = self.runtime.executable_by_prefix("sft")?;
        let data = [
            Tensor::scalar_i32(self.store.step),
            Tensor::i32(vec![rows, t], tokens),
            Tensor::f32(vec![rows, t], mask),
            Tensor::scalar_f32(lr as f32),
            Tensor::scalar_f32(0.0), // no weight decay in warmup
            Tensor::scalar_f32(1.0),
        ];
        let out = exe.run_state_groups(&self.store.opt_groups(), &data)?;
        let stats = self.store.absorb_update(out)?;
        self.sft_steps += 1;
        stats[0].scalar()
    }
}

impl RolloutEngine for RealPolicy {
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult> {
        let (groups, cost_s, rows_used) = self.rollout_call(requests, temperature)?;
        Ok(GenResult { groups, cost_s, rows_used, weight_version: self.version })
    }

    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult> {
        let plan = self.plan().clone();
        let rows = plan.rollout_rows;
        let mut correct = 0usize;
        let mut cost_s = 0.0;
        for chunk in tasks.chunks(rows) {
            let requests: Vec<GenRequest> = chunk
                .iter()
                .enumerate()
                .map(|(i, task)| GenRequest { prompt_idx: i, task: task.clone(), n_samples: 1 })
                .collect();
            let (groups, c, _) = self.rollout_call(&requests, 0.0)?; // greedy
            cost_s += c;
            for (task, rollouts) in chunk.iter().zip(&groups) {
                if verify(&self.tok, task, &rollouts[0].gen_tokens) == VerifyOutcome::Correct {
                    correct += 1;
                }
            }
        }
        Ok(EvalResult { accuracy: correct as f64 / tasks.len().max(1) as f64, cost_s })
    }

    fn rollout_capacity(&self) -> usize {
        self.plan().rollout_rows
    }

    fn gen_len(&self) -> usize {
        self.plan().gen_len
    }

    fn install(&mut self, snap: &WeightSnapshot) {
        // The single PJRT engine shares the device-resident ParamStore with
        // the learner — only the version needs recording.
        self.version = snap.version;
    }

    fn serving_version(&self) -> u64 {
        self.version
    }

    fn name(&self) -> &str {
        &self.label
    }
}

impl Trainable for RealPolicy {
    fn train(&mut self, groups: &[PromptGroup], algo: &AlgoConfig) -> Result<TrainResult> {
        let plan = self.plan().clone();
        let rows = plan.train_rows;
        let t = plan.prompt_len + plan.gen_len;
        let batch = TrainBatch::assemble(
            groups,
            &self.tok,
            algo.estimator(),
            0.0, // global REINFORCE baseline handled by the trainer if used
            rows,
            t,
        )?;
        let (tokens, mask, old_lp, adv) = batch.tensors();
        let exe = self.runtime.executable_by_prefix("train")?;
        let t0 = Instant::now();
        let data = [
            Tensor::scalar_i32(self.store.step),
            tokens,
            mask,
            old_lp,
            adv,
            Tensor::scalar_f32(algo.lr as f32),
            Tensor::scalar_f32(algo.clip_low),
            Tensor::scalar_f32(algo.clip_high),
            Tensor::scalar_f32(algo.weight_decay as f32),
            Tensor::scalar_f32(algo.max_grad_norm as f32),
        ];
        let out = exe.run_state_groups(&self.store.opt_groups(), &data)?;
        let cost_s = t0.elapsed().as_secs_f64();
        let stats = self.store.absorb_update(out)?;
        self.version += 1;
        Ok(TrainResult {
            loss: stats[0].scalar()?,
            grad_norm: stats[1].scalar()?,
            clip_frac: stats[2].scalar()?,
            cost_s,
        })
    }

    fn train_capacity(&self) -> usize {
        self.plan().train_rows
    }

    fn weight_version(&self) -> u64 {
        self.version
    }

    fn snapshot(&self) -> WeightSnapshot {
        WeightSnapshot { version: self.version, values: Vec::new() }
    }

    /// Weights/optimizer state live in the [`ParamStore`] raw buffers (see
    /// [`save_params`](Self::save_params)); the sidecar only carries what
    /// those files cannot: the RL weight version, the sampling-RNG stream,
    /// and the SFT step count.
    fn state_json(&self) -> Option<crate::util::json::Json> {
        use crate::util::json::Json;
        Some(Json::obj(vec![
            ("version", crate::checkpoint::ju64(self.version)),
            ("rng", crate::checkpoint::rng_state_to_json(self.rng.state())),
            ("sft_steps", Json::num(self.sft_steps as f64)),
        ]))
    }

    fn restore_state_json(&mut self, state: &crate::util::json::Json) -> Result<()> {
        self.version = state
            .get("version")
            .map(crate::checkpoint::pu64)
            .transpose()?
            .unwrap_or(0);
        if let Some(rng_state) = state.get("rng") {
            self.rng = Rng::from_state(crate::checkpoint::rng_state_from_json(rng_state)?);
        }
        self.sft_steps = state.get("sft_steps").and_then(|x| x.as_usize()).unwrap_or(0);
        Ok(())
    }

    fn save_params(&self, dir: &std::path::Path, tag: &str) -> Result<()> {
        self.store.save(dir, tag)
    }

    fn load_params(&mut self, dir: &std::path::Path, tag: &str) -> Result<()> {
        self.store.load(dir, tag)
    }

    /// The optimizer step is persisted in the `ParamStore` meta and bumps
    /// with every update — the cross-file generation token that ties a
    /// sidecar to the weight files saved with it.
    fn params_token(&self) -> Option<u64> {
        Some(self.store.step as u64)
    }
}
