//! Deterministic fault injection for the engine pool (DESIGN.md §13).
//!
//! A chaos run must be reproducible or it proves nothing: a flaky sleep
//! here and a racy kill there exercise *some* recovery path on every run
//! but never the same one twice, so a regression can hide behind a lucky
//! schedule. This module scripts faults instead: a [`FaultPlan`] names
//! exact (replica, call-index) coordinates and a [`FaultyEngine`] wrapper
//! fires them when its own generate-call counter reaches the scripted
//! index — no clocks, no RNG, the same plan hits the same calls every run.
//!
//! Three fault kinds cover the failure taxonomy the service recovers from:
//!
//! * `err`   — a transient generate error (the engine returns `Err` once;
//!   the call counter still advances, so a retry of the same plan sees a
//!   healthy engine — transient by construction).
//! * `stall` — the call sleeps a fixed duration before executing normally,
//!   long enough to trip the scheduler's execute watchdog in chaos tests.
//! * `die`   — a panic mid-call: the hard replica death whose containment
//!   (catch_unwind → quarantine → redispatch) the harness gates.
//!
//! [`RecoveryConfig`] bundles the plan with the recovery knobs (bounded
//! retry, watchdog timeout, respawn) handed to
//! `InferenceService::spawn_pool_with_recovery`. An inactive config (the
//! plain spawn paths) disables every new code path, preserving the
//! no-faults bit-for-bit equivalence rail.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::data::tasks::TaskInstance;
use crate::policy::{EvalResult, GenRequest, GenResult, RolloutEngine, WeightSnapshot};

/// The fault-plan grammar, quoted by every parse error so a bad spec is
/// self-documenting (the `--curriculum`/`--metric` error convention).
pub const FAULT_GRAMMAR: &str =
    "kind@replica:call[:millis], comma-separated, e.g. \"err@0:2,stall@1:3:400,die@2:4\"; \
     'none' = no faults";

/// One scripted fault behaviour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Return a transient error from this generate call.
    Transient,
    /// Sleep this many milliseconds, then execute the call normally.
    Stall(u64),
    /// Panic mid-call (hard replica death).
    Die,
}

impl FaultKind {
    fn name(&self) -> &'static str {
        match self {
            FaultKind::Transient => "err",
            FaultKind::Stall(_) => "stall",
            FaultKind::Die => "die",
        }
    }
}

/// One scripted fault: `kind` fires on replica `replica`'s `call`-th
/// generate call (0-based; retries advance the counter too).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub replica: usize,
    pub call: u64,
    pub kind: FaultKind,
}

/// A parsed `--fault-plan`: the full chaos script for one run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse the `--fault-plan` spec ([`FAULT_GRAMMAR`]). `""` and `none`
    /// are the explicit empty plan — the chaos harness with nothing
    /// scheduled, which must behave byte-for-byte like no harness at all.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::default());
        }
        let mut events = Vec::new();
        for ev in spec.split(',') {
            let ev = ev.trim();
            let Some((kind, coords)) = ev.split_once('@') else {
                bail!("malformed fault event '{ev}' (grammar: {FAULT_GRAMMAR})");
            };
            let parts: Vec<&str> = coords.split(':').collect();
            let n_coords = match kind {
                "err" | "die" => 2,
                "stall" => 3,
                other => bail!(
                    "unknown fault kind '{other}' in '{ev}' (valid kinds: err, stall, die; \
                     grammar: {FAULT_GRAMMAR})"
                ),
            };
            if parts.len() != n_coords {
                bail!(
                    "fault event '{ev}' takes {n_coords} coordinates after '@', got {} \
                     (grammar: {FAULT_GRAMMAR})",
                    parts.len()
                );
            }
            let coord = |i: usize, what: &str| -> Result<u64> {
                match parts.get(i).and_then(|p| p.parse::<u64>().ok()) {
                    Some(v) => Ok(v),
                    None => bail!("bad {what} in fault event '{ev}' (grammar: {FAULT_GRAMMAR})"),
                }
            };
            let kind = match kind {
                "err" => FaultKind::Transient,
                "stall" => FaultKind::Stall(coord(2, "stall millis")?),
                _ => FaultKind::Die,
            };
            let event =
                FaultEvent { replica: coord(0, "replica index")? as usize, call: coord(1, "call index")?, kind };
            if events.iter().any(|e: &FaultEvent| e.replica == event.replica && e.call == event.call)
            {
                bail!(
                    "duplicate fault at replica {} call {} in '{spec}' — one fault per \
                     (replica, call) coordinate",
                    event.replica,
                    event.call
                );
            }
            events.push(event);
        }
        Ok(FaultPlan { events })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Highest replica index the plan names (for config validation against
    /// the actual pool size).
    pub fn max_replica(&self) -> Option<usize> {
        self.events.iter().map(|e| e.replica).max()
    }

    /// The scripted faults for one replica, sorted by call index — what a
    /// [`FaultyEngine`] wrapping that replica consumes.
    pub fn for_replica(&self, replica: usize) -> Vec<(u64, FaultKind)> {
        let mut faults: Vec<(u64, FaultKind)> = self
            .events
            .iter()
            .filter(|e| e.replica == replica)
            .map(|e| (e.call, e.kind))
            .collect();
        faults.sort_by_key(|(call, _)| *call);
        faults
    }

    /// Render back to the spec grammar (config/CLI echo in diagnostics).
    pub fn to_spec(&self) -> String {
        if self.events.is_empty() {
            return "none".into();
        }
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Stall(ms) => format!("stall@{}:{}:{ms}", e.replica, e.call),
                kind => format!("{}@{}:{}", kind.name(), e.replica, e.call),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Recovery knobs for a fault-tolerant pool spawn
/// (`InferenceService::spawn_pool_with_recovery`).
///
/// [`RecoveryConfig::inactive`] — what the plain spawn paths pass —
/// disables every recovery code path; the service then runs the exact
/// pre-fault state machine (the equivalence rail). The `Default` is the
/// recovery-enabled baseline the driver starts from when any fault knob is
/// set: bounded retry on, watchdog and respawn opt-in.
#[derive(Clone, Debug)]
pub struct RecoveryConfig {
    /// Retries per plan after a failed execute (0 = fail straight through
    /// to the tickets, the pre-fault behaviour).
    pub retry_max: u32,
    /// Backoff before the first retry, doubling per attempt.
    pub retry_backoff_ms: u64,
    /// Execute watchdog: a replica whose call runs longer than this is
    /// quarantined and its plans redispatched (0 = no watchdog).
    pub exec_timeout_ms: u64,
    /// Re-fork a quarantined replica from a pre-forked spare engine,
    /// restoring pool capacity E after a death instead of degrading.
    pub respawn: bool,
    /// The scripted chaos plan (empty = no injected faults).
    pub fault_plan: FaultPlan,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            retry_max: 2,
            retry_backoff_ms: 1,
            exec_timeout_ms: 0,
            respawn: false,
            fault_plan: FaultPlan::default(),
        }
    }
}

impl RecoveryConfig {
    /// The no-recovery config: every fault path disabled
    /// ([`active`](Self::active) = false). The plain `spawn`/`spawn_pool`
    /// entry points use this, so existing callers get the pre-fault
    /// service verbatim.
    pub fn inactive() -> RecoveryConfig {
        RecoveryConfig {
            retry_max: 0,
            retry_backoff_ms: 0,
            exec_timeout_ms: 0,
            respawn: false,
            fault_plan: FaultPlan::default(),
        }
    }

    /// Whether any recovery machinery is armed. Inactive configs must not
    /// perturb the service at all — the no-faults equivalence rail.
    pub fn active(&self) -> bool {
        self.retry_max > 0
            || self.exec_timeout_ms > 0
            || self.respawn
            || !self.fault_plan.is_empty()
    }
}

/// A seeded chaos wrapper over any [`RolloutEngine`]: fires the scripted
/// faults of one replica's [`FaultPlan`] slice at exact generate-call
/// indices, delegating everything else to the wrapped engine.
pub struct FaultyEngine {
    inner: Box<dyn RolloutEngine + Send>,
    /// (call index, fault), sorted by call index.
    faults: Vec<(u64, FaultKind)>,
    /// Generate calls served so far — the script clock. Advances on every
    /// call including faulted ones, so a retried plan replays against the
    /// *next* index, making `err` transient by construction.
    call: u64,
}

impl FaultyEngine {
    /// Wrap `inner` with `plan`'s faults for `replica`. A replica the plan
    /// never names gets its engine back unwrapped — the no-fault replicas
    /// of a chaos run carry zero overhead and identical dynamic types.
    pub fn wrap(
        inner: Box<dyn RolloutEngine + Send>,
        replica: usize,
        plan: &FaultPlan,
    ) -> Box<dyn RolloutEngine + Send> {
        let faults = plan.for_replica(replica);
        if faults.is_empty() {
            return inner;
        }
        Box::new(FaultyEngine { inner, faults, call: 0 })
    }
}

impl RolloutEngine for FaultyEngine {
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult> {
        let idx = self.call;
        self.call += 1;
        match self.faults.iter().find(|(call, _)| *call == idx).map(|(_, kind)| *kind) {
            Some(FaultKind::Transient) => {
                bail!("injected transient fault at call {idx}")
            }
            Some(FaultKind::Stall(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.generate(requests, temperature)
            }
            Some(FaultKind::Die) => panic!("injected replica death at call {idx}"),
            None => self.inner.generate(requests, temperature),
        }
    }

    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult> {
        self.inner.evaluate(tasks)
    }

    fn rollout_capacity(&self) -> usize {
        self.inner.rollout_capacity()
    }

    fn gen_len(&self) -> usize {
        self.inner.gen_len()
    }

    fn install(&mut self, snap: &WeightSnapshot) {
        self.inner.install(snap)
    }

    fn serving_version(&self) -> u64 {
        self.inner.serving_version()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::update::Rollout;

    #[test]
    fn parse_roundtrips_all_kinds() {
        let plan = FaultPlan::parse("err@0:2,stall@1:3:400,die@2:4").unwrap();
        assert_eq!(plan.events.len(), 3);
        assert_eq!(
            plan.events[0],
            FaultEvent { replica: 0, call: 2, kind: FaultKind::Transient }
        );
        assert_eq!(
            plan.events[1],
            FaultEvent { replica: 1, call: 3, kind: FaultKind::Stall(400) }
        );
        assert_eq!(plan.events[2], FaultEvent { replica: 2, call: 4, kind: FaultKind::Die });
        assert_eq!(plan.max_replica(), Some(2));
        assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan);
        // Whitespace between events is tolerated.
        assert_eq!(FaultPlan::parse(" err@0:2 , die@1:0 ").unwrap().events.len(), 2);
    }

    #[test]
    fn empty_and_none_parse_to_the_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        assert_eq!(FaultPlan::parse("none").unwrap().to_spec(), "none");
        assert_eq!(FaultPlan::default().max_replica(), None);
    }

    #[test]
    fn parse_errors_name_the_kinds_and_grammar() {
        let err = FaultPlan::parse("explode@0:1").unwrap_err().to_string();
        assert!(err.contains("unknown fault kind 'explode'"), "{err}");
        assert!(err.contains("err, stall, die"), "{err}");
        assert!(err.contains("kind@replica:call[:millis]"), "{err}");
        // Structural failures quote the grammar too.
        let err = FaultPlan::parse("err0:1").unwrap_err().to_string();
        assert!(err.contains("malformed") && err.contains("kind@replica:call"), "{err}");
        // stall without a duration, err with one: both arity errors.
        assert!(FaultPlan::parse("stall@0:1").unwrap_err().to_string().contains("3 coordinates"));
        assert!(FaultPlan::parse("err@0:1:5").unwrap_err().to_string().contains("2 coordinates"));
        // Non-numeric coordinates.
        assert!(FaultPlan::parse("err@x:1").unwrap_err().to_string().contains("replica index"));
        // Duplicate coordinates would make the script ambiguous.
        let err = FaultPlan::parse("err@0:1,die@0:1").unwrap_err().to_string();
        assert!(err.contains("duplicate fault"), "{err}");
    }

    #[test]
    fn recovery_config_activity() {
        assert!(!RecoveryConfig::inactive().active());
        assert!(RecoveryConfig::default().active()); // bounded retry armed
        let mut r = RecoveryConfig::inactive();
        r.exec_timeout_ms = 50;
        assert!(r.active());
        let mut r = RecoveryConfig::inactive();
        r.fault_plan = FaultPlan::parse("die@0:0").unwrap();
        assert!(r.active());
    }

    /// Minimal deterministic engine for exercising the wrapper.
    struct OkEngine {
        calls: u64,
    }

    impl RolloutEngine for OkEngine {
        fn generate(&mut self, requests: &[GenRequest], _t: f32) -> Result<GenResult> {
            self.calls += 1;
            let groups = requests
                .iter()
                .map(|r| {
                    vec![
                        Rollout { gen_tokens: vec![1], gen_logprobs: vec![-0.1], reward: 1.0 };
                        r.n_samples
                    ]
                })
                .collect();
            Ok(GenResult { groups, cost_s: 1.0, rows_used: 0, weight_version: 0 })
        }

        fn evaluate(&mut self, _tasks: &[TaskInstance]) -> Result<EvalResult> {
            Ok(EvalResult { accuracy: 0.5, cost_s: 0.0 })
        }

        fn rollout_capacity(&self) -> usize {
            64
        }

        fn gen_len(&self) -> usize {
            4
        }

        fn install(&mut self, _snap: &WeightSnapshot) {}

        fn serving_version(&self) -> u64 {
            0
        }

        fn name(&self) -> &str {
            "ok"
        }
    }

    #[test]
    fn faulty_engine_fires_at_exact_call_indices() {
        let plan = FaultPlan::parse("err@3:1").unwrap();
        let mut engine = FaultyEngine::wrap(Box::new(OkEngine { calls: 0 }), 3, &plan);
        assert!(engine.generate(&[], 1.0).is_ok()); // call 0
        let err = engine.generate(&[], 1.0).unwrap_err().to_string(); // call 1
        assert!(err.contains("injected transient fault at call 1"), "{err}");
        // Transient by construction: the very next call succeeds.
        assert!(engine.generate(&[], 1.0).is_ok()); // call 2
    }

    #[test]
    fn unnamed_replicas_are_returned_unwrapped() {
        let plan = FaultPlan::parse("err@0:0").unwrap();
        let mut engine = FaultyEngine::wrap(Box::new(OkEngine { calls: 0 }), 1, &plan);
        // Replica 1 has no scripted faults: the wrapper stepped aside and
        // the original engine serves directly (its name shows through; a
        // FaultyEngine would also answer "ok", so probe behaviour instead).
        for _ in 0..5 {
            assert!(engine.generate(&[], 1.0).is_ok());
        }
        assert_eq!(engine.name(), "ok");
    }

    #[test]
    fn stall_delays_then_serves_and_die_panics() {
        let plan = FaultPlan::parse("stall@0:0:30,die@0:1").unwrap();
        let mut engine = FaultyEngine::wrap(Box::new(OkEngine { calls: 0 }), 0, &plan);
        let t0 = std::time::Instant::now();
        assert!(engine.generate(&[], 1.0).is_ok()); // stalls, then serves
        assert!(t0.elapsed() >= Duration::from_millis(25), "stall did not delay");
        let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = engine.generate(&[], 1.0);
        }));
        assert!(died.is_err(), "die fault must panic");
    }
}
