//! The shared inference service: a pool of E data-parallel engine replicas
//! behind ONE submission queue, coalescing generation requests *across*
//! rollout workers into maximally-packed calls (DESIGN.md §8, §11).
//!
//! The pipelined coordinator's original design forks a private engine per
//! worker, so each of the K workers issues its own lightly-filled
//! fixed-shape calls and installs every weight snapshot K times — exactly
//! the under-utilization SPEED's pre-fetch batcher exists to avoid *within*
//! one worker (paper §4.3). This module applies the same idea one level up:
//!
//! ```text
//!   worker 0 ──submit──┐                           ┌─▶ replica 0 (engine)
//!   worker 1 ──submit──┤   queue    ┌──────────┐   ├─▶ replica 1 (engine)
//!   worker K ──submit──┼──────────▶ │  router  │ ──┤      ...
//!     ...              │ (deadline/ │  thread  │   └─▶ replica E-1
//!   Ticket::wait ◀─fan-out─waterline)└──────────┘   (least-loaded dispatch
//!                                                    + work-stealing)
//! ```
//!
//! * [`SubmitHandle`] — the cheap per-worker handle. It *is* a
//!   [`RolloutEngine`], so workers and curricula run unchanged; `generate`
//!   becomes submit + block on the [`Ticket`]. The advertised
//!   `rollout_capacity` is the submit quantum (capacity x E / K), so K
//!   workers' plans coalesce into full calls that keep E replicas fed.
//! * router — drains the queue; waits up to `coalesce_wait_ms` for the
//!   fill waterline, then merges the leading submissions that fit one
//!   replica's capacity into ONE coalesced plan and packs it onto the
//!   least-loaded replica (by in-flight + queued rollout rows, lowest
//!   index on ties). The deadline guarantees no ticket ever starves
//!   waiting for co-travelers.
//! * replicas — each owns one engine (fork stream r) and executes its
//!   queue FIFO; a drained replica *steals* the oldest plan from the most
//!   backlogged busy peer instead of idling (idle peers pop their own
//!   queues, so routing stays deterministic when only one plan is ever in
//!   flight).
//! * weights — handles dedupe installs by version; the router publishes
//!   each announced snapshot once and every replica installs it lazily
//!   before its next plan (and eagerly while idle), so a replica mid-call
//!   keeps serving its old version but never serves one newer than
//!   announced. Per-replica installed versions are surfaced in
//!   [`ServiceCounters::replica_weight_version`]; the existing buffer
//!   staleness telemetry bounds the lag.
//!
//! * batching modes — the router above is [`BatchingMode::Deadline`] (the
//!   default and the bit-for-bit legacy rail). [`BatchingMode::Slots`]
//!   replaces the micro-batch gather with slot-level continuous batching
//!   (DESIGN.md §14): each leading submission is admitted into a replica
//!   slot the moment the router sees it and retired on completion
//!   (`slot-admit` / `slot-retire` trace instants), while the submit
//!   quantum grows to full engine capacity so every admission already
//!   packs one full call — fill without a staleness-priced gather window.
//!
//! Inference cost is apportioned to tickets by row share (the last ticket
//! takes the exact remainder), so per-worker `InferenceCounters` still sum
//! to the true engine cost. With a single producer the router dispatches
//! immediately, every call carries exactly one submission, and E=1 routes
//! every plan to replica 0 (fork stream 0) in FIFO order — which is what
//! makes the serial-through-service path ([`ServicedPolicy`]) reproduce the
//! plain serial `RunRecord` bit for bit (`rust/tests/service_sim.rs`).

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::tasks::TaskInstance;
use crate::metrics::{ServiceCounters, MAX_POOL};
use crate::policy::fault::{FaultyEngine, RecoveryConfig};
use crate::policy::{
    EvalResult, GenRequest, GenResult, RolloutEngine, TrainResult, Trainable, WeightSnapshot,
};
use crate::rl::algo::AlgoConfig;
use crate::rl::update::PromptGroup;
use crate::util::sync::{plock, pwait, pwait_timeout, SyncCondvar, SyncMutex};

/// Typed terminal failures the fault-tolerant service delivers to waiting
/// tickets (via `anyhow`, so `Ticket::wait` callers see them as ordinary
/// errors with a descriptive message instead of hanging).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The scheduler thread panicked: queued submissions are failed, the
    /// queue is closed, and every later submission errors immediately.
    SchedulerPanicked,
    /// The replica executing this plan panicked and no healthy peer was
    /// left to take the work over.
    ReplicaPanicked {
        replica: usize,
    },
    /// Every replica is quarantined (and no spare is left to respawn), so
    /// the plan cannot be dispatched anywhere.
    NoHealthyReplicas,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::SchedulerPanicked => {
                write!(f, "inference service scheduler panicked; submission abandoned")
            }
            ServiceError::ReplicaPanicked { replica } => {
                write!(f, "engine replica {replica} panicked with no healthy peer to take over")
            }
            ServiceError::NoHealthyReplicas => {
                write!(f, "no healthy engine replica left in the pool")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// How the router turns queued submissions into executable plans
/// (the `--batching` CLI flag).
///
/// `Deadline` is the §8 micro-batch coalescer — wait up to
/// `coalesce_wait_ms` for the fill waterline, then merge the leading run
/// of submissions into one call. It stays the default and the bit-for-bit
/// legacy rail. `Slots` is slot-level continuous batching (DESIGN.md
/// §14): each leading submission is admitted into a replica slot the
/// moment the router sees it and retired when it completes, so fill comes
/// from full-capacity submission quanta instead of a staleness-priced
/// gather window.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchingMode {
    #[default]
    Deadline,
    Slots,
}

impl BatchingMode {
    /// Every valid `--batching` mode, in display order.
    pub const NAMES: [&'static str; 2] = ["deadline", "slots"];

    pub fn name(self) -> &'static str {
        match self {
            BatchingMode::Deadline => "deadline",
            BatchingMode::Slots => "slots",
        }
    }

    /// Parse a `--batching` value, listing the valid modes on error.
    pub fn parse_or_err(s: &str) -> Result<BatchingMode> {
        match s {
            "deadline" => Ok(BatchingMode::Deadline),
            "slots" => Ok(BatchingMode::Slots),
            other => Err(anyhow!(
                "unknown batching mode '{other}' (valid: {})",
                Self::NAMES.join(", ")
            )),
        }
    }
}

/// Scheduler knobs (the `--batching` / `--coalesce-wait-ms` /
/// `--fill-waterline` CLI flags). In deadline mode the deadline trades a
/// little extra on-policy staleness for fuller calls and the waterline
/// dispatches early once a call is full enough; slots mode ignores both
/// (admission is immediate) and rejects overrides at validation time.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Dispatch discipline: deadline coalescing (legacy default) or
    /// slot-level continuous batching.
    pub batching: BatchingMode,
    /// After the first pending submission arrives, wait at most this long
    /// (real milliseconds) for more before executing. With `adaptive` on
    /// this becomes the upper bound of the adaptive deadline.
    pub coalesce_wait_ms: u64,
    /// Fraction of engine capacity that triggers immediate dispatch.
    pub fill_waterline: f64,
    /// Scale the deadline with the observed inter-submission gap (EWMA)
    /// instead of the fixed constant: fast producers get a short deadline
    /// (less staleness), slow ones a longer window (fuller calls) — both
    /// clamped to `[coalesce_wait_ms / 8, coalesce_wait_ms]`.
    pub adaptive: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batching: BatchingMode::Deadline,
            coalesce_wait_ms: 2,
            fill_waterline: 0.85,
            adaptive: false,
        }
    }
}

/// One queued generation submission awaiting the scheduler. Cloneable so
/// replicas can park a shadow copy of their in-flight plan: the `Sender`
/// clone feeds the same ticket, so whichever copy executes delivers.
#[derive(Clone)]
struct GenWork {
    requests: Vec<GenRequest>,
    temperature: f32,
    rows: usize,
    enqueued: Instant,
    tx: mpsc::Sender<Result<GenResult>>,
}

/// Queue entries: generation (coalescable) and evaluation (runs alone).
enum Work {
    Generate(GenWork),
    Evaluate { tasks: Vec<TaskInstance>, tx: mpsc::Sender<Result<EvalResult>> },
}

#[derive(Default)]
struct ServiceQueue {
    q: VecDeque<Work>,
    /// Newest learner snapshot not yet installed at the engine. Installs
    /// jump the queue (checked before every dispatch).
    pending_install: Option<WeightSnapshot>,
    closed: bool,
}

struct Shared {
    queue: SyncMutex<ServiceQueue>,
    work_ready: SyncCondvar,
    /// Version the service serves once any pending install lands — what
    /// handles report as `serving_version`, deduping K workers' installs.
    version: AtomicU64,
    stats: SyncMutex<ServiceCounters>,
    /// Test hook: when raised, the scheduler panics at the top of its next
    /// iteration (the containment regression: every waiter must unblock
    /// with a typed error, not hang). Never set outside tests.
    panic_scheduler: AtomicBool,
}

/// One routed unit of work: the router's coalescing decisions are already
/// made (which submissions travel together, call vs split), so replicas
/// only execute. Cloneable (see [`GenWork`]) for the shadow in-flight copy
/// that survives a replica's death or watchdog seizure.
#[derive(Clone)]
enum Plan {
    /// A coalesced call: `subs` fit one replica's capacity together.
    Call { subs: Vec<GenWork>, rows_total: usize, deadline_fired: bool },
    /// One oversized submission, executed as successive chunked calls.
    Split(GenWork),
    /// An evaluation pass (0 rollout rows for load accounting).
    Eval { tasks: Vec<TaskInstance>, tx: mpsc::Sender<Result<EvalResult>> },
}

/// Rollout rows a plan will occupy on its replica (the load metric for
/// least-loaded dispatch; evaluation is excluded from fill accounting).
fn plan_rows(plan: &Plan) -> usize {
    match plan {
        Plan::Call { rows_total, .. } => *rows_total,
        Plan::Split(g) => g.rows,
        Plan::Eval { .. } => 0,
    }
}

/// Shared pool state: one mutex + condvar across all E replicas (E <=
/// [`MAX_POOL`], so contention is negligible and least-loaded dispatch,
/// stealing, and snapshot publication are race-free against each other).
struct PoolState {
    /// Per-replica FIFO plan queues (the router pushes, replicas pop).
    /// Sized to active replicas + spare slots; spare slots stay empty
    /// until a respawn admits them.
    queues: Vec<VecDeque<Plan>>,
    /// Rollout rows queued but not yet started, per replica.
    queued_rows: Vec<usize>,
    /// Rollout rows currently executing, per replica.
    inflight_rows: Vec<usize>,
    /// Version each replica has installed (or reserved for install).
    installed: Vec<u64>,
    /// Replica admitted for dispatch: true for the initial E replicas,
    /// false for spare slots and quarantined replicas. The router and
    /// stealers only touch live replicas.
    live: Vec<bool>,
    /// Shadow copy of the plan each replica is executing (parked at plan
    /// take, claimed back at completion). If the replica dies or stalls
    /// past the watchdog, the shadow is what gets redispatched.
    inflight_plan: Vec<Option<Plan>>,
    /// When the current plan's execution started (drives the watchdog;
    /// cleared when the replica claims completion).
    exec_started: Vec<Option<Instant>>,
    /// Set by the watchdog when it seizes a stalled replica's plan while
    /// the replica is still executing. The zombie checks-and-clears it at
    /// completion and discards its results — no stats, no sends — so a
    /// redispatched plan is delivered exactly once.
    abandoned: Vec<bool>,
    /// Newest published snapshot; replicas install it lazily before their
    /// next plan and eagerly while idle. A replica mid-call keeps serving
    /// its old version, never one newer than announced.
    snap: WeightSnapshot,
    closed: bool,
}

impl PoolState {
    fn slots(&self) -> usize {
        self.queues.len()
    }

    fn live_count(&self) -> usize {
        self.live.iter().filter(|&&b| b).count()
    }
}

/// Declared through the [`crate::util::sync`] aliases: the exactly-once
/// seized-slot claim protocol living under `state` is one of the two
/// protocols modeled exhaustively by `analysis::model`
/// (`rust/tests/loom_sync.rs`), and the aliases are the one-file swap
/// point for a real loom build (DESIGN.md §15).
struct Pool {
    state: SyncMutex<PoolState>,
    ready: SyncCondvar,
    /// Dispatch discipline the router runs. Replica-side code needs it
    /// too: slot-retire trace instants only fire in slots mode.
    batching: BatchingMode,
    /// Engine rows per call (for the quantum recomputed on degrade).
    capacity: usize,
    /// Producers the quantum divides capacity across.
    producers: usize,
    /// Quantum floor (the allocator's largest possible group).
    min_quantum: usize,
    /// The live submit quantum, shared with every [`SubmitHandle`]:
    /// recomputed when the pool degrades (quarantine) or recovers
    /// (respawn) so producers size future submissions to real capacity.
    quantum: Arc<AtomicUsize>,
    /// Pre-forked spare engines `(slot, engine)`, activated into fresh
    /// slots at quarantine time when respawn is enabled. Never
    /// fault-wrapped. Popped in ascending slot order.
    spares: SyncMutex<Vec<(usize, Box<dyn RolloutEngine + Send>)>>,
    /// `(slot, handle)` of respawned replica threads (the scheduler joins
    /// them at shutdown alongside the original replicas).
    respawned: SyncMutex<Vec<(usize, std::thread::JoinHandle<()>)>>,
}

/// A pending reply for one submission. `wait` blocks until the scheduler
/// has executed the coalesced call containing it.
pub struct Ticket {
    rx: mpsc::Receiver<Result<GenResult>>,
}

impl Ticket {
    pub fn wait(self) -> Result<GenResult> {
        self.rx.recv().map_err(|_| anyhow!("inference service shut down before replying"))?
    }
}

/// The cheap per-worker handle: submit generation batches, block on
/// tickets. Implements [`RolloutEngine`] so rollout workers and curricula
/// drive the shared service exactly as they would a private engine.
#[derive(Clone)]
pub struct SubmitHandle {
    shared: Arc<Shared>,
    /// Rows this handle advertises to its curriculum (engine capacity x
    /// live replicas / K, floored at the allocator's largest possible
    /// group so every plan stays executable — oversized plans the floor
    /// admits are split across successive engine calls by the scheduler).
    /// Shared with the pool: quarantine/respawn recompute it live.
    quantum: Arc<AtomicUsize>,
    gen_len: usize,
    label: String,
}

impl SubmitHandle {
    /// Enqueue one generation batch; returns immediately with a ticket.
    pub fn submit(&self, requests: Vec<GenRequest>, temperature: f32) -> Ticket {
        let rows = requests.iter().map(|r| r.n_samples).sum();
        let (tx, rx) = mpsc::channel();
        let mut q = plock(&self.shared.queue);
        if q.closed {
            let _ = tx.send(Err(anyhow!("inference service is closed")));
        } else {
            q.q.push_back(Work::Generate(GenWork {
                requests,
                temperature,
                rows,
                enqueued: Instant::now(),
                tx,
            }));
            self.shared.work_ready.notify_all();
        }
        Ticket { rx }
    }
}

impl RolloutEngine for SubmitHandle {
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult> {
        self.submit(requests.to_vec(), temperature).wait()
    }

    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = plock(&self.shared.queue);
            if q.closed {
                return Err(anyhow!("inference service is closed"));
            }
            q.q.push_back(Work::Evaluate { tasks: tasks.to_vec(), tx });
            self.shared.work_ready.notify_all();
        }
        rx.recv().map_err(|_| anyhow!("inference service shut down before replying"))?
    }

    fn rollout_capacity(&self) -> usize {
        self.quantum.load(Ordering::Acquire)
    }

    fn gen_len(&self) -> usize {
        self.gen_len
    }

    fn install(&mut self, snap: &WeightSnapshot) {
        let mut q = plock(&self.shared.queue);
        // Dedupe: the first handle to notice a published version queues the
        // install; the rest see `serving_version` already advanced.
        if self.shared.version.load(Ordering::Acquire) < snap.version {
            self.shared.version.store(snap.version, Ordering::Release);
            q.pending_install = Some(snap.clone());
            self.shared.work_ready.notify_all();
        }
    }

    fn serving_version(&self) -> u64 {
        self.shared.version.load(Ordering::Acquire)
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// The service: owns the router thread, which in turn owns one worker
/// thread per engine replica. Dropping it closes the queue and joins the
/// router (which joins the replicas).
pub struct InferenceService {
    shared: Arc<Shared>,
    thread: Option<std::thread::JoinHandle<()>>,
    quantum: Arc<AtomicUsize>,
    gen_len: usize,
    label: String,
}

impl InferenceService {
    /// Single-engine service: `spawn_pool` with E = 1 (the historical
    /// entry point; every plan lands on replica 0 in FIFO order).
    pub fn spawn(
        engine: Box<dyn RolloutEngine + Send>,
        cfg: ServiceConfig,
        producers: usize,
        min_quantum: usize,
    ) -> InferenceService {
        Self::spawn_pool(vec![engine], cfg, producers, min_quantum)
    }

    /// Spawn the router around a pool of E data-parallel replicas (forks of
    /// one policy: same capacity, gen_len, and serving version).
    /// `producers` is the number of workers that will hold handles (sets
    /// the submit quantum, scaled by E since E replicas execute
    /// concurrently); `min_quantum` floors the quantum so one full
    /// screening/continuation group always fits a single submission (pass
    /// the allocator's `max_n_total` — the largest budget a prompt can be
    /// issued).
    pub fn spawn_pool(
        engines: Vec<Box<dyn RolloutEngine + Send>>,
        cfg: ServiceConfig,
        producers: usize,
        min_quantum: usize,
    ) -> InferenceService {
        Self::spawn_pool_with_recovery(
            engines,
            Vec::new(),
            cfg,
            RecoveryConfig::inactive(),
            producers,
            min_quantum,
        )
    }

    /// [`InferenceService::spawn_pool`] plus the fault-tolerance machinery
    /// of DESIGN.md §13: active replicas are wrapped in the recovery
    /// config's scripted [`crate::policy::fault::FaultPlan`] (a no-op for
    /// unnamed replicas and the empty plan), failed calls retry with
    /// bounded backoff, stalled or dead replicas are quarantined and their
    /// work redispatched, and `spares` (never fault-wrapped) are activated
    /// into fresh slots to replace quarantined replicas when
    /// `recovery.respawn` is set. With `RecoveryConfig::inactive()` and no
    /// spares this is behaviorally identical to the plain pool.
    pub fn spawn_pool_with_recovery(
        engines: Vec<Box<dyn RolloutEngine + Send>>,
        spares: Vec<Box<dyn RolloutEngine + Send>>,
        cfg: ServiceConfig,
        recovery: RecoveryConfig,
        producers: usize,
        min_quantum: usize,
    ) -> InferenceService {
        let e = engines.len();
        let slots = e + spares.len();
        assert!(
            e >= 1 && slots <= MAX_POOL,
            "engine pool size (incl. spares) must be 1..={MAX_POOL}, got {e}+{}",
            spares.len()
        );
        let capacity = engines[0].rollout_capacity();
        let q0 = quantum_for(cfg.batching, capacity, e, producers, min_quantum);
        let quantum = Arc::new(AtomicUsize::new(q0));
        let gen_len = engines[0].gen_len();
        let label = engines[0].name().to_string();
        let mut installed: Vec<u64> = engines.iter().map(|en| en.serving_version()).collect();
        installed.extend(spares.iter().map(|en| en.serving_version()));
        let version = installed[0];
        let mut stats = ServiceCounters {
            engines: e as u64,
            slots_mode: (cfg.batching == BatchingMode::Slots) as u64,
            ..Default::default()
        };
        for (r, v) in installed.iter().take(e).enumerate() {
            stats.replica_weight_version[r] = *v;
        }
        let shared = Arc::new(Shared {
            queue: SyncMutex::new(ServiceQueue::default()),
            work_ready: SyncCondvar::new(),
            version: AtomicU64::new(version),
            stats: SyncMutex::new(stats),
            panic_scheduler: AtomicBool::new(false),
        });
        // Spares activate in ascending slot order (pop from the back).
        let spares: Vec<(usize, Box<dyn RolloutEngine + Send>)> =
            spares.into_iter().enumerate().map(|(i, en)| (e + i, en)).rev().collect();
        let pool = Arc::new(Pool {
            state: SyncMutex::new(PoolState {
                queues: (0..slots).map(|_| VecDeque::new()).collect(),
                queued_rows: vec![0; slots],
                inflight_rows: vec![0; slots],
                installed,
                live: (0..slots).map(|r| r < e).collect(),
                inflight_plan: (0..slots).map(|_| None).collect(),
                exec_started: vec![None; slots],
                abandoned: vec![false; slots],
                snap: WeightSnapshot { version, values: Vec::new() },
                closed: false,
            }),
            ready: SyncCondvar::new(),
            batching: cfg.batching,
            capacity,
            producers,
            min_quantum,
            quantum: Arc::clone(&quantum),
            spares: SyncMutex::new(spares),
            respawned: SyncMutex::new(Vec::new()),
        });
        let recovery = Arc::new(recovery);
        let replicas: Vec<std::thread::JoinHandle<()>> = engines
            .into_iter()
            .enumerate()
            .map(|(r, engine)| {
                let engine = FaultyEngine::wrap(engine, r, &recovery.fault_plan);
                let pool = Arc::clone(&pool);
                let shared = Arc::clone(&shared);
                let recovery = Arc::clone(&recovery);
                std::thread::Builder::new()
                    .name(format!("speedrl-engine-{r}"))
                    .spawn(move || replica_main(r, engine, pool, shared, recovery))
                    .expect("spawn engine replica")
            })
            .collect();
        let thread = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("speedrl-inference-service".to_string())
                .spawn(move || scheduler(pool, replicas, capacity, shared, cfg, producers, recovery))
                .expect("spawn inference-service scheduler")
        };
        InferenceService { shared, thread: Some(thread), quantum, gen_len, label }
    }

    /// A fresh handle for one producer (cheap: one `Arc` clone).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle {
            shared: Arc::clone(&self.shared),
            quantum: Arc::clone(&self.quantum),
            gen_len: self.gen_len,
            label: self.label.clone(),
        }
    }

    /// Rows each producer's handle advertises (engine capacity x live
    /// replicas / K; shrinks when the pool degrades, grows on respawn).
    pub fn quantum(&self) -> usize {
        self.quantum.load(Ordering::Acquire)
    }

    /// Live counters snapshot.
    pub fn stats(&self) -> ServiceCounters {
        *plock(&self.shared.stats)
    }

    /// Close the queue: in-flight work is served, new submissions fail.
    pub fn close(&self) {
        plock(&self.shared.queue).closed = true;
        self.shared.work_ready.notify_all();
    }

    /// Test hook: make the scheduler panic at its next iteration (the
    /// containment regression — waiters must unblock, not hang).
    #[cfg(test)]
    fn kill_scheduler(&self) {
        self.shared.panic_scheduler.store(true, Ordering::Release);
        self.shared.work_ready.notify_all();
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Rows in the queue's leading run of generate submissions that could join
/// the next call (same temperature, FIFO, stopping at an evaluate).
fn leading_rows(q: &VecDeque<Work>) -> usize {
    let mut rows = 0usize;
    let mut temp: Option<f32> = None;
    for w in q {
        match w {
            Work::Generate(g) => {
                if *temp.get_or_insert(g.temperature) != g.temperature {
                    break;
                }
                rows += g.rows;
            }
            Work::Evaluate { .. } => break,
        }
    }
    rows
}

/// Route one coalesced plan onto the least-loaded replica (queued +
/// in-flight rollout rows, lowest index on ties). With E=1 every plan
/// lands on replica 0 in FIFO order — the serial bit-for-bit rail. The
/// busy-replica count *before* this assignment feeds the pool-balance
/// histogram.
fn dispatch(pool: &Pool, shared: &Shared, plan: Plan) {
    let rows = plan_rows(&plan);
    let (busy, occupancy) = {
        let mut ps = plock(&pool.state);
        let busy = (0..ps.slots())
            .filter(|&i| {
                ps.live[i]
                    && (ps.queued_rows[i] + ps.inflight_rows[i] > 0 || !ps.queues[i].is_empty())
            })
            .count();
        let Some(r) = (0..ps.slots())
            .filter(|&i| ps.live[i])
            .min_by_key(|&i| (ps.queued_rows[i] + ps.inflight_rows[i], i))
        else {
            // Every replica is quarantined and no spare was left: fail the
            // plan's tickets instead of stranding them on a dead pool.
            drop(ps);
            fail_plan(plan, &ServiceError::NoHealthyReplicas.to_string());
            return;
        };
        ps.queued_rows[r] += rows;
        ps.queues[r].push_back(plan);
        (busy, ps.queued_rows[r] + ps.inflight_rows[r])
    };
    pool.ready.notify_all();
    {
        let mut stats = plock(&shared.stats);
        stats.pool_dispatches += 1;
        stats.pool_busy_sum += busy as u64;
        stats.pool_hist[busy.min(stats.pool_hist.len() - 1)] += 1;
        // Slot-occupancy telemetry (always on, in both batching modes):
        // rollout rows resident on the chosen replica right after this
        // admission, against its engine capacity. Pure row arithmetic —
        // no clocks — so serviced records stay deterministic. Evaluation
        // plans occupy no rollout slots and are excluded.
        if rows > 0 {
            stats.slot_admissions += 1;
            stats.slot_occupancy_sum += occupancy as u64;
            stats.slot_capacity_sum += pool.capacity as u64;
            let b = ServiceCounters::occupancy_bucket(occupancy, pool.capacity);
            stats.slot_occupancy_hist[b] += 1;
        }
    }
    crate::trace::instant("dispatch", "scheduler", busy as i64);
}

/// Deliver a terminal error to every ticket riding on `plan`, using the
/// same message shapes the execute paths use.
fn fail_plan(plan: Plan, msg: &str) {
    match plan {
        Plan::Call { subs, .. } => {
            for s in subs {
                let _ = s.tx.send(Err(anyhow!("coalesced inference call failed: {msg}")));
            }
        }
        Plan::Split(g) => {
            let _ = g.tx.send(Err(anyhow!("split inference call failed: {msg}")));
        }
        Plan::Eval { tx, .. } => {
            let _ = tx.send(Err(anyhow!("evaluation failed: {msg}")));
        }
    }
}

/// Deliver a terminal error to a not-yet-routed queue entry (the
/// scheduler's crash path: queued work still holds live ticket senders, so
/// dropping it silently would leave `Ticket::wait` blocked forever).
fn fail_work(work: Work, err: ServiceError) {
    match work {
        Work::Generate(g) => {
            let _ = g.tx.send(Err(anyhow!(err)));
        }
        Work::Evaluate { tx, .. } => {
            let _ = tx.send(Err(anyhow!(err)));
        }
    }
}

/// Route seized plans (in-flight shadow first, then the quarantined
/// replica's queue, preserving FIFO) back through least-loaded dispatch.
fn redispatch(pool: &Pool, shared: &Shared, plans: Vec<Plan>) {
    for plan in plans {
        plock(&shared.stats).redispatches += 1;
        crate::trace::instant("redispatch", "scheduler", plan_rows(&plan) as i64);
        dispatch(pool, shared, plan);
    }
}

/// The submit quantum each producer's handle advertises for a pool with
/// `live` healthy replicas. Deadline mode slices pool capacity across the
/// K producers so their plans tile one coalesced call; slots mode hands
/// every producer the full engine capacity, so each admitted submission
/// already packs one full call and the router never needs to merge.
fn quantum_for(
    batching: BatchingMode,
    capacity: usize,
    live: usize,
    producers: usize,
    min_quantum: usize,
) -> usize {
    let base = match batching {
        BatchingMode::Deadline => capacity * live / producers.max(1),
        BatchingMode::Slots => capacity,
    };
    base.max(min_quantum).clamp(1, capacity.max(1))
}

/// Recompute the submit quantum from the live replica count (graceful
/// degradation: producers size future submissions to the real capacity).
fn recompute_quantum(pool: &Pool) {
    let live = plock(&pool.state).live_count().max(1);
    let q = quantum_for(pool.batching, pool.capacity, live, pool.producers, pool.min_quantum);
    pool.quantum.store(q, Ordering::Release);
}

/// Activate one pre-forked spare into its reserved slot: install the
/// announced snapshot first, then admit the slot for dispatch and spawn
/// its replica thread. No-op when respawn is off or no spare is left.
fn try_respawn(
    pool: &Arc<Pool>,
    shared: &Arc<Shared>,
    recovery: &Arc<RecoveryConfig>,
) {
    if !recovery.respawn {
        return;
    }
    let Some((slot, mut engine)) = plock(&pool.spares).pop() else {
        return;
    };
    // Install the announced snapshot BEFORE admission so the new replica
    // never serves pre-quarantine weights to post-quarantine plans.
    let snap = plock(&pool.state).snap.clone();
    if snap.version > engine.serving_version() {
        engine.install(&snap);
    }
    let version = engine.serving_version();
    {
        let mut ps = plock(&pool.state);
        ps.installed[slot] = version;
        ps.live[slot] = true;
    }
    {
        let mut stats = plock(&shared.stats);
        stats.respawns += 1;
        stats.replica_weight_version[slot] = version;
    }
    crate::trace::instant("respawn", "scheduler", slot as i64);
    let handle = {
        let pool2 = Arc::clone(pool);
        let shared2 = Arc::clone(shared);
        let recovery2 = Arc::clone(recovery);
        std::thread::Builder::new()
            .name(format!("speedrl-engine-{slot}"))
            .spawn(move || replica_main(slot, engine, pool2, shared2, recovery2))
            .expect("spawn respawned engine replica")
    };
    plock(&pool.respawned).push((slot, handle));
    pool.ready.notify_all();
}

/// The execute watchdog: quarantine any live replica whose current plan
/// has been executing for `exec_timeout_ms` or longer, seize its shadow
/// plan and queue, and hand everything to healthy peers. The stalled
/// thread becomes a zombie: the `abandoned` flag makes it discard its
/// eventual results, so the redispatched plan delivers exactly once.
fn watchdog_scan(pool: &Arc<Pool>, shared: &Arc<Shared>, recovery: &Arc<RecoveryConfig>) {
    if recovery.exec_timeout_ms == 0 {
        return;
    }
    let timeout = Duration::from_millis(recovery.exec_timeout_ms);
    let now = Instant::now();
    let mut seized: Vec<Plan> = Vec::new();
    let mut expired: Vec<usize> = Vec::new();
    {
        let mut ps = plock(&pool.state);
        for r in 0..ps.slots() {
            let stalled = ps.live[r]
                && ps
                    .exec_started[r]
                    .is_some_and(|t0| now.saturating_duration_since(t0) >= timeout);
            if !stalled {
                continue;
            }
            ps.live[r] = false;
            ps.abandoned[r] = true;
            ps.exec_started[r] = None;
            if let Some(p) = ps.inflight_plan[r].take() {
                seized.push(p);
            }
            seized.extend(ps.queues[r].drain(..));
            ps.queued_rows[r] = 0;
            ps.inflight_rows[r] = 0;
            expired.push(r);
        }
    }
    if expired.is_empty() {
        return;
    }
    {
        let mut stats = plock(&shared.stats);
        for &r in &expired {
            stats.faults_injected += 1;
            stats.replica_faults[r] += 1;
            stats.quarantines += 1;
        }
    }
    for &r in &expired {
        crate::trace::instant("quarantine", "scheduler", r as i64);
    }
    for _ in &expired {
        try_respawn(pool, shared, recovery);
    }
    redispatch(pool, shared, seized);
    recompute_quantum(pool);
    pool.ready.notify_all();
}

/// Close the pool and join every replica (run by the router on shutdown;
/// replicas drain their queues — and each other's — before exiting, so
/// already-dispatched tickets are still served). Zombie replicas (seized
/// by the watchdog and possibly stuck in a hung engine call forever) are
/// detached instead of joined, so shutdown never blocks on them.
fn shutdown_pool(pool: &Pool, replicas: Vec<std::thread::JoinHandle<()>>) {
    plock(&pool.state).closed = true;
    pool.ready.notify_all();
    let respawned: Vec<(usize, std::thread::JoinHandle<()>)> =
        std::mem::take(&mut *plock(&pool.respawned));
    let originals = replicas.into_iter().enumerate();
    for (r, h) in originals.chain(respawned) {
        if plock(&pool.state).abandoned[r] {
            drop(h); // zombie: detach, never block shutdown on a hung engine
        } else {
            let _ = h.join();
        }
    }
}

/// What one plan's execution resolved to (see [`execute_call`] /
/// [`execute_split`]): the claim protocol on the shadow plan decides
/// between these, so results and stats land exactly once per plan.
enum ExecOutcome {
    /// Results (or a terminal error) were delivered to the tickets.
    Done,
    /// The watchdog seized the plan mid-execution and a peer owns it now:
    /// results were discarded, the zombie thread must exit.
    Abandoned,
    /// Retries exhausted: nothing was delivered; the caller decides
    /// between redispatch-and-quarantine and the graceful floor.
    Failed {
        seized: Box<Plan>,
        msg: String,
    },
}

/// Execution context a replica passes into the execute helpers: identity
/// plus the shared state the retry/abandon protocol needs.
struct ReplicaCtx<'a> {
    r: usize,
    pool: &'a Pool,
    shared: &'a Shared,
    recovery: &'a RecoveryConfig,
}

/// Replica thread entry: the worker loop runs under `catch_unwind`, so a
/// panicking engine (a hard-death fault, or a real crash) converts into
/// quarantine + redispatch instead of a poisoned-lock hang.
fn replica_main(
    r: usize,
    engine: Box<dyn RolloutEngine + Send>,
    pool: Arc<Pool>,
    shared: Arc<Shared>,
    recovery: Arc<RecoveryConfig>,
) {
    let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
        replica_loop(r, engine, &pool, &shared, &recovery)
    }))
    .is_err();
    if panicked {
        on_replica_panic(r, &pool, &shared, &recovery);
    }
}

/// Containment for a replica panic: quarantine the slot, hand the shadow
/// plan and queued work to healthy peers (respawning a spare first when
/// enabled), or — with nobody left — deliver a typed error to every
/// waiting ticket so no worker blocks on a dead pool.
fn on_replica_panic(
    r: usize,
    pool: &Arc<Pool>,
    shared: &Arc<Shared>,
    recovery: &Arc<RecoveryConfig>,
) {
    let seized: Vec<Plan> = {
        let mut ps = plock(&pool.state);
        if ps.abandoned[r] {
            // The watchdog already seized everything while we were dying.
            ps.abandoned[r] = false;
            return;
        }
        ps.live[r] = false;
        ps.exec_started[r] = None;
        ps.inflight_rows[r] = 0;
        ps.queued_rows[r] = 0;
        let mut seized: Vec<Plan> = ps.inflight_plan[r].take().into_iter().collect();
        seized.extend(ps.queues[r].drain(..));
        seized
    };
    {
        let mut stats = plock(&shared.stats);
        stats.faults_injected += 1;
        stats.replica_faults[r] += 1;
        stats.quarantines += 1;
    }
    crate::trace::instant("quarantine", "replica", r as i64);
    try_respawn(pool, shared, recovery);
    let has_peer = plock(&pool.state).live_count() > 0;
    if has_peer {
        redispatch(pool, shared, seized);
    } else {
        for plan in seized {
            fail_plan(plan, &ServiceError::ReplicaPanicked { replica: r }.to_string());
        }
    }
    recompute_quantum(pool);
    pool.ready.notify_all();
}

/// One replica worker: install published snapshots (lazily before every
/// plan, eagerly while idle), execute its own queue FIFO, steal the oldest
/// plan from the most backlogged peer when drained, and exit once the pool
/// is closed with nothing left anywhere — or once it is quarantined.
fn replica_loop(
    r: usize,
    mut engine: Box<dyn RolloutEngine + Send>,
    pool: &Arc<Pool>,
    shared: &Arc<Shared>,
    recovery: &Arc<RecoveryConfig>,
) {
    let capacity = engine.rollout_capacity();
    loop {
        let mut plan: Option<(Plan, usize)> = None;
        let mut install: Option<WeightSnapshot> = None;
        {
            let mut ps = plock(&pool.state);
            loop {
                // A quarantined replica has nothing left to do: its queue
                // was seized and the router will never route to it again.
                if !ps.live[r] {
                    return;
                }
                // Install first: a replica never starts a plan with a
                // newer announced snapshot uninstalled (the reservation of
                // `installed[r]` under the lock makes the install
                // exactly-once per version per replica).
                if ps.installed[r] < ps.snap.version {
                    ps.installed[r] = ps.snap.version;
                    install = Some(ps.snap.clone());
                    break;
                }
                if let Some(p) = ps.queues[r].pop_front() {
                    let rows = plan_rows(&p);
                    ps.queued_rows[r] -= rows;
                    ps.inflight_rows[r] += rows;
                    ps.inflight_plan[r] = Some(p.clone());
                    ps.exec_started[r] = Some(Instant::now());
                    plan = Some((p, rows));
                    break;
                }
                // Work-stealing: drained, so pull the oldest plan from the
                // most backlogged peer (lowest index on row ties) instead
                // of idling. Only BUSY peers are victims: an idle peer is
                // about to pop its own queue anyway, and racing it would
                // make single-producer routing nondeterministic (the E=1
                // and one-producer rails dispatch to idle replicas only).
                let victim = (0..ps.slots())
                    .filter(|&i| {
                        i != r && ps.live[i] && !ps.queues[i].is_empty() && ps.inflight_rows[i] > 0
                    })
                    .max_by_key(|&i| (ps.queued_rows[i], std::cmp::Reverse(i)));
                if let Some(v) = victim {
                    let p = ps.queues[v].pop_front().expect("victim queue checked non-empty");
                    let rows = plan_rows(&p);
                    ps.queued_rows[v] -= rows;
                    ps.inflight_rows[r] += rows;
                    ps.inflight_plan[r] = Some(p.clone());
                    ps.exec_started[r] = Some(Instant::now());
                    plan = Some((p, rows));
                    {
                        let mut stats = plock(&shared.stats);
                        stats.steals += 1;
                        stats.replica_steals[r] += 1;
                    }
                    crate::trace::instant("steal", "replica", v as i64);
                    break;
                }
                if ps.closed {
                    return;
                }
                let t_idle = crate::trace::start();
                ps = pwait(&pool.ready, ps);
                crate::trace::span("replica-idle", "replica", t_idle, r as i64);
            }
        }
        if let Some(snap) = install {
            let t_install = crate::trace::start();
            engine.install(&snap);
            crate::trace::span("weight-install", "replica", t_install, snap.version as i64);
            let mut stats = plock(&shared.stats);
            stats.installs += 1;
            stats.replica_installs[r] += 1;
            stats.replica_weight_version[r] = snap.version;
            continue;
        }
        let (p, rows) = plan.expect("no install, so a plan was taken");
        let ctx = ReplicaCtx { r, pool, shared, recovery };
        let outcome = match p {
            Plan::Call { subs, rows_total, deadline_fired } => {
                execute_call(&mut *engine, subs, rows_total, capacity, deadline_fired, &ctx)
            }
            Plan::Split(g) => execute_split(&mut *engine, g, capacity, &ctx),
            Plan::Eval { tasks, tx } => {
                let res = engine.evaluate(&tasks);
                let abandoned = {
                    let mut ps = plock(&pool.state);
                    if ps.abandoned[r] {
                        ps.abandoned[r] = false;
                        true
                    } else {
                        ps.inflight_plan[r] = None;
                        ps.exec_started[r] = None;
                        false
                    }
                };
                if abandoned {
                    ExecOutcome::Abandoned
                } else {
                    let _ = tx.send(res);
                    ExecOutcome::Done
                }
            }
        };
        match outcome {
            ExecOutcome::Done => {
                plock(&pool.state).inflight_rows[r] -= rows;
                // Retire the slot: the admitted rollout rows completed and
                // their capacity is free again. Counted in both batching
                // modes (evaluation plans hold no slot rows); the trace
                // instant is slots-mode-only — admit/retire pairs are the
                // slots lifecycle, deadline traces keep their §12 shape.
                if rows > 0 {
                    plock(&shared.stats).slot_retires += 1;
                    if pool.batching == BatchingMode::Slots {
                        crate::trace::instant("slot-retire", "replica", r as i64);
                    }
                }
                // A peer blocked in `dispatch`-order terms doesn't exist
                // (the router never blocks on replicas), but idle peers
                // wake to steal and the router's load view updates on its
                // next lock.
                pool.ready.notify_all();
            }
            ExecOutcome::Abandoned => {
                // The watchdog zeroed this replica's row accounting when it
                // seized the plan; just vacate the thread.
                pool.ready.notify_all();
                return;
            }
            ExecOutcome::Failed { seized, msg } => {
                if on_retry_exhaustion(r, rows, *seized, &msg, pool, shared, recovery) {
                    return;
                }
            }
        }
    }
}

/// Retry budget exhausted on replica `r`: quarantine it and move the failed
/// plan (plus everything queued behind it) to healthy peers — unless it IS
/// the last healthy replica, in which case deliver the error to the plan's
/// tickets and keep serving (the graceful floor that preserves single-
/// engine behavior at E=1). Returns true when the replica was quarantined
/// (the thread must exit).
fn on_retry_exhaustion(
    r: usize,
    rows: usize,
    seized: Plan,
    msg: &str,
    pool: &Arc<Pool>,
    shared: &Arc<Shared>,
    recovery: &Arc<RecoveryConfig>,
) -> bool {
    let mut seized_plans = vec![seized];
    let quarantined = {
        let mut ps = plock(&pool.state);
        ps.inflight_rows[r] -= rows;
        let peers = (0..ps.slots()).filter(|&i| i != r && ps.live[i]).count();
        if peers == 0 {
            false
        } else {
            ps.live[r] = false;
            seized_plans.extend(ps.queues[r].drain(..));
            ps.queued_rows[r] = 0;
            true
        }
    };
    if !quarantined {
        // Graceful floor: no peer to fall back to, so the error goes to
        // the tickets exactly as a single-engine failure would.
        for plan in seized_plans {
            fail_plan(plan, msg);
        }
        pool.ready.notify_all();
        return false;
    }
    plock(&shared.stats).quarantines += 1;
    crate::trace::instant("quarantine", "replica", r as i64);
    try_respawn(pool, shared, recovery);
    redispatch(pool, shared, seized_plans);
    recompute_quantum(pool);
    pool.ready.notify_all();
    true
}

/// The router thread: run the scheduling loop under `catch_unwind`; on a
/// clean close OR a panic, close the pool and join the replicas. A panic
/// additionally fails every queued submission with a typed
/// [`ServiceError::SchedulerPanicked`] and closes the queue, so blocked
/// `Ticket::wait` and future submissions error out instead of hanging.
fn scheduler(
    pool: Arc<Pool>,
    replicas: Vec<std::thread::JoinHandle<()>>,
    capacity: usize,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    producers: usize,
    recovery: Arc<RecoveryConfig>,
) {
    let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
        scheduler_loop(&pool, capacity, &shared, &cfg, producers, &recovery)
    }))
    .is_err();
    if panicked {
        let drained: Vec<Work> = {
            let mut q = plock(&shared.queue);
            q.closed = true;
            q.pending_install = None;
            q.q.drain(..).collect()
        };
        shared.work_ready.notify_all();
        for w in drained {
            fail_work(w, ServiceError::SchedulerPanicked);
        }
    }
    shutdown_pool(&pool, replicas);
}

/// The router loop: install → evaluate → coalesce-and-dispatch, until the
/// queue is closed and drained. Returns (instead of shutting the pool
/// down itself) so the panic containment in [`scheduler`] shares one
/// shutdown path with the clean close.
fn scheduler_loop(
    pool: &Arc<Pool>,
    capacity: usize,
    shared: &Arc<Shared>,
    cfg: &ServiceConfig,
    producers: usize,
    recovery: &Arc<RecoveryConfig>,
) {
    let waterline_rows =
        ((capacity as f64 * cfg.fill_waterline).ceil() as usize).clamp(1, capacity);
    let base_wait_s = cfg.coalesce_wait_ms as f64 / 1e3;
    // The watchdog wakes at half the execute timeout, so a stalled replica
    // is caught within one period of the deadline passing.
    let watchdog_period = (recovery.exec_timeout_ms > 0)
        .then(|| Duration::from_millis((recovery.exec_timeout_ms / 2).max(1)));
    // Adaptive deadline state: EWMA of the gap between consecutive
    // submission arrivals. Seeded at the configured deadline so the first
    // calls behave exactly like the fixed-constant scheduler.
    let mut ewma_gap_s = base_wait_s;
    let mut last_enqueued: Option<Instant> = None;
    loop {
        if shared.panic_scheduler.load(Ordering::Acquire) {
            panic!("injected scheduler death (test hook)");
        }
        watchdog_scan(pool, shared, recovery);
        // The deadline for THIS gathering round: long enough for roughly
        // the other producers' next submissions to arrive (3x the observed
        // gap), never longer than the configured constant.
        let wait = if cfg.adaptive {
            Duration::from_secs_f64((3.0 * ewma_gap_s).clamp(base_wait_s / 8.0, base_wait_s))
        } else {
            Duration::from_secs_f64(base_wait_s)
        };
        let mut guard = plock(&shared.queue);
        // Phase 1: wait for any work at all. With the watchdog armed, wake
        // every half-timeout to scan for stalled replicas (their tickets
        // are in flight, not in this queue, so nothing else would wake us).
        while guard.q.is_empty() && guard.pending_install.is_none() {
            if guard.closed {
                return;
            }
            if shared.panic_scheduler.load(Ordering::Acquire) {
                panic!("injected scheduler death (test hook)");
            }
            match watchdog_period {
                Some(period) => {
                    drop(guard);
                    watchdog_scan(pool, shared, recovery);
                    guard = plock(&shared.queue);
                    if !guard.q.is_empty() || guard.pending_install.is_some() {
                        break;
                    }
                    if guard.closed {
                        return;
                    }
                    let (g, _) = pwait_timeout(&shared.work_ready, guard, period);
                    guard = g;
                }
                None => guard = pwait(&shared.work_ready, guard),
            }
        }
        // Phase 2: installs jump the queue — publish the snapshot once per
        // version, however many workers requested it; every replica
        // installs it before its next plan (the publish precedes any later
        // dispatch, so a plan submitted after an install always runs under
        // at least that version).
        if let Some(snap) = guard.pending_install.take() {
            drop(guard);
            {
                let mut ps = plock(&pool.state);
                if snap.version > ps.snap.version {
                    ps.snap = snap;
                }
            }
            pool.ready.notify_all();
            continue;
        }
        // Phase 3: evaluation routes as its own plan (greedy; excluded
        // from fill accounting like the trainers exclude eval time).
        if matches!(guard.q.front(), Some(Work::Evaluate { .. })) {
            let Some(Work::Evaluate { tasks, tx }) = guard.q.pop_front() else {
                unreachable!("front checked above");
            };
            drop(guard);
            dispatch(&pool, &shared, Plan::Eval { tasks, tx });
            continue;
        }
        // Phase 4/5 in slots mode: continuous batching. There is no gather
        // window — the leading submission is admitted into a replica slot
        // the moment the router sees it, as its own call (its quantum
        // already packs full engine capacity; see [`quantum_for`]). The
        // deadline/waterline/EWMA machinery below is the legacy rail: in
        // slots mode fill is bought at admission time, not by making
        // co-travellers wait, so the staleness/fill trade-off of §8
        // disappears rather than being tuned (DESIGN.md §14).
        if cfg.batching == BatchingMode::Slots {
            let Some(Work::Generate(g)) = guard.q.pop_front() else {
                unreachable!("install and evaluate fronts handled above");
            };
            drop(guard);
            crate::trace::instant("slot-admit", "scheduler", g.rows as i64);
            let rows = g.rows;
            let plan = if rows > capacity {
                // An oversized admission still chunks across successive
                // engine calls on its replica (requests stay whole).
                Plan::Split(g)
            } else {
                Plan::Call { subs: vec![g], rows_total: rows, deadline_fired: false }
            };
            dispatch(&pool, &shared, plan);
            continue;
        }
        // Phase 4: micro-batch — wait for the waterline until the deadline.
        // A single producer cannot submit again while blocked on its
        // ticket, so dispatch immediately (the serial-equivalence rail).
        let mut deadline_fired = false;
        if producers > 1 {
            let t_coalesce = crate::trace::start();
            let deadline = Instant::now() + wait;
            loop {
                if guard.closed || guard.pending_install.is_some() {
                    break;
                }
                if leading_rows(&guard.q) >= waterline_rows {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    deadline_fired = true;
                    break;
                }
                let (g, timeout) = pwait_timeout(&shared.work_ready, guard, deadline - now);
                guard = g;
                if timeout.timed_out() {
                    deadline_fired = true;
                    break;
                }
            }
            crate::trace::span("coalesce-wait", "scheduler", t_coalesce, deadline_fired as i64);
            if guard.pending_install.is_some() {
                continue; // install first, then re-gather
            }
        }
        // Phase 5: drain the leading submissions that fit one call.
        let mut subs: Vec<GenWork> = Vec::new();
        let mut rows_total = 0usize;
        while let Some(front) = guard.q.front() {
            match front {
                Work::Generate(g) => {
                    if let Some(first) = subs.first() {
                        if g.temperature != first.temperature || rows_total + g.rows > capacity {
                            break;
                        }
                    }
                    let Some(Work::Generate(g)) = guard.q.pop_front() else {
                        unreachable!("front checked above");
                    };
                    rows_total += g.rows;
                    subs.push(g);
                }
                Work::Evaluate { .. } => break,
            }
        }
        drop(guard);
        if subs.is_empty() {
            continue; // raced with close/install; re-enter the wait loop
        }
        // Track the inter-submission gap (EWMA) that drives the adaptive
        // deadline; arrival timestamps are recorded at enqueue, so the
        // measurement is independent of how long this call executes.
        for s in &subs {
            if let Some(prev) = last_enqueued {
                let gap = s.enqueued.saturating_duration_since(prev).as_secs_f64();
                ewma_gap_s = 0.8 * ewma_gap_s + 0.2 * gap;
            }
            last_enqueued = Some(s.enqueued);
        }
        plock(&shared.stats).ewma_gap_s = ewma_gap_s;
        // An oversized lone submission cannot execute as ONE call — split
        // it across successive engine invocations and merge the results
        // onto its single ticket (variable per-prompt budgets make such
        // plans legitimate whenever a handle's quantum was floored at a
        // max-budget group larger than capacity / K).
        if rows_total > capacity {
            let g = subs.remove(0);
            debug_assert!(subs.is_empty(), "coalesced run cannot exceed capacity");
            dispatch(&pool, &shared, Plan::Split(g));
            continue;
        }
        dispatch(&pool, &shared, Plan::Call { subs, rows_total, deadline_fired });
    }
}

/// One logical engine call under the bounded per-plan retry: up to
/// `1 + retry_max` attempts with doubling backoff from `retry_backoff_ms`.
/// Every failed attempt counts as an observed fault; the retry counter
/// only moves when a retry is actually taken, so a fault-free run's
/// counters stay untouched and the first-attempt success path is
/// byte-identical to the pre-recovery scheduler.
fn generate_with_retry(
    engine: &mut dyn RolloutEngine,
    requests: &[GenRequest],
    temperature: f32,
    ctx: &ReplicaCtx,
) -> Result<GenResult> {
    let expected_groups = requests.len();
    let mut attempt = 0u32;
    loop {
        let result = engine.generate(requests, temperature).and_then(|res| {
            // A short groups vector would silently shift later tickets'
            // groups onto the wrong submissions — fail the whole call.
            anyhow::ensure!(
                res.groups.len() == expected_groups,
                "engine returned {} groups for {expected_groups} requests",
                res.groups.len()
            );
            Ok(res)
        });
        let err = match result {
            Ok(res) => return Ok(res),
            Err(e) => e,
        };
        {
            let mut stats = plock(&ctx.shared.stats);
            stats.faults_injected += 1;
            stats.replica_faults[ctx.r] += 1;
        }
        crate::trace::instant("fault", "replica", ctx.r as i64);
        if attempt >= ctx.recovery.retry_max {
            return Err(err);
        }
        let backoff = ctx.recovery.retry_backoff_ms.saturating_mul(1u64 << attempt.min(16));
        attempt += 1;
        plock(&ctx.shared.stats).retries += 1;
        crate::trace::instant("retry", "replica", attempt as i64);
        if backoff > 0 {
            std::thread::sleep(Duration::from_millis(backoff));
        }
    }
}

/// Resolve the shadow plan at execution end: `Ok(shadow)` when this
/// replica still owns the plan (it may deliver results or decide
/// failure), `Err(())` when the watchdog seized it mid-execution — a peer
/// owns it now, so the caller discards everything and the thread exits.
/// Seizure and claim are mutually exclusive under the pool lock, which is
/// what makes delivery exactly-once.
fn claim_inflight(ctx: &ReplicaCtx) -> Result<Option<Plan>, ()> {
    let mut ps = plock(&ctx.pool.state);
    if ps.abandoned[ctx.r] {
        ps.abandoned[ctx.r] = false;
        Err(())
    } else {
        ps.exec_started[ctx.r] = None;
        Ok(ps.inflight_plan[ctx.r].take())
    }
}

/// True when the watchdog seized this replica's plan (clears the flag —
/// the caller must discard its work and exit).
fn seized_by_watchdog(ctx: &ReplicaCtx) -> bool {
    let mut ps = plock(&ctx.pool.state);
    if ps.abandoned[ctx.r] {
        ps.abandoned[ctx.r] = false;
        true
    } else {
        false
    }
}

/// Execute one oversized submission as successive engine calls: requests
/// are chunked greedily (kept whole) under `capacity`, every chunk runs as
/// its own engine call, and the per-request groups are merged back into a
/// single [`GenResult`] for the submission's ticket. Cost and row
/// accounting sum over the chunks, so the ticket still pays the true
/// engine bill (including the extra per-call overheads the split costs).
fn execute_split(
    engine: &mut dyn RolloutEngine,
    g: GenWork,
    capacity: usize,
    ctx: &ReplicaCtx,
) -> ExecOutcome {
    let replica = ctx.r;
    let shared = ctx.shared;
    // A single request that alone exceeds capacity can never execute: a
    // caller error, not an engine fault — claim the shadow (so the
    // watchdog never redispatches it) and deliver the error.
    if let Some(req) = g.requests.iter().find(|r| r.n_samples > capacity) {
        if claim_inflight(ctx).is_err() {
            return ExecOutcome::Abandoned;
        }
        let _ = g.tx.send(Err(anyhow!(
            "request of {} samples exceeds engine capacity {capacity} (prompt {})",
            req.n_samples,
            req.prompt_idx
        )));
        return ExecOutcome::Done;
    }
    let mut chunks: Vec<Vec<GenRequest>> = Vec::new();
    let mut chunk: Vec<GenRequest> = Vec::new();
    let mut chunk_rows = 0usize;
    for req in g.requests {
        if chunk_rows + req.n_samples > capacity {
            chunks.push(std::mem::take(&mut chunk));
            chunk_rows = 0;
        }
        chunk_rows += req.n_samples;
        chunk.push(req);
    }
    if !chunk.is_empty() {
        chunks.push(chunk);
    }
    let started = Instant::now();
    let mut groups = Vec::new();
    let mut cost_s = 0.0f64;
    let mut weight_version = 0u64;
    for chunk in &chunks {
        // Zombie check between chunks: once seized, stop burning the
        // engine on work a peer now owns.
        if seized_by_watchdog(ctx) {
            return ExecOutcome::Abandoned;
        }
        let chunk_rows: usize = chunk.iter().map(|r| r.n_samples).sum();
        let chunk_started = Instant::now();
        let result = generate_with_retry(engine, chunk, g.temperature, ctx);
        // Unconditional end-of-call clock read: the exec histogram is
        // always on, so traced and untraced runs do identical work here.
        let chunk_finished = Instant::now();
        crate::trace::span_between(
            "engine-execute",
            "replica",
            chunk_started,
            chunk_finished,
            replica as i64,
        );
        {
            let mut stats = plock(&shared.stats);
            stats.calls += 1;
            stats.split_calls += 1;
            stats.rows_used += chunk_rows as u64;
            stats.rows_capacity += capacity as u64;
            stats.max_call_rows = stats.max_call_rows.max(chunk_rows as u64);
            stats.coalesced_hist[ServiceCounters::hist_bucket(1)] += 1;
            stats.replica_calls[replica] += 1;
            stats.replica_rows[replica] += chunk_rows as u64;
            stats.exec_hist[crate::trace::latency_bucket(
                chunk_finished.saturating_duration_since(chunk_started).as_secs_f64(),
            )] += 1;
        }
        match result {
            Ok(res) => {
                groups.extend(res.groups);
                cost_s += res.cost_s;
                weight_version = res.weight_version;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let Ok(shadow) = claim_inflight(ctx) else {
                    return ExecOutcome::Abandoned;
                };
                if ctx.recovery.active() {
                    if let Some(p) = shadow {
                        return ExecOutcome::Failed { seized: Box::new(p), msg };
                    }
                }
                let _ = g.tx.send(Err(anyhow!("split inference call failed: {msg}")));
                return ExecOutcome::Done;
            }
        }
    }
    if claim_inflight(ctx).is_err() {
        return ExecOutcome::Abandoned;
    }
    {
        let mut stats = plock(&shared.stats);
        stats.submissions += 1;
        let wait_s = started.saturating_duration_since(g.enqueued).as_secs_f64();
        stats.queue_wait_s += wait_s;
        stats.queue_wait_hist[crate::trace::latency_bucket(wait_s)] += 1;
    }
    let _ = g.tx.send(Ok(GenResult { groups, cost_s, rows_used: g.rows, weight_version }));
    ExecOutcome::Done
}

/// Execute one coalesced call and fan the results back out per ticket.
fn execute_call(
    engine: &mut dyn RolloutEngine,
    mut subs: Vec<GenWork>,
    rows_total: usize,
    capacity: usize,
    deadline_fired: bool,
    ctx: &ReplicaCtx,
) -> ExecOutcome {
    let replica = ctx.r;
    let shared = ctx.shared;
    let temperature = subs[0].temperature;
    // Drain, don't clone: the submissions are owned and only their request
    // counts are needed for the fan-out split (the redispatchable copy
    // already sits in the pool's shadow slot).
    let n_requests: Vec<usize> = subs.iter().map(|s| s.requests.len()).collect();
    let merged: Vec<GenRequest> = subs.iter_mut().flat_map(|s| s.requests.drain(..)).collect();
    let started = Instant::now();
    let result = generate_with_retry(engine, &merged, temperature, ctx);
    // Unconditional end-of-call clock read: the exec histogram is always
    // on, so traced and untraced runs do identical work here.
    let finished = Instant::now();
    crate::trace::span_between("engine-execute", "replica", started, finished, replica as i64);
    let Ok(shadow) = claim_inflight(ctx) else {
        return ExecOutcome::Abandoned;
    };
    {
        let mut stats = plock(&shared.stats);
        stats.calls += 1;
        stats.submissions += subs.len() as u64;
        stats.rows_used += rows_total as u64;
        stats.rows_capacity += capacity as u64;
        stats.max_call_rows = stats.max_call_rows.max(rows_total as u64);
        stats.coalesced_hist[ServiceCounters::hist_bucket(subs.len())] += 1;
        stats.replica_calls[replica] += 1;
        stats.replica_rows[replica] += rows_total as u64;
        stats.exec_hist[crate::trace::latency_bucket(
            finished.saturating_duration_since(started).as_secs_f64(),
        )] += 1;
        if deadline_fired {
            stats.deadline_dispatches += 1;
        }
        for s in &subs {
            let wait_s = started.saturating_duration_since(s.enqueued).as_secs_f64();
            stats.queue_wait_s += wait_s;
            stats.queue_wait_hist[crate::trace::latency_bucket(wait_s)] += 1;
        }
    }
    match result {
        Ok(res) => {
            // Fan out: per-request groups split by submission, inference
            // cost apportioned by row share with the last ticket taking the
            // exact remainder (per-worker counters sum to the true cost).
            let mut groups = res.groups.into_iter();
            let mut cost_left = res.cost_s;
            let n = subs.len();
            for (i, s) in subs.into_iter().enumerate() {
                let share = if i + 1 == n {
                    cost_left
                } else {
                    res.cost_s * s.rows as f64 / rows_total.max(1) as f64
                };
                cost_left -= share;
                let out = GenResult {
                    groups: groups.by_ref().take(n_requests[i]).collect(),
                    cost_s: share,
                    rows_used: s.rows,
                    weight_version: res.weight_version,
                };
                let _ = s.tx.send(Ok(out));
            }
            ExecOutcome::Done
        }
        Err(e) => {
            let msg = format!("{e:#}");
            if ctx.recovery.active() {
                if let Some(p) = shadow {
                    // Hand the plan back for redispatch: a healthy peer
                    // may well serve what this replica could not.
                    return ExecOutcome::Failed { seized: Box::new(p), msg };
                }
            }
            for s in subs {
                let _ = s.tx.send(Err(anyhow!("coalesced inference call failed: {msg}")));
            }
            ExecOutcome::Done
        }
    }
}

/// The serial trainer's view of a serviced run: the inference half goes
/// through a [`SubmitHandle`] (one producer, so every call carries exactly
/// one submission), the learner half stays on the real policy, and every
/// `train` re-publishes the snapshot so the service engine tracks the
/// learner exactly — the bit-for-bit equivalence rail of DESIGN.md §8.
pub struct ServicedPolicy<'a, P: Trainable> {
    handle: SubmitHandle,
    learner: &'a mut P,
}

impl<'a, P: Trainable> ServicedPolicy<'a, P> {
    pub fn new(handle: SubmitHandle, learner: &'a mut P) -> ServicedPolicy<'a, P> {
        ServicedPolicy { handle, learner }
    }
}

impl<P: Trainable> RolloutEngine for ServicedPolicy<'_, P> {
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult> {
        self.handle.generate(requests, temperature)
    }

    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult> {
        self.handle.evaluate(tasks)
    }

    fn rollout_capacity(&self) -> usize {
        self.handle.rollout_capacity()
    }

    fn gen_len(&self) -> usize {
        self.handle.gen_len()
    }

    fn install(&mut self, snap: &WeightSnapshot) {
        self.handle.install(snap);
    }

    fn serving_version(&self) -> u64 {
        self.handle.serving_version()
    }

    fn name(&self) -> &str {
        self.handle.name()
    }
}

impl<P: Trainable> Trainable for ServicedPolicy<'_, P> {
    fn train(&mut self, groups: &[PromptGroup], algo: &AlgoConfig) -> Result<TrainResult> {
        let tr = self.learner.train(groups, algo)?;
        // Sync point: the serial loop expects the next collect to run under
        // the post-update weights, exactly as when engine == learner.
        self.handle.install(&self.learner.snapshot());
        Ok(tr)
    }

    fn train_capacity(&self) -> usize {
        self.learner.train_capacity()
    }

    fn weight_version(&self) -> u64 {
        self.learner.weight_version()
    }

    fn snapshot(&self) -> WeightSnapshot {
        self.learner.snapshot()
    }

    // Warm-resume persistence delegates to the learner — the service owns
    // no run state of its own. After restoring, re-publish the snapshot:
    // the replica engines were forked from the pre-restore learner and
    // must serve the restored weights for the next collect.

    fn state_json(&self) -> Option<crate::util::json::Json> {
        self.learner.state_json()
    }

    fn restore_state_json(&mut self, state: &crate::util::json::Json) -> Result<()> {
        self.learner.restore_state_json(state)?;
        self.handle.install(&self.learner.snapshot());
        Ok(())
    }

    fn save_params(&self, dir: &std::path::Path, tag: &str) -> Result<()> {
        self.learner.save_params(dir, tag)
    }

    fn load_params(&mut self, dir: &std::path::Path, tag: &str) -> Result<()> {
        self.learner.load_params(dir, tag)?;
        self.handle.install(&self.learner.snapshot());
        Ok(())
    }

    fn params_token(&self) -> Option<u64> {
        self.learner.params_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, TaskFamily};
    use crate::rl::update::Rollout;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    /// Deterministic engine: reward = 1.0 for every rollout, cost 1.0 per
    /// call + 0.1 per row; records per-call row counts and installs.
    /// `delay_ms` simulates execution time (pool tests pace replicas with
    /// it to make dispatch/steal interleavings deterministic).
    struct CountingEngine {
        capacity: usize,
        calls: Arc<Mutex<Vec<usize>>>,
        installs: Arc<AtomicUsize>,
        version: u64,
        delay_ms: u64,
    }

    impl RolloutEngine for CountingEngine {
        fn generate(&mut self, requests: &[GenRequest], _t: f32) -> Result<GenResult> {
            let rows_used: usize = requests.iter().map(|r| r.n_samples).sum();
            anyhow::ensure!(rows_used <= self.capacity, "call exceeds capacity");
            if self.delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(self.delay_ms));
            }
            self.calls.lock().unwrap().push(rows_used);
            let groups = requests
                .iter()
                .map(|req| {
                    (0..req.n_samples)
                        .map(|_| Rollout {
                            gen_tokens: vec![2],
                            gen_logprobs: vec![-0.1],
                            reward: 1.0,
                        })
                        .collect()
                })
                .collect();
            Ok(GenResult {
                groups,
                cost_s: 1.0 + 0.1 * rows_used as f64,
                rows_used,
                weight_version: self.version,
            })
        }

        fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult> {
            Ok(EvalResult { accuracy: 0.25, cost_s: tasks.len() as f64 })
        }

        fn rollout_capacity(&self) -> usize {
            self.capacity
        }

        fn gen_len(&self) -> usize {
            4
        }

        fn install(&mut self, snap: &WeightSnapshot) {
            self.installs.fetch_add(1, Ordering::Relaxed);
            self.version = snap.version;
        }

        fn serving_version(&self) -> u64 {
            self.version
        }

        fn name(&self) -> &str {
            "counting"
        }
    }

    type TestEngine = (Box<dyn RolloutEngine + Send>, Arc<Mutex<Vec<usize>>>, Arc<AtomicUsize>);

    fn engine(capacity: usize) -> TestEngine {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let installs = Arc::new(AtomicUsize::new(0));
        let e = CountingEngine {
            capacity,
            calls: Arc::clone(&calls),
            installs: Arc::clone(&installs),
            version: 0,
            delay_ms: 0,
        };
        (Box::new(e), calls, installs)
    }

    type TestPool =
        (Vec<Box<dyn RolloutEngine + Send>>, Arc<Mutex<Vec<usize>>>, Arc<AtomicUsize>);

    /// A pool of replicas over shared call/install counters, one entry per
    /// replica in `delays_ms` (its simulated execution time — pool tests
    /// pace replicas unevenly to pin down dispatch/steal interleavings).
    fn pool_engines(capacity: usize, delays_ms: &[u64]) -> TestPool {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let installs = Arc::new(AtomicUsize::new(0));
        let engines = delays_ms
            .iter()
            .map(|&delay_ms| {
                Box::new(CountingEngine {
                    capacity,
                    calls: Arc::clone(&calls),
                    installs: Arc::clone(&installs),
                    version: 0,
                    delay_ms,
                }) as Box<dyn RolloutEngine + Send>
            })
            .collect();
        (engines, calls, installs)
    }

    fn reqs(rng: &mut Rng, n_prompts: usize, n_samples: usize) -> Vec<GenRequest> {
        (0..n_prompts)
            .map(|i| GenRequest {
                prompt_idx: i,
                task: generate(rng, TaskFamily::Add, 3, 20),
                n_samples,
            })
            .collect()
    }

    #[test]
    fn single_producer_passes_calls_through_unchanged() {
        let (e, calls, _) = engine(64);
        let service = InferenceService::spawn(e, ServiceConfig::default(), 1, 8);
        assert_eq!(service.quantum(), 64);
        let mut h = service.handle();
        let mut rng = Rng::new(1);
        let r = reqs(&mut rng, 3, 4);
        let res = h.generate(&r, 1.0).unwrap();
        assert_eq!(res.groups.len(), 3);
        assert_eq!(res.rows_used, 12);
        assert!((res.cost_s - 2.2).abs() < 1e-12, "full cost to the only ticket");
        let stats = service.stats();
        assert_eq!(stats.calls, 1);
        assert_eq!(stats.submissions, 1);
        assert_eq!(stats.coalesced_hist[0], 1);
        assert_eq!(calls.lock().unwrap().as_slice(), &[12]);
    }

    #[test]
    fn concurrent_submissions_coalesce_and_split_correctly() {
        let (e, calls, _) = engine(64);
        let cfg = ServiceConfig {
            coalesce_wait_ms: 200,
            fill_waterline: 1.0,
            ..ServiceConfig::default()
        };
        let service = InferenceService::spawn(e, cfg, 4, 8);
        assert_eq!(service.quantum(), 16);
        let mut rng = Rng::new(2);
        // Submit 4 tickets without waiting, then wait all: the scheduler
        // must merge them (waterline 64 rows = 4 x 16) into ONE call.
        let tickets: Vec<Ticket> =
            (0..4).map(|_| service.handle().submit(reqs(&mut rng, 4, 4), 1.0)).collect();
        let results: Vec<GenResult> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        assert_eq!(calls.lock().unwrap().as_slice(), &[64], "expected one coalesced call");
        let total_cost: f64 = results.iter().map(|r| r.cost_s).sum();
        assert!((total_cost - (1.0 + 0.1 * 64.0)).abs() < 1e-9, "cost not conserved");
        for r in &results {
            assert_eq!(r.groups.len(), 4, "per-ticket group split broken");
            assert_eq!(r.rows_used, 16);
            assert!(r.groups.iter().all(|g| g.len() == 4));
        }
        let stats = service.stats();
        assert_eq!(stats.calls, 1);
        assert_eq!(stats.submissions, 4);
        assert_eq!(stats.max_call_rows, 64);
        assert_eq!(stats.coalesced_hist[3], 1);
        assert_eq!(stats.deadline_dispatches, 0, "waterline, not deadline, dispatched");
    }

    #[test]
    fn deadline_rescues_an_unreachable_waterline() {
        let (e, calls, _) = engine(64);
        // Waterline requires 64 rows but only one 8-row submission will
        // ever arrive: the deadline must fire or the ticket starves.
        let cfg =
            ServiceConfig { coalesce_wait_ms: 5, fill_waterline: 1.0, ..ServiceConfig::default() };
        let service = InferenceService::spawn(e, cfg, 4, 8);
        let mut rng = Rng::new(3);
        let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
        assert_eq!(res.rows_used, 8);
        assert_eq!(calls.lock().unwrap().as_slice(), &[8]);
        assert_eq!(service.stats().deadline_dispatches, 1);
    }

    #[test]
    fn installs_dedupe_by_version_across_handles() {
        let (e, _, installs) = engine(64);
        let service = InferenceService::spawn(e, ServiceConfig::default(), 4, 8);
        let snap = WeightSnapshot { version: 3, values: vec![] };
        for _ in 0..4 {
            service.handle().install(&snap); // K workers, same snapshot
        }
        let mut h = service.handle();
        assert_eq!(h.serving_version(), 3);
        let mut rng = Rng::new(4);
        let res = h.generate(&reqs(&mut rng, 1, 4), 1.0).unwrap();
        assert_eq!(res.weight_version, 3, "call must run under the installed version");
        assert_eq!(installs.load(Ordering::Relaxed), 1, "engine installed more than once");
        // A stale snapshot is ignored entirely.
        service.handle().install(&WeightSnapshot { version: 2, values: vec![] });
        assert_eq!(service.handle().serving_version(), 3);
    }

    #[test]
    fn evaluate_routes_through_the_service_engine() {
        let (e, _, _) = engine(64);
        let service = InferenceService::spawn(e, ServiceConfig::default(), 2, 8);
        let mut h = service.handle();
        let mut rng = Rng::new(5);
        let tasks: Vec<TaskInstance> =
            (0..3).map(|_| generate(&mut rng, TaskFamily::Add, 2, 20)).collect();
        let res = h.evaluate(&tasks).unwrap();
        assert_eq!(res.accuracy, 0.25);
        assert_eq!(service.stats().calls, 0, "evaluation must not count as a rollout call");
    }

    #[test]
    fn closed_service_fails_tickets_instead_of_hanging() {
        let (e, _, _) = engine(64);
        let service = InferenceService::spawn(e, ServiceConfig::default(), 1, 8);
        let h = service.handle();
        service.close();
        let mut rng = Rng::new(6);
        let err = h.submit(reqs(&mut rng, 1, 4), 1.0).wait();
        assert!(err.is_err());
    }

    #[test]
    fn oversized_submission_splits_across_successive_calls() {
        let (e, calls, _) = engine(16);
        let service = InferenceService::spawn(e, ServiceConfig::default(), 1, 8);
        let mut rng = Rng::new(7);
        // 5 prompts x 4 samples = 20 rows > capacity 16: the scheduler must
        // split the plan across engine calls (16 + 4) and merge the results
        // onto the one ticket, instead of refusing it.
        let res = service.handle().submit(reqs(&mut rng, 5, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 5, "all requests served");
        assert!(res.groups.iter().all(|g| g.len() == 4));
        assert_eq!(res.rows_used, 20);
        // cost sums both calls: 2 overheads + 0.1 per row
        assert!((res.cost_s - (2.0 + 0.1 * 20.0)).abs() < 1e-9, "cost {}", res.cost_s);
        assert_eq!(calls.lock().unwrap().as_slice(), &[16, 4]);
        let stats = service.stats();
        assert_eq!(stats.calls, 2);
        assert_eq!(stats.split_calls, 2);
        assert_eq!(stats.submissions, 1);
        assert_eq!(stats.max_call_rows, 16);
        // and the service keeps serving normal submissions afterwards
        let ok = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait();
        assert!(ok.is_ok());
    }

    #[test]
    fn single_request_beyond_capacity_still_errors() {
        let (e, calls, _) = engine(16);
        let service = InferenceService::spawn(e, ServiceConfig::default(), 1, 8);
        let mut rng = Rng::new(8);
        // One request of 20 samples cannot be split (requests stay whole).
        let err = service.handle().submit(reqs(&mut rng, 1, 20), 1.0).wait();
        assert!(err.is_err());
        assert!(calls.lock().unwrap().is_empty(), "no engine call for an unservable request");
    }

    #[test]
    fn adaptive_deadline_serves_and_tracks_the_submission_gap() {
        let (e, calls, _) = engine(64);
        let cfg = ServiceConfig {
            coalesce_wait_ms: 5,
            fill_waterline: 1.0,
            adaptive: true,
            ..ServiceConfig::default()
        };
        let service = InferenceService::spawn(e, cfg, 2, 8);
        let mut rng = Rng::new(9);
        for _ in 0..4 {
            let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
            assert_eq!(res.rows_used, 8);
        }
        assert_eq!(calls.lock().unwrap().len(), 4);
        let stats = service.stats();
        assert_eq!(stats.submissions, 4);
        // The gap EWMA was updated away from its deadline-seeded value and
        // stays a sane non-negative duration.
        assert!(stats.ewma_gap_s >= 0.0);
        assert!(stats.ewma_gap_s < 10.0, "gap EWMA diverged: {}", stats.ewma_gap_s);
    }

    #[test]
    fn pool_spreads_concurrent_calls_across_replicas() {
        // Two slow replicas, two producers issuing full-capacity calls
        // back to back: the second dispatch must see replica 0 loaded and
        // pick replica 1 (least-loaded routing).
        let (engines, calls, _) = pool_engines(16, &[30, 30]);
        let cfg = ServiceConfig {
            coalesce_wait_ms: 50,
            fill_waterline: 1.0,
            ..ServiceConfig::default()
        };
        let service = InferenceService::spawn_pool(engines, cfg, 2, 8);
        // quantum scales with the pool: capacity x E / producers
        assert_eq!(service.quantum(), 16);
        let mut rng = Rng::new(11);
        let t0 = service.handle().submit(reqs(&mut rng, 4, 4), 1.0);
        let t1 = service.handle().submit(reqs(&mut rng, 4, 4), 1.0);
        t0.wait().unwrap();
        t1.wait().unwrap();
        let stats = service.stats();
        assert_eq!(stats.engines, 2);
        assert_eq!(stats.calls, 2);
        assert_eq!(calls.lock().unwrap().as_slice(), &[16, 16]);
        assert_eq!(stats.replica_calls[0], 1, "first call on replica 0");
        assert_eq!(stats.replica_calls[1], 1, "second call routed to the idle replica");
        assert_eq!(stats.replica_rows[0], 16);
        assert_eq!(stats.replica_rows[1], 16);
        // Pool-balance telemetry: first dispatch saw 0 busy replicas, the
        // second saw 1.
        assert_eq!(stats.pool_dispatches, 2);
        assert_eq!(stats.pool_hist[0], 1);
        assert_eq!(stats.pool_hist[1], 1);
        assert!((stats.pool_balance() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn drained_replica_steals_queued_plans() {
        // Replica 0 is 10x slower than replica 1. Three full-capacity
        // submissions: s0 -> replica 0, s1 -> replica 1, s2 queues behind
        // the slow replica 0 (load tie, lowest index). Replica 1 drains
        // first and must steal s2 instead of idling.
        let (engines, calls, _) = pool_engines(16, &[100, 10]);
        let cfg =
            ServiceConfig { coalesce_wait_ms: 1, fill_waterline: 1.0, ..ServiceConfig::default() };
        let service = InferenceService::spawn_pool(engines, cfg, 3, 8);
        let mut rng = Rng::new(12);
        let tickets: Vec<Ticket> =
            (0..3).map(|_| service.handle().submit(reqs(&mut rng, 4, 4), 1.0)).collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().rows_used, 16);
        }
        let stats = service.stats();
        assert_eq!(stats.calls, 3);
        assert_eq!(calls.lock().unwrap().len(), 3);
        assert_eq!(stats.steals, 1, "the drained replica must pull queued work");
        assert_eq!(stats.replica_steals[1], 1);
        assert_eq!(stats.replica_calls[0], 1, "slow replica served only its first plan");
        assert_eq!(stats.replica_calls[1], 2, "fast replica served its own plan + the steal");
        assert!(stats.pool_balance() > 0.0);
    }

    #[test]
    fn work_stealing_preserves_each_producers_fifo_order() {
        // One producer issues 20 distinguishable submissions (row counts
        // cycle 1..=5) without waiting in between; two unevenly-paced
        // replicas coalesce, dispatch, and steal underneath. Every ticket
        // must still receive ITS OWN groups — sizes pair up exactly with
        // the submission order, whatever replica executed it.
        let (engines, _, _) = pool_engines(8, &[3, 0]);
        let cfg = ServiceConfig::default();
        let service = InferenceService::spawn_pool(engines, cfg, 2, 4);
        let mut rng = Rng::new(13);
        let h = service.handle();
        let submitted: Vec<(usize, Ticket)> = (0..20)
            .map(|i| {
                let n = (i % 5) + 1;
                (n, h.submit(reqs(&mut rng, 1, n), 1.0))
            })
            .collect();
        for (n, t) in submitted {
            let res = t.wait().unwrap();
            assert_eq!(res.rows_used, n, "ticket answered with another submission's rows");
            assert_eq!(res.groups.len(), 1);
            assert_eq!(res.groups[0].len(), n);
        }
        let stats = service.stats();
        assert_eq!(stats.submissions, 20);
        assert_eq!(stats.rows_used, 60, "sum of 4 cycles of 1+2+3+4+5");
    }

    #[test]
    fn replica_never_serves_a_version_newer_than_announced() {
        // Interleave installs of increasing versions with generates across
        // an unevenly-paced E=2 pool. Installs jump the queue and publish
        // before any later dispatch, and a replica installs lazily before
        // executing — so every result carries exactly the version announced
        // at submit time, and per-replica installed versions never exceed
        // the announced version.
        let (engines, _, installs) = pool_engines(16, &[5, 0]);
        let service = InferenceService::spawn_pool(engines, ServiceConfig::default(), 2, 8);
        let mut h = service.handle();
        let mut rng = Rng::new(14);
        for v in 1..=10u64 {
            h.install(&WeightSnapshot { version: v, values: vec![] });
            let t0 = h.submit(reqs(&mut rng, 1, 2), 1.0);
            let t1 = h.submit(reqs(&mut rng, 1, 2), 1.0);
            for t in [t0, t1] {
                let res = t.wait().unwrap();
                assert!(
                    res.weight_version <= h.serving_version(),
                    "replica served v{} > announced v{}",
                    res.weight_version,
                    h.serving_version()
                );
                assert_eq!(res.weight_version, v, "post-install generate must run under v{v}");
            }
        }
        let stats = service.stats();
        for r in 0..2 {
            assert!(
                stats.replica_weight_version[r] <= 10,
                "replica {r} reports v{} beyond announced v10",
                stats.replica_weight_version[r]
            );
        }
        // Each replica installs each version at most once (idle replicas
        // may batch-skip intermediate versions, executing replicas install
        // lazily exactly once per version they serve).
        let n = installs.load(Ordering::Relaxed) as u64;
        assert!((10..=20).contains(&n), "unexpected install count {n}");
        assert_eq!(stats.installs, n);
    }

    use crate::policy::fault::FaultPlan;

    /// Recovery-enabled baseline (bounded retry) plus a scripted plan.
    fn recovery(plan: &str) -> RecoveryConfig {
        RecoveryConfig { fault_plan: FaultPlan::parse(plan).unwrap(), ..RecoveryConfig::default() }
    }

    #[test]
    fn transient_fault_retries_and_succeeds() {
        let (e, calls, _) = engine(64);
        let service = InferenceService::spawn_pool_with_recovery(
            vec![e],
            Vec::new(),
            ServiceConfig::default(),
            recovery("err@0:0"),
            1,
            8,
        );
        let mut rng = Rng::new(20);
        // Call 0 fails (injected), the bounded retry replays as call 1.
        let res = service.handle().submit(reqs(&mut rng, 3, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 3);
        assert_eq!(res.rows_used, 12);
        let stats = service.stats();
        assert_eq!(stats.faults_injected, 1);
        assert_eq!(stats.retries, 1);
        assert_eq!(stats.replica_faults[0], 1);
        assert_eq!(stats.calls, 1, "retries stay inside one logical call");
        assert_eq!(calls.lock().unwrap().as_slice(), &[12], "inner engine served once");
    }

    #[test]
    fn retry_exhaustion_quarantines_and_redispatches_to_a_peer() {
        // Replica 0's only call fails with no retry budget: the plan must
        // move to replica 1 and the ticket still be served, while the
        // quantum shrinks to the degraded pool's capacity.
        let (engines, _, _) = pool_engines(16, &[0, 0]);
        let mut rec = recovery("err@0:0");
        rec.retry_max = 0;
        let service = InferenceService::spawn_pool_with_recovery(
            engines,
            Vec::new(),
            ServiceConfig::default(),
            rec,
            2,
            4,
        );
        assert_eq!(service.quantum(), 16);
        let mut rng = Rng::new(21);
        let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 2, "redispatched plan served exactly once");
        let stats = service.stats();
        assert_eq!(stats.faults_injected, 1);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.quarantines, 1);
        assert_eq!(stats.redispatches, 1);
        assert_eq!(stats.replica_faults[0], 1);
        assert_eq!(service.quantum(), 8, "quantum recomputed for the degraded pool");
        // The survivor keeps serving later submissions.
        assert!(service.handle().submit(reqs(&mut rng, 1, 4), 1.0).wait().is_ok());
    }

    #[test]
    fn hard_death_is_contained_and_a_spare_respawns() {
        // Replica 0 panics mid-call. The panic must convert into
        // quarantine + redispatch (ticket served by the peer), and the
        // pre-forked spare must be activated to restore pool capacity.
        let (engines, _, _) = pool_engines(16, &[0, 0]);
        let (spare, _, _) = engine(16);
        let mut rec = recovery("die@0:0");
        rec.respawn = true;
        let service = InferenceService::spawn_pool_with_recovery(
            engines,
            vec![spare],
            ServiceConfig::default(),
            rec,
            2,
            4,
        );
        let mut rng = Rng::new(22);
        let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 2, "plan survived the replica death exactly once");
        let stats = service.stats();
        assert_eq!(stats.faults_injected, 1);
        assert_eq!(stats.quarantines, 1);
        assert_eq!(stats.redispatches, 1);
        assert_eq!(stats.respawns, 1, "the spare must be admitted");
        assert_eq!(service.quantum(), 16, "respawn restores full pool capacity");
        // The pool (peer + respawned spare) keeps serving.
        for _ in 0..4 {
            assert!(service.handle().submit(reqs(&mut rng, 1, 4), 1.0).wait().is_ok());
        }
    }

    #[test]
    fn watchdog_seizes_a_stalled_replica_and_a_peer_delivers() {
        // Replica 0 stalls 500ms on its first call; the 50ms execute
        // watchdog must quarantine it and hand the plan to replica 1 long
        // before the stall ends — and the zombie's eventual result must be
        // discarded, not double-delivered.
        let (engines, _, _) = pool_engines(16, &[0, 0]);
        let mut rec = recovery("stall@0:0:500");
        rec.exec_timeout_ms = 50;
        let service = InferenceService::spawn_pool_with_recovery(
            engines,
            Vec::new(),
            ServiceConfig::default(),
            rec,
            2,
            4,
        );
        let mut rng = Rng::new(23);
        let t0 = Instant::now();
        let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 2);
        assert!(
            t0.elapsed() < Duration::from_millis(400),
            "ticket waited out the stall instead of being redispatched"
        );
        let stats = service.stats();
        assert_eq!(stats.quarantines, 1, "stalled replica must be quarantined");
        assert_eq!(stats.redispatches, 1);
        assert_eq!(stats.faults_injected, 1);
        assert!(service.handle().submit(reqs(&mut rng, 1, 4), 1.0).wait().is_ok());
    }

    #[test]
    fn last_replica_fails_gracefully_and_keeps_serving() {
        // E=1 with every retry exhausted: no peer exists, so the error
        // goes to the ticket (single-engine behaviour) and the replica
        // stays live for the next submission.
        let (e, _, _) = engine(64);
        let service = InferenceService::spawn_pool_with_recovery(
            vec![e],
            Vec::new(),
            ServiceConfig::default(),
            recovery("err@0:0,err@0:1,err@0:2"),
            1,
            8,
        );
        let mut rng = Rng::new(24);
        let err = service.handle().submit(reqs(&mut rng, 1, 4), 1.0).wait().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("coalesced inference call failed"), "{msg}");
        assert!(msg.contains("injected transient fault"), "{msg}");
        let stats = service.stats();
        assert_eq!(stats.faults_injected, 3, "initial attempt + 2 retries all faulted");
        assert_eq!(stats.retries, 2);
        assert_eq!(stats.quarantines, 0, "the last replica must never quarantine itself");
        // Call index 3 has no scripted fault: service still serves.
        let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 2);
    }

    #[test]
    fn scheduler_death_unblocks_every_waiter_with_a_typed_error() {
        // Kill the scheduler while producers are mid-flight: every blocked
        // `wait` must return (served or typed error), and later submissions
        // must fail fast instead of hanging on a dead queue.
        let (engines, _, _) = pool_engines(16, &[20, 20]);
        let service = InferenceService::spawn_pool(engines, ServiceConfig::default(), 2, 4);
        let mut rng = Rng::new(25);
        let producers: Vec<std::thread::JoinHandle<Vec<String>>> = (0..2)
            .map(|_| {
                let h = service.handle();
                let r = reqs(&mut rng, 1, 4);
                std::thread::spawn(move || {
                    let mut errs = Vec::new();
                    for _ in 0..20 {
                        if let Err(e) = h.submit(r.clone(), 1.0).wait() {
                            errs.push(format!("{e:#}"));
                        }
                    }
                    errs
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        service.kill_scheduler();
        // The join IS the regression: a hung waiter would deadlock here.
        for p in producers {
            for msg in p.join().expect("producer thread must finish") {
                assert!(
                    msg.contains("scheduler panicked") || msg.contains("closed"),
                    "unexpected error shape: {msg}"
                );
            }
        }
        let err = service
            .handle()
            .submit(reqs(&mut rng, 1, 4), 1.0)
            .wait()
            .expect_err("post-crash submissions must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("scheduler panicked") || msg.contains("closed"), "{msg}");
    }

    #[test]
    fn batching_mode_parse_lists_valid_modes() {
        assert_eq!(BatchingMode::parse_or_err("deadline").unwrap(), BatchingMode::Deadline);
        assert_eq!(BatchingMode::parse_or_err("slots").unwrap(), BatchingMode::Slots);
        assert_eq!(BatchingMode::default(), BatchingMode::Deadline);
        assert_eq!(BatchingMode::Slots.name(), "slots");
        let err = BatchingMode::parse_or_err("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        for name in BatchingMode::NAMES {
            assert!(err.contains(name), "mode '{name}' missing from the error: {err}");
        }
    }

    #[test]
    fn slots_mode_admits_each_submission_as_its_own_call() {
        // Slots mode with 4 producers: the quantum grows to full engine
        // capacity and every submission is admitted the moment the router
        // sees it, as its own call — no coalescing, no deadline.
        let (e, calls, _) = engine(64);
        let cfg = ServiceConfig { batching: BatchingMode::Slots, ..ServiceConfig::default() };
        let service = InferenceService::spawn(e, cfg, 4, 8);
        assert_eq!(service.quantum(), 64, "slots mode advertises full capacity per producer");
        let mut rng = Rng::new(30);
        let tickets: Vec<Ticket> =
            (0..4).map(|_| service.handle().submit(reqs(&mut rng, 4, 4), 1.0)).collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().rows_used, 16);
        }
        let stats = service.stats();
        assert_eq!(stats.calls, 4, "one engine call per admitted submission");
        assert_eq!(stats.submissions, 4);
        assert_eq!(stats.coalesced_hist[0], 4, "every call carries exactly one submission");
        assert_eq!(stats.deadline_dispatches, 0, "no gather deadline exists to fire");
        assert_eq!(stats.slots_mode, 1);
        assert_eq!(stats.slot_admissions, 4);
        assert_eq!(stats.slot_retires, 4, "every admitted slot must retire");
        assert!(stats.mean_slot_occupancy() > 0.0);
        assert_eq!(calls.lock().unwrap().as_slice(), &[16, 16, 16, 16]);
    }

    #[test]
    fn slot_admission_and_steal_preserve_each_producers_fifo_order() {
        // The slots-mode twin of the deadline FIFO property test above: 20
        // distinguishable submissions admitted one per call across two
        // unevenly-paced replicas (stealing underneath). Every ticket must
        // still receive ITS OWN groups, in submission order.
        let (engines, _, _) = pool_engines(8, &[3, 0]);
        let cfg = ServiceConfig { batching: BatchingMode::Slots, ..ServiceConfig::default() };
        let service = InferenceService::spawn_pool(engines, cfg, 2, 4);
        let mut rng = Rng::new(31);
        let h = service.handle();
        let submitted: Vec<(usize, Ticket)> = (0..20)
            .map(|i| {
                let n = (i % 5) + 1;
                (n, h.submit(reqs(&mut rng, 1, n), 1.0))
            })
            .collect();
        for (n, t) in submitted {
            let res = t.wait().unwrap();
            assert_eq!(res.rows_used, n, "ticket answered with another submission's rows");
            assert_eq!(res.groups.len(), 1);
            assert_eq!(res.groups[0].len(), n);
        }
        let stats = service.stats();
        assert_eq!(stats.submissions, 20);
        assert_eq!(stats.calls, 20, "slots mode never merges submissions");
        assert_eq!(stats.rows_used, 60, "sum of 4 cycles of 1+2+3+4+5");
        assert_eq!(stats.slot_admissions, 20);
        assert_eq!(stats.slot_retires, 20);
    }

    #[test]
    fn slots_mode_redispatches_a_seized_slot_exactly_once() {
        // Replica 0's admitted slot fails with no retry budget: the slot
        // must be re-admitted on the peer exactly once and the ticket
        // still served — admissions count both placements, retires only
        // the completion.
        let (engines, _, _) = pool_engines(16, &[0, 0]);
        let mut rec = recovery("err@0:0");
        rec.retry_max = 0;
        let cfg = ServiceConfig { batching: BatchingMode::Slots, ..ServiceConfig::default() };
        let service =
            InferenceService::spawn_pool_with_recovery(engines, Vec::new(), cfg, rec, 2, 4);
        assert_eq!(service.quantum(), 16, "slots quantum is full engine capacity");
        let mut rng = Rng::new(32);
        let res = service.handle().submit(reqs(&mut rng, 2, 4), 1.0).wait().unwrap();
        assert_eq!(res.groups.len(), 2, "redispatched slot served exactly once");
        let stats = service.stats();
        assert_eq!(stats.quarantines, 1);
        assert_eq!(stats.redispatches, 1);
        assert_eq!(stats.slot_admissions, 2, "original admission + the redispatch");
        assert_eq!(stats.slot_retires, 1, "only the completed placement retires");
        assert_eq!(service.quantum(), 16, "a degraded slots pool still advertises capacity");
    }
}
