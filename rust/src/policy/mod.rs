//! Policy abstraction: the coordinator talks to a model through these
//! traits, so the *same* SPEED scheduler drives both the real PJRT
//! transformer ([`real::RealPolicy`]) and the IRT simulator
//! ([`sim::SimPolicy`]) used for paper-scale benchmark regeneration.

pub mod real;
pub mod sampler;
pub mod sim;

use anyhow::Result;

use crate::data::tasks::TaskInstance;
use crate::rl::algo::AlgoConfig;
use crate::rl::update::{PromptGroup, Rollout};

/// One generation request: `n_samples` rollouts for one prompt.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Index into the active training dataset (carried through for
    /// bookkeeping; the policy does not interpret it).
    pub prompt_idx: usize,
    pub task: TaskInstance,
    pub n_samples: usize,
}

/// Result of one batched inference call.
#[derive(Debug)]
pub struct GenResult {
    /// Per-request rollouts, same order as the request slice. Rewards are
    /// already verified (binary, eq. 2).
    pub groups: Vec<Vec<Rollout>>,
    /// Inference cost in seconds — wall-clock for the real policy, the cost
    /// model's virtual time for the simulator.
    pub cost_s: f64,
    /// Rows of the fixed-shape call actually carrying data.
    pub rows_used: usize,
}

/// Result of one RL update step.
#[derive(Clone, Copy, Debug)]
pub struct TrainResult {
    pub loss: f64,
    pub grad_norm: f64,
    pub clip_frac: f64,
    pub cost_s: f64,
}

/// Result of an evaluation pass.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub accuracy: f64,
    pub cost_s: f64,
}

/// The coordinator-facing model interface.
pub trait Policy {
    /// Batched generation: all requests are packed into ONE fixed-shape
    /// inference call (the pre-fetch batcher guarantees they fit). Total
    /// `sum(n_samples)` must be <= [`Policy::rollout_capacity`].
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult>;

    /// One RL update on completed prompt groups.
    fn train(&mut self, groups: &[PromptGroup], algo: &AlgoConfig) -> Result<TrainResult>;

    /// Greedy-decode accuracy on a held-out set. `cost_s` is excluded from
    /// training-time accounting (the paper excludes validation time).
    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult>;

    /// Rows per inference call (the compiled artifact's row count).
    fn rollout_capacity(&self) -> usize;

    /// Maximum rollouts the train step can consume at once.
    fn train_capacity(&self) -> usize;

    /// Generation length (tokens) per rollout.
    fn gen_len(&self) -> usize;

    fn name(&self) -> &str;
}

/// Split a flat row vector of rollouts back into per-request groups.
pub fn split_rows(requests: &[GenRequest], mut rows: Vec<Rollout>) -> Vec<Vec<Rollout>> {
    let mut out = Vec::with_capacity(requests.len());
    for req in requests {
        let rest = rows.split_off(req.n_samples.min(rows.len()));
        out.push(std::mem::replace(&mut rows, rest));
    }
    out
}
