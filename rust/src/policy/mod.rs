//! Policy abstraction: the coordinator talks to a model through two traits
//! (DESIGN.md §4) so the *same* SPEED scheduler drives both the real PJRT
//! transformer ([`real::RealPolicy`]) and the IRT simulator
//! ([`sim::SimPolicy`]) used for paper-scale benchmark regeneration.
//!
//! * [`RolloutEngine`] — the inference side: batched generation and greedy
//!   evaluation. Rollout workers in the pipelined coordinator own one
//!   engine each and serve a (possibly stale) parameter snapshot.
//! * [`Trainable`]     — the learner side: RL updates plus weight
//!   versioning. Every `train` call bumps the version; engines record the
//!   version they serve so the buffer can account for off-policy staleness.
//! * [`Policy`]        — the combination, implemented automatically for any
//!   type providing both halves (the serial trainer's interface).
//! * [`ForkEngine`]    — replication of the inference side into independent
//!   engines, one per rollout worker (simulator substrate only; the real
//!   substrate has a single compiled engine).
//! * [`service`]       — the shared inference service: a pool of E
//!   data-parallel engine replicas behind one submission queue whose
//!   router coalesces generation requests across workers into
//!   maximally-packed calls and packs them onto the least-loaded replica
//!   (handles implement [`RolloutEngine`], so workers run unchanged).
//! * [`fault`]         — deterministic fault injection ([`fault::FaultPlan`]
//!   / [`fault::FaultyEngine`]) and the recovery knobs
//!   ([`fault::RecoveryConfig`]) for the fault-tolerant pool.

pub mod fault;
pub mod real;
pub mod sampler;
pub mod service;
pub mod sim;

use anyhow::Result;

use crate::data::tasks::TaskInstance;
use crate::rl::algo::AlgoConfig;
use crate::rl::update::{PromptGroup, Rollout};

/// One generation request: `n_samples` rollouts for one prompt.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Index into the active training dataset (carried through for
    /// bookkeeping; the policy does not interpret it).
    pub prompt_idx: usize,
    pub task: TaskInstance,
    pub n_samples: usize,
}

/// Result of one batched inference call.
#[derive(Debug)]
pub struct GenResult {
    /// Per-request rollouts, same order as the request slice. Rewards are
    /// already verified (binary, eq. 2).
    pub groups: Vec<Vec<Rollout>>,
    /// Inference cost in seconds — wall-clock for the real policy, the cost
    /// model's virtual time for the simulator.
    pub cost_s: f64,
    /// Rows of the fixed-shape call actually carrying data.
    pub rows_used: usize,
    /// Parameter version that produced these rollouts (the engine's
    /// serving version at call time).
    pub weight_version: u64,
}

/// Result of one RL update step.
#[derive(Clone, Copy, Debug)]
pub struct TrainResult {
    pub loss: f64,
    pub grad_norm: f64,
    pub clip_frac: f64,
    pub cost_s: f64,
}

/// Result of an evaluation pass.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub accuracy: f64,
    pub cost_s: f64,
}

/// Opaque parameter snapshot handed from the learner to rollout engines.
///
/// The payload is substrate-defined: [`sim::SimPolicy`] ships its scalar
/// skill, [`real::RealPolicy`] ships nothing (its single engine shares the
/// device-resident [`crate::runtime::ParamStore`] with the learner) — the
/// version alone lets engines and buffers track staleness.
#[derive(Clone, Debug, Default)]
pub struct WeightSnapshot {
    /// Monotone counter: number of `train` calls applied to these weights.
    pub version: u64,
    /// Flat substrate payload (see above).
    pub values: Vec<f64>,
}

/// The inference side of a policy: generation + evaluation.
pub trait RolloutEngine {
    /// Batched generation: all requests are packed into ONE fixed-shape
    /// inference call (the pre-fetch batcher guarantees they fit). Total
    /// `sum(n_samples)` must be <= [`RolloutEngine::rollout_capacity`].
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult>;

    /// Greedy-decode accuracy on a held-out set. `cost_s` is excluded from
    /// training-time accounting (the paper excludes validation time).
    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult>;

    /// Rows per inference call (the compiled artifact's row count).
    fn rollout_capacity(&self) -> usize;

    /// Generation length (tokens) per rollout.
    fn gen_len(&self) -> usize;

    /// Install a learner snapshot; subsequent rollouts are produced under
    /// `snap.version`.
    fn install(&mut self, snap: &WeightSnapshot);

    /// Version of the parameters the engine currently serves.
    fn serving_version(&self) -> u64;

    fn name(&self) -> &str;
}

/// The learner side of a policy: RL updates + weight versioning.
pub trait Trainable {
    /// One RL update on completed prompt groups. Bumps the weight version.
    fn train(&mut self, groups: &[PromptGroup], algo: &AlgoConfig) -> Result<TrainResult>;

    /// Maximum rollouts the train step can consume at once.
    fn train_capacity(&self) -> usize;

    /// Number of `train` calls applied so far.
    fn weight_version(&self) -> u64;

    /// Export the current weights for handoff to rollout engines.
    fn snapshot(&self) -> WeightSnapshot;

    /// Substrate-internal state for a warm-resume checkpoint sidecar
    /// (`None` = nothing beyond what [`save_params`](Self::save_params)
    /// persists). The simulator stores its skill + RNG stream here — the
    /// piece that makes the resume-equivalence rail bit-exact.
    fn state_json(&self) -> Option<crate::util::json::Json> {
        None
    }

    /// Restore state written by [`state_json`](Self::state_json). The
    /// default accepts silently so stateless substrates (and test mocks)
    /// resume on weights alone.
    fn restore_state_json(&mut self, _state: &crate::util::json::Json) -> Result<()> {
        Ok(())
    }

    /// Persist raw weight/optimizer buffers next to the run-state sidecar
    /// (`ParamStore::save` for the real substrate). Substrates whose whole
    /// state fits the sidecar (the simulator) need nothing here.
    fn save_params(&self, _dir: &std::path::Path, _tag: &str) -> Result<()> {
        Ok(())
    }

    /// Load buffers written by [`save_params`](Self::save_params).
    fn load_params(&mut self, _dir: &std::path::Path, _tag: &str) -> Result<()> {
        Ok(())
    }

    /// A value that changes with every weight update and is persisted by
    /// [`save_params`](Self::save_params) (the real substrate's optimizer
    /// step). The sidecar records it at save time and the resume loader
    /// compares it against the loaded weights, so a crash landing between
    /// the weight files and the sidecar (two save generations on disk)
    /// fails loudly instead of resuming torn. `None` = the substrate has
    /// no separate weight files (the sim; its whole state is in the
    /// sidecar, which is written atomically).
    fn params_token(&self) -> Option<u64> {
        None
    }
}

/// The combined coordinator-facing interface, implemented automatically
/// for any type providing both halves.
pub trait Policy: RolloutEngine + Trainable {
    /// View the policy as its inference half (what [`crate::coordinator`]'s
    /// `StepContext` drives).
    fn as_engine(&mut self) -> &mut dyn RolloutEngine;
}

impl<T: RolloutEngine + Trainable> Policy for T {
    fn as_engine(&mut self) -> &mut dyn RolloutEngine {
        self
    }
}

/// Replication of the inference side into independent engines, one per
/// rollout worker. Stream 0 must reproduce the RNG stream the type's own
/// engine would use, so a 1-worker pipeline matches a serial run on a
/// stationary (scripted) policy.
pub trait ForkEngine {
    fn fork_engine(&self, stream: u64) -> Box<dyn RolloutEngine + Send>;
}

/// Split a flat row vector of rollouts back into per-request groups — the
/// checked splitting primitive for engine frontends that decode a flat
/// fixed-shape row batch (the in-tree substrates group inline while
/// verifying, and the service validates per-request group counts at
/// fan-out; external engines should route their flat results through
/// this).
///
/// The row count must equal `sum(n_samples)` exactly: a short (or long)
/// vector means an engine under- or over-produced and silently clamping
/// would shift later requests' rollouts onto the wrong groups — with
/// variable per-prompt budgets that corruption would also be invisible to
/// any uniform-size sanity check downstream, so it is an error here.
pub fn split_rows(requests: &[GenRequest], mut rows: Vec<Rollout>) -> Result<Vec<Vec<Rollout>>> {
    let expected: usize = requests.iter().map(|r| r.n_samples).sum();
    anyhow::ensure!(
        rows.len() == expected,
        "row-count mismatch: {} rollout rows for {} requested samples across {} requests",
        rows.len(),
        expected,
        requests.len()
    );
    let mut out = Vec::with_capacity(requests.len());
    for req in requests {
        let rest = rows.split_off(req.n_samples);
        out.push(std::mem::replace(&mut rows, rest));
    }
    debug_assert!(rows.is_empty());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, TaskFamily};
    use crate::util::rng::Rng;

    fn reqs(samples: &[usize]) -> Vec<GenRequest> {
        let mut rng = Rng::new(1);
        samples
            .iter()
            .enumerate()
            .map(|(i, &n)| GenRequest {
                prompt_idx: i,
                task: generate(&mut rng, TaskFamily::Add, 2, 20),
                n_samples: n,
            })
            .collect()
    }

    fn rows(n: usize) -> Vec<Rollout> {
        (0..n)
            .map(|i| Rollout {
                gen_tokens: vec![i as i32],
                gen_logprobs: vec![-0.1],
                reward: 0.0,
            })
            .collect()
    }

    #[test]
    fn split_rows_respects_variable_budgets() {
        let groups = split_rows(&reqs(&[3, 1, 5]), rows(9)).unwrap();
        assert_eq!(groups.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 1, 5]);
        // rows are assigned in order, no duplication or loss
        assert_eq!(groups[1][0].gen_tokens, vec![3]);
        assert_eq!(groups[2][4].gen_tokens, vec![8]);
    }

    #[test]
    fn split_rows_rejects_row_count_mismatch() {
        // A short result must error loudly, not shift rollouts across
        // groups (the silent-truncation bug the clamp used to hide).
        let err = split_rows(&reqs(&[3, 2]), rows(4)).unwrap_err().to_string();
        assert!(err.contains("4 rollout rows for 5"), "{err}");
        assert!(split_rows(&reqs(&[3, 2]), rows(6)).is_err());
        assert!(split_rows(&reqs(&[]), rows(0)).unwrap().is_empty());
    }
}
