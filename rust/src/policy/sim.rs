//! SimPolicy: an item-response-theory policy simulator with learning
//! dynamics derived from the paper's own theory (Fact 1 + Theorem 3.1) and
//! a calibrated inference cost model.
//!
//! Purpose (DESIGN.md §3): the paper's headline numbers are *hours of
//! GH200 time*; regenerating Table 1 / Figs 3-6 at paper scale through the
//! real CPU transformer would take days. The simulator preserves exactly
//! the quantities SPEED interacts with — the pass-rate distribution under
//! the current model, its evolution during training, and per-call inference
//! cost — while the *same coordinator code* (screening, buffer, pre-fetch
//! batcher, curricula) drives both substrates.
//!
//! Mechanics:
//!
//! * pass rate: `p(task) = sigma(a * (skill - difficulty(task)))`, an IRT
//!   two-parameter model; `difficulty` = generator level + family offset +
//!   per-instance jitter. The `sim-1.5b`/`sim-7b` presets are calibrated so
//!   the *base-model* pass-rate histogram over `synth-dapo17k` matches
//!   Figure 2 (~34% / ~26% zero-pass mass at 50 samples).
//! * learning: one RL step moves skill by
//!   `eta * mean_g[ p_hat(1-p_hat) * (1 - 1/SNR_g)+ ]` — the group's
//!   gradient magnitude (reward variance) gated by Fact 1's improvement
//!   factor with Theorem 3.1's SNR at the group's rollout count. Groups
//!   with uniform rewards contribute zero (eq. 6).
//! * cost: `call = overhead + rows * (prefill + decode * response_len)`,
//!   a vLLM-like per-token model; response length grows with difficulty.

use anyhow::Result;

use crate::data::tasks::{TaskFamily, TaskInstance};
use crate::data::tokenizer::EOS;
use crate::policy::{
    EvalResult, ForkEngine, GenRequest, GenResult, RolloutEngine, TrainResult, Trainable,
    WeightSnapshot,
};
use crate::rl::algo::AlgoConfig;
use crate::rl::theory::snr_bound_exact;
use crate::rl::update::{PromptGroup, Rollout};
use crate::util::rng::Rng;

/// Model-scale preset (the Qwen2.5-Math-1.5B / 7B analogues).
#[derive(Clone, Copy, Debug)]
pub struct SimModelSpec {
    pub name: &'static str,
    /// Initial skill (IRT ability).
    pub skill0: f64,
    /// Learning-rate of the skill dynamics.
    pub eta: f64,
    /// IRT discrimination parameter `a`.
    pub discrimination: f64,
}

impl SimModelSpec {
    /// Calibrated to Fig. 2-left: ~34% of synth-dapo17k prompts at pass
    /// rate exactly 0 over 50 samples for the base model. Discrimination
    /// 2.2 reproduces the *U-shaped* (bimodal) pass-rate histogram the
    /// paper observes — most prompts are either hopeless or trivial for a
    /// given checkpoint, which is exactly the regime SPEED exploits.
    pub fn qwen_15b() -> SimModelSpec {
        SimModelSpec { name: "sim-1.5b", skill0: 6.2, eta: 0.55, discrimination: 1.6 }
    }

    /// Calibrated to Fig. 2-middle: smaller zero-pass mass than the 1.5B
    /// model; learns faster.
    pub fn qwen_7b() -> SimModelSpec {
        SimModelSpec { name: "sim-7b", skill0: 6.9, eta: 0.4, discrimination: 1.6 }
    }

    pub fn parse(s: &str) -> Option<SimModelSpec> {
        match s {
            "sim-1.5b" | "1.5b" => Some(Self::qwen_15b()),
            "sim-7b" | "7b" => Some(Self::qwen_7b()),
            _ => None,
        }
    }
}

/// Inference/update cost model (seconds). Defaults approximate the paper's
/// testbed shape: inference dominates updates ~2:1 per step (Fig. 2-right),
/// scaled so full paper runs land in the paper's "hours" range.
///
/// The model charges by rows USED (`overhead + sum over requests`), so
/// splitting the same rows across more calls costs exactly one extra
/// `call_overhead_s` per extra call — which is how the sim reflects the
/// coalescing service's gains: merging K lightly-filled per-worker calls
/// into one engine call amortizes K-1 overheads without changing the
/// per-row charge (`rust/tests/service_sim.rs` asserts the end-to-end
/// version of this).
#[derive(Clone, Copy, Debug)]
pub struct SimCostModel {
    /// Fixed cost per inference-engine call (scheduling, kernel launch).
    pub call_overhead_s: f64,
    /// Per row: prompt prefill.
    pub prefill_row_s: f64,
    /// Per row per generated token.
    pub decode_row_token_s: f64,
    /// Fixed cost per train step.
    pub train_overhead_s: f64,
    /// Per training row (fwd+bwd+optimizer).
    pub train_row_s: f64,
}

impl Default for SimCostModel {
    fn default() -> Self {
        // Calibrated for paper-scale generation lengths (gen cap ~512
        // tokens): a vanilla 384-row generation wave with ~50% max-length
        // rambles ~ 55 s, an update on 384 rows ~ 22 s => a vanilla RLOO
        // step ~ 80 s — the shape of Fig. 2-right (inference ~2x training)
        // and Table 1's hours-scale totals over a few hundred steps.
        SimCostModel {
            call_overhead_s: 2.0,
            prefill_row_s: 0.004,
            decode_row_token_s: 5.3e-4,
            train_overhead_s: 5.0,
            train_row_s: 0.045,
        }
    }
}

/// Deterministic per-instance difficulty jitter from the prompt text.
fn jitter(prompt: &str) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in prompt.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    // uniform in [-1, 1)
    ((h >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0
}

/// Family hardness offsets (multiplication/counting are harder per level).
fn family_offset(f: TaskFamily) -> f64 {
    match f {
        TaskFamily::Add => 0.0,
        TaskFamily::Sub => 0.3,
        TaskFamily::Mul => 1.5,
        TaskFamily::Mod => 0.8,
        TaskFamily::Chain => 0.6,
        TaskFamily::Count => 1.0,
        TaskFamily::Compare => 0.2,
    }
}

/// IRT difficulty of one instance. The 1.3x level stretch + 1.5x jitter
/// widen the spread so base-model accuracies sit below the paper's Table 1
/// target thresholds while the zero-pass mass still matches Figure 2.
pub fn difficulty(task: &TaskInstance) -> f64 {
    1.3 * task.level as f64 + family_offset(task.family) + 1.5 * jitter(&task.prompt)
}

pub struct SimPolicy {
    pub spec: SimModelSpec,
    pub cost: SimCostModel,
    pub skill: f64,
    rng: Rng,
    seed: u64,
    capacity: usize,
    train_rows: usize,
    gen_len: usize,
    train_steps: usize,
    /// Weight version: bumped by `train`, copied by `install`.
    version: u64,
}

impl SimPolicy {
    pub fn new(spec: SimModelSpec, cost: SimCostModel, seed: u64) -> SimPolicy {
        SimPolicy {
            spec,
            cost,
            skill: spec.skill0,
            rng: Rng::new(seed ^ 0x51b0_11c0),
            seed,
            capacity: 384,
            train_rows: 384,
            gen_len: 512, // paper-scale generation cap
            train_steps: 0,
            version: 0,
        }
    }

    /// Configure the inference-call and train-batch shapes (paper: gen
    /// batch 64 prompts x N rollouts; we express capacity in rows).
    pub fn with_shapes(mut self, capacity: usize, train_rows: usize, gen_len: usize) -> SimPolicy {
        self.capacity = capacity;
        self.train_rows = train_rows;
        self.gen_len = gen_len;
        self
    }

    /// True pass rate of the current model on `task`.
    pub fn pass_prob(&self, task: &TaskInstance) -> f64 {
        let z = self.spec.discrimination * (self.skill - difficulty(task));
        let p = 1.0 / (1.0 + (-z).exp());
        p.clamp(1e-6, 1.0 - 1e-6)
    }

    /// Expected response length (tokens) for a task under the *current*
    /// model. Matches the observed LLM behaviour the paper's speedup rides
    /// on: prompts the model can solve terminate quickly (answer + EOS),
    /// hopeless prompts ramble to the generation cap. This is what makes
    /// uniform sampling expensive — 34% of DAPO-17k burns max-length
    /// decodes for zero gradient signal.
    fn response_len(&self, task: &TaskInstance) -> f64 {
        let p = self.pass_prob(task);
        // Solvable prompts produce a CoT whose length grows with
        // difficulty; hopeless prompts decode to the cap.
        let solved = (40.0 + 4.0 * task.answer_text().len() as f64 + 3.0 * difficulty(task))
            .min(self.gen_len as f64);
        let ramble = self.gen_len as f64;
        (p * solved + (1.0 - p) * ramble).clamp(2.0, self.gen_len as f64)
    }

    fn call_cost(&self, requests: &[GenRequest]) -> f64 {
        let mut cost = self.cost.call_overhead_s;
        for r in requests {
            let len = self.response_len(&r.task);
            cost += r.n_samples as f64 * (self.cost.prefill_row_s + self.cost.decode_row_token_s * len);
        }
        cost
    }
}

impl RolloutEngine for SimPolicy {
    fn generate(&mut self, requests: &[GenRequest], temperature: f32) -> Result<GenResult> {
        let rows_used: usize = requests.iter().map(|r| r.n_samples).sum();
        anyhow::ensure!(rows_used <= self.capacity, "call exceeds capacity");
        let greedy = temperature <= 0.0;
        let groups = requests
            .iter()
            .map(|req| {
                let p = self.pass_prob(&req.task);
                (0..req.n_samples)
                    .map(|_| {
                        let correct =
                            if greedy { p >= 0.5 } else { self.rng.bool(p) };
                        Rollout {
                            gen_tokens: vec![EOS],
                            gen_logprobs: vec![(p.max(1e-6)).ln() as f32],
                            reward: if correct { 1.0 } else { 0.0 },
                        }
                    })
                    .collect()
            })
            .collect();
        Ok(GenResult {
            groups,
            cost_s: self.call_cost(requests),
            rows_used,
            weight_version: self.version,
        })
    }

    fn evaluate(&mut self, tasks: &[TaskInstance]) -> Result<EvalResult> {
        // Expected accuracy (smooth, deterministic — the EMA'd curves of
        // Fig. 6 without sampling noise).
        let acc = tasks.iter().map(|t| self.pass_prob(t)).sum::<f64>() / tasks.len().max(1) as f64;
        let cost = tasks.len() as f64
            * (self.cost.prefill_row_s + self.cost.decode_row_token_s * 8.0);
        Ok(EvalResult { accuracy: acc, cost_s: cost })
    }

    fn rollout_capacity(&self) -> usize {
        self.capacity
    }

    fn gen_len(&self) -> usize {
        self.gen_len
    }

    fn install(&mut self, snap: &WeightSnapshot) {
        if let Some(&skill) = snap.values.first() {
            self.skill = skill;
        }
        self.version = snap.version;
    }

    fn serving_version(&self) -> u64 {
        self.version
    }

    fn name(&self) -> &str {
        self.spec.name
    }
}

impl Trainable for SimPolicy {
    fn train(&mut self, groups: &[PromptGroup], _algo: &AlgoConfig) -> Result<TrainResult> {
        let rows: usize = groups.iter().map(|g| g.rollouts.len()).sum();
        anyhow::ensure!(rows <= self.train_rows, "train batch exceeds capacity");
        let mut signal = 0.0f64;
        let mut grad_sq = 0.0f64;
        let mut reward_sum = 0.0f64;
        for g in groups {
            let n = g.rollouts.len();
            let p = g.pass_rate();
            reward_sum += p;
            let var = p * (1.0 - p);
            // Theorem 3.1's SNR at this group's rollout count gates the
            // useful fraction of the gradient step (Fact 1).
            let snr = snr_bound_exact(n, p);
            let gate = if snr > 1.0 { 1.0 - 1.0 / snr } else { 0.0 };
            signal += var * gate;
            grad_sq += var; // RLOO advantage RMS^2 ~ p(1-p) per group
        }
        let b = groups.len().max(1) as f64;
        self.skill += self.spec.eta * signal / b;
        self.train_steps += 1;
        self.version += 1;
        let cost = self.cost.train_overhead_s + self.cost.train_row_s * rows as f64;
        Ok(TrainResult {
            loss: -(reward_sum / b),
            grad_norm: (grad_sq / b).sqrt(),
            clip_frac: 0.0,
            cost_s: cost,
        })
    }

    fn train_capacity(&self) -> usize {
        self.train_rows
    }

    fn weight_version(&self) -> u64 {
        self.version
    }

    fn snapshot(&self) -> WeightSnapshot {
        WeightSnapshot { version: self.version, values: vec![self.skill] }
    }

    /// The simulator's full internal state: skill, sampling-RNG stream,
    /// weight version and step counter. With these restored, a resumed sim
    /// run reproduces an uninterrupted run's rollout stream bit for bit
    /// (the checkpoint equivalence rail).
    fn state_json(&self) -> Option<crate::util::json::Json> {
        use crate::util::json::Json;
        Some(Json::obj(vec![
            ("skill", Json::num(self.skill)),
            ("rng", crate::checkpoint::rng_state_to_json(self.rng.state())),
            ("version", crate::checkpoint::ju64(self.version)),
            ("train_steps", Json::num(self.train_steps as f64)),
        ]))
    }

    fn restore_state_json(&mut self, state: &crate::util::json::Json) -> Result<()> {
        self.skill = state
            .get("skill")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| anyhow::anyhow!("sim policy state missing 'skill'"))?;
        let rng_state = state
            .get("rng")
            .ok_or_else(|| anyhow::anyhow!("sim policy state missing 'rng'"))?;
        self.rng = Rng::from_state(crate::checkpoint::rng_state_from_json(rng_state)?);
        self.version = state
            .get("version")
            .map(crate::checkpoint::pu64)
            .transpose()?
            .unwrap_or(0);
        self.train_steps =
            state.get("train_steps").and_then(|x| x.as_usize()).unwrap_or(0);
        Ok(())
    }
}

impl ForkEngine for SimPolicy {
    fn fork_engine(&self, stream: u64) -> Box<dyn RolloutEngine + Send> {
        // Stream 0 reproduces this policy's own RNG stream; higher streams
        // derive independent ones (splitmix-style increment).
        let seed = self.seed.wrapping_add(stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut engine = SimPolicy::new(self.spec, self.cost, seed).with_shapes(
            self.capacity,
            self.train_rows,
            self.gen_len,
        );
        engine.skill = self.skill;
        engine.version = self.version;
        Box::new(engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Dataset, DatasetKind};

    fn sim(spec: SimModelSpec) -> SimPolicy {
        SimPolicy::new(spec, SimCostModel::default(), 7)
    }

    #[test]
    fn harder_tasks_lower_pass_prob() {
        let s = sim(SimModelSpec::qwen_15b());
        let mut rng = Rng::new(0);
        let easy = crate::data::tasks::generate(&mut rng, TaskFamily::Add, 1, 24);
        let hard = crate::data::tasks::generate(&mut rng, TaskFamily::Mul, 9, 24);
        assert!(s.pass_prob(&easy) > s.pass_prob(&hard));
    }

    #[test]
    fn zero_pass_mass_matches_figure2_shape() {
        // Fig 2: a large spike of prompts at pass rate exactly 0 over 50
        // samples (paper: 34% for 1.5B, 25.8% for 7B), with the smaller
        // model's spike strictly larger. The sim preserves the *shape*
        // (U-shaped histogram with a dominant zero spike); the absolute
        // spike sizes land within a wider band because the synthetic
        // difficulty distribution is not Qwen's (see EXPERIMENTS.md).
        let data = Dataset::training(DatasetKind::SynthDapo17k, 1000, 0, 24);
        let zero_mass = |spec: SimModelSpec| {
            let s = sim(spec);
            data.instances
                .iter()
                .filter(|t| (1.0 - s.pass_prob(t)).powi(50) > 0.5)
                .count() as f64
                / data.len() as f64
        };
        let z15 = zero_mass(SimModelSpec::qwen_15b());
        let z7 = zero_mass(SimModelSpec::qwen_7b());
        assert!((0.25..0.70).contains(&z15), "1.5b zero-pass mass {z15:.3}");
        assert!((0.20..0.60).contains(&z7), "7b zero-pass mass {z7:.3}");
        assert!(z15 > z7 + 0.05, "smaller model must have larger zero mass: {z15:.3} vs {z7:.3}");
    }

    #[test]
    fn training_on_intermediate_difficulty_improves_skill() {
        let mut s = sim(SimModelSpec::qwen_15b());
        let mut rng = Rng::new(1);
        let before = s.skill;
        // Groups at pass rate 0.5 (max signal)
        let groups: Vec<PromptGroup> = (0..8)
            .map(|i| PromptGroup {
                prompt_idx: i,
                task: crate::data::tasks::generate(&mut rng, TaskFamily::Add, 3, 24),
                rollouts: (0..24)
                    .map(|j| Rollout {
                        gen_tokens: vec![EOS],
                        gen_logprobs: vec![-0.5],
                        reward: if j % 2 == 0 { 1.0 } else { 0.0 },
                    })
                    .collect(),
            })
            .collect();
        let algo = AlgoConfig::new(crate::rl::algo::BaseAlgo::Rloo);
        let tr = s.train(&groups, &algo).unwrap();
        assert!(s.skill > before);
        assert!(tr.grad_norm > 0.4); // sqrt(0.25) = 0.5
    }

    #[test]
    fn uniform_reward_groups_carry_no_signal() {
        let mut s = sim(SimModelSpec::qwen_15b());
        let mut rng = Rng::new(2);
        let before = s.skill;
        let groups: Vec<PromptGroup> = (0..4)
            .map(|i| PromptGroup {
                prompt_idx: i,
                task: crate::data::tasks::generate(&mut rng, TaskFamily::Add, 1, 24),
                rollouts: (0..24)
                    .map(|_| Rollout {
                        gen_tokens: vec![EOS],
                        gen_logprobs: vec![-0.1],
                        reward: 1.0,
                    })
                    .collect(),
            })
            .collect();
        let algo = AlgoConfig::new(crate::rl::algo::BaseAlgo::Rloo);
        let tr = s.train(&groups, &algo).unwrap();
        assert_eq!(s.skill, before);
        assert_eq!(tr.grad_norm, 0.0);
    }

    #[test]
    fn cost_model_inference_dominates_training() {
        // Fig 2-right: per-step inference time ~2x training time for RLOO.
        let mut s = sim(SimModelSpec::qwen_7b()).with_shapes(384, 384, 512);
        let mut rng = Rng::new(3);
        let task = crate::data::tasks::generate(&mut rng, TaskFamily::Add, 5, 24);
        let reqs: Vec<GenRequest> = (0..16)
            .map(|i| GenRequest { prompt_idx: i, task: task.clone(), n_samples: 24 })
            .collect();
        let gen = s.generate(&reqs, 1.0).unwrap();
        let groups: Vec<PromptGroup> = reqs
            .iter()
            .zip(gen.groups)
            .map(|(r, rollouts)| PromptGroup {
                prompt_idx: r.prompt_idx,
                task: r.task.clone(),
                rollouts,
            })
            .collect();
        let tr = s.train(&groups, &AlgoConfig::new(crate::rl::algo::BaseAlgo::Rloo)).unwrap();
        let ratio = gen.cost_s / tr.cost_s;
        assert!((1.2..4.0).contains(&ratio), "inference/train ratio {ratio}");
    }

    #[test]
    fn cost_charges_rows_used_so_coalescing_amortizes_overhead_only() {
        // One call carrying 4 workers' worth of requests must cost exactly
        // 3 call overheads less than the same requests split into 4 calls:
        // the per-row charge is identical either way (rows-used pricing).
        let s = sim(SimModelSpec::qwen_7b()).with_shapes(384, 384, 512);
        let mut rng = Rng::new(17);
        let task = crate::data::tasks::generate(&mut rng, TaskFamily::Add, 5, 24);
        let reqs: Vec<GenRequest> = (0..8)
            .map(|i| GenRequest { prompt_idx: i, task: task.clone(), n_samples: 12 })
            .collect();
        let merged = s.call_cost(&reqs);
        let split: f64 = reqs.chunks(2).map(|c| s.call_cost(c)).sum();
        let saved = split - merged;
        assert!(
            (saved - 3.0 * s.cost.call_overhead_s).abs() < 1e-9,
            "coalescing 4 calls into 1 must save exactly 3 overheads, saved {saved}"
        );
    }

    #[test]
    fn rollouts_record_producing_weight_version() {
        let mut s = sim(SimModelSpec::qwen_15b());
        let mut rng = Rng::new(9);
        let task = crate::data::tasks::generate(&mut rng, TaskFamily::Add, 2, 24);
        let reqs = vec![GenRequest { prompt_idx: 0, task, n_samples: 4 }];
        assert_eq!(s.generate(&reqs, 1.0).unwrap().weight_version, 0);
        // installing a learner snapshot advances the served version, and
        // subsequent rollouts are stamped with it
        let snap = WeightSnapshot { version: 5, values: vec![s.skill + 0.25] };
        s.install(&snap);
        assert_eq!(s.serving_version(), 5);
        assert_eq!(s.generate(&reqs, 1.0).unwrap().weight_version, 5);
    }

    #[test]
    fn fork_engine_stream_zero_reproduces_serial_rollouts() {
        let serial = sim(SimModelSpec::qwen_7b());
        let mut fork = serial.fork_engine(0);
        let mut serial = sim(SimModelSpec::qwen_7b());
        let mut rng = Rng::new(4);
        let task = crate::data::tasks::generate(&mut rng, TaskFamily::Add, 4, 24);
        let reqs = vec![GenRequest { prompt_idx: 0, task, n_samples: 16 }];
        let a = serial.generate(&reqs, 1.0).unwrap();
        let b = fork.generate(&reqs, 1.0).unwrap();
        let rewards = |r: &GenResult| -> Vec<f32> {
            r.groups[0].iter().map(|x| x.reward).collect()
        };
        assert_eq!(rewards(&a), rewards(&b), "stream 0 must match the serial RNG stream");
    }

    #[test]
    fn state_json_roundtrip_continues_the_rollout_stream() {
        let mut a = sim(SimModelSpec::qwen_7b());
        let mut rng = Rng::new(4);
        let task = crate::data::tasks::generate(&mut rng, TaskFamily::Add, 4, 24);
        let reqs = vec![GenRequest { prompt_idx: 0, task: task.clone(), n_samples: 16 }];
        a.generate(&reqs, 1.0).unwrap(); // advance the stream
        a.train(&[], &AlgoConfig::new(crate::rl::algo::BaseAlgo::Rloo)).unwrap();

        // Round-trip through the serialized form, onto a differently-seeded
        // fresh policy.
        let text = Trainable::state_json(&a).unwrap().to_string();
        let mut b = SimPolicy::new(SimModelSpec::qwen_7b(), SimCostModel::default(), 999);
        b.restore_state_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(b.weight_version(), a.weight_version());
        assert_eq!(b.skill.to_bits(), a.skill.to_bits());
        let ra = a.generate(&reqs, 1.0).unwrap();
        let rb = b.generate(&reqs, 1.0).unwrap();
        let rewards = |r: &GenResult| r.groups[0].iter().map(|x| x.reward).collect::<Vec<_>>();
        assert_eq!(rewards(&ra), rewards(&rb), "restored RNG stream must continue exactly");
    }

    #[test]
    fn greedy_eval_deterministic() {
        let mut s = sim(SimModelSpec::qwen_7b());
        let data = Dataset::training(DatasetKind::SynthNumina, 50, 5, 24);
        let a = s.evaluate(&data.instances).unwrap().accuracy;
        let b = s.evaluate(&data.instances).unwrap().accuracy;
        assert_eq!(a, b);
        assert!(a > 0.0 && a < 1.0);
    }
}
