//! Host-side helpers for packing prompts into fixed-shape rollout calls.

use anyhow::Result;

use crate::data::tokenizer::{Tokenizer, BOS, PAD};
use crate::policy::GenRequest;

/// Packed prompt rows for one inference call.
pub struct PackedRows {
    pub tokens: Vec<i32>,  // [rows * width]
    pub lens: Vec<i32>,    // [rows]
    pub rows_used: usize,
    pub rows: usize,
    pub width: usize,
}

/// Expand requests into per-sample rows (prompt duplicated `n_samples`
/// times), left-aligned and PAD-tailed; unused rows hold a lone BOS so the
/// compiled graph has valid lengths everywhere.
pub fn pack_requests(
    tok: &Tokenizer,
    requests: &[GenRequest],
    rows: usize,
    width: usize,
) -> Result<PackedRows> {
    let rows_used: usize = requests.iter().map(|r| r.n_samples).sum();
    anyhow::ensure!(rows_used <= rows, "requests need {rows_used} rows, capacity {rows}");
    let mut tokens = vec![PAD; rows * width];
    let mut lens = vec![1i32; rows];
    // Padding rows: a lone BOS (length 1) — harmless, masked by length.
    for r in 0..rows {
        tokens[r * width] = BOS;
    }
    let mut row = 0usize;
    for req in requests {
        let (encoded, len) = tok.encode_padded(&req.task.prompt, width)?;
        for _ in 0..req.n_samples {
            tokens[row * width..(row + 1) * width].copy_from_slice(&encoded);
            lens[row] = len as i32;
            row += 1;
        }
    }
    Ok(PackedRows { tokens, lens, rows_used, rows, width })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{TaskFamily, TaskInstance};

    fn req(prompt: &str, n: usize) -> GenRequest {
        GenRequest {
            prompt_idx: 0,
            task: TaskInstance {
                family: TaskFamily::Add,
                level: 1,
                prompt: prompt.to_string(),
                answer: 0,
            },
            n_samples: n,
        }
    }

    #[test]
    fn duplicates_prompt_per_sample() {
        let tok = Tokenizer::new();
        let packed = pack_requests(&tok, &[req("1+2=", 3), req("9-4=", 2)], 8, 10).unwrap();
        assert_eq!(packed.rows_used, 5);
        // rows 0..3 share the first prompt
        assert_eq!(packed.tokens[0..4], packed.tokens[10..14]);
        assert_eq!(packed.lens[0], 4);
        // row 3 is the second prompt
        assert_ne!(packed.tokens[0..4], packed.tokens[30..34]);
        // padding rows: lone BOS, len 1
        assert_eq!(packed.tokens[5 * 10], BOS);
        assert_eq!(packed.lens[5], 1);
    }

    #[test]
    fn rejects_overflow() {
        let tok = Tokenizer::new();
        assert!(pack_requests(&tok, &[req("1+2=", 9)], 8, 10).is_err());
    }
}
