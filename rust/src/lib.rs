//! SPEED-RL: Faster Training of Reasoning Models via Online Curriculum
//! Learning — a full-system reproduction (Zhang, Arora, Mei, Zanette, 2025).
//!
//! Layer 3 of the three-layer Rust + JAX + Pallas stack. This crate owns the
//! whole request path: the SPEED online-curriculum scheduler (screening +
//! continuation + sampling buffer + pre-fetch batcher, paper §4), the RL
//! algorithms (RLOO / GRPO / REINFORCE / REINFORCE++ / DAPO), the synthetic
//! math-task substrate, and the PJRT runtime that executes the AOT-compiled
//! JAX/Pallas artifacts. Python never runs at request time.
//!
//! Module map (see DESIGN.md §4 for the full inventory):
//!
//! * [`util`]        — substrates the offline environment lacks: PRNG, JSON,
//!                     stats, CLI parsing, thread pool, logging, mini
//!                     property-testing harness. The thread pool carries the
//!                     pipelined coordinator's rollout workers.
//! * [`config`]      — typed run/model/algo configuration + JSON presets,
//!                     including the `workers`/`pipeline`/`buffer_cap` knobs.
//! * [`data`]        — tokenizer, synthetic task families, datasets,
//!                     verifier, and the `PromptSource` loader abstraction
//!                     (exclusive or mutex-shared prompt streams).
//! * [`rl`]          — advantage estimators, algorithm definitions, the
//!                     SNR/Φ theory of §3 and Appendix A/B.
//! * [`coordinator`] — the paper's contribution: SPEED scheduler (Alg. 2),
//!                     curricula, sampling buffers, pre-fetch batcher, the
//!                     serial trainer, and the pipelined trainer that
//!                     overlaps inference with updates (DESIGN.md §5).
//! * [`predictor`]   — online difficulty prediction: discounted Beta
//!                     posteriors per prompt identity + a generalizing
//!                     feature model, consulted by the `predictive-speed`
//!                     curriculum to skip screening before any rollout is
//!                     spent.
//! * [`checkpoint`]  — warm-resume run-state checkpoints: the predictor's
//!                     accumulated difficulty knowledge, run progress, and
//!                     substrate/curriculum internals persisted in a
//!                     sidecar next to the `ParamStore` buffers, behind a
//!                     config fingerprint (DESIGN.md §10).
//! * [`policy`]      — the two-trait policy layer: `RolloutEngine`
//!                     (generate + evaluate) and `Trainable` (update +
//!                     weight versioning), implemented by the PJRT
//!                     transformer (`real`) and the IRT simulator (`sim`);
//!                     plus the shared inference service (`service`) that
//!                     coalesces rollout requests across workers into one
//!                     maximally-packed engine (DESIGN.md §8).
//! * [`runtime`]     — PJRT client, artifact manifest, device-resident
//!                     parameter store.
//! * [`metrics`]     — phase timers, run records, curve logging, and the
//!                     atomic per-worker inference counters.
//! * [`trace`]       — the trace spine: per-thread bounded event rings,
//!                     Chrome trace-event export, log-bucketed latency
//!                     histograms, and the `speed-rl trace` analyzer
//!                     (DESIGN.md §12). Zero-perturbation when off.
//! * [`eval`]        — held-out benchmark evaluation.
//! * [`bench`]       — in-tree benchmark harness (no criterion offline).
//! * [`analysis`]    — the `speed-rl lint` invariant linter (lock
//!                     discipline, counter schemas, harness registration,
//!                     wall-clock hygiene, metric tables) and the
//!                     exhaustive interleaving explorer that model-checks
//!                     the sync protocols (DESIGN.md §15).

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod driver;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod policy;
pub mod predictor;
pub mod rl;
pub mod runtime;
pub mod trace;
pub mod util;
