//! Run metrics: per-phase time accounting (paper Fig. 2-right), training
//! curves (Fig. 4), evaluation curves (Fig. 3/6), and the run record that
//! benches serialize for EXPERIMENTS.md.

pub mod report;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Cumulative inference-side counters. Plain and `Copy`: each rollout
/// worker owns one and periodically merges it into the run-wide
/// [`AtomicCounters`], so per-worker accounting sums correctly.
#[derive(Clone, Copy, Debug, Default)]
pub struct InferenceCounters {
    pub calls: u64,
    pub rows_used: u64,
    pub rows_capacity: u64,
    pub cost_s: f64,
    pub prompts_screened: u64,
    pub prompts_accepted: u64,
    pub rollouts: u64,
    /// Real seconds the rollout engine spent inside collection calls
    /// (pipelined runs only; the engine-utilization numerator).
    pub busy_s: f64,
    /// Prompts the difficulty predictor dropped before screening
    /// (predictive-speed only).
    pub prompts_skipped: u64,
    /// Confident skips that were screened anyway (exploration + the
    /// forced-screen safety valve) — the predictor's ground-truth feed.
    pub prompts_explored: u64,
    /// Screening rollouts *not* spent thanks to skips (`N_init` per skip).
    pub rollouts_saved: u64,
    /// Skip-decision confusion counts over prompts actually screened
    /// (positive class = "the skip rule would have fired"; realized
    /// positive = screening rejected the prompt).
    pub pred_tp: u64,
    pub pred_fp: u64,
    pub pred_tn: u64,
    pub pred_fn: u64,
    /// Sum of squared forecast errors (predicted acceptance probability vs
    /// realized accept/reject) over `brier_n` screened prompts.
    pub brier_sum: f64,
    pub brier_n: u64,
    /// Continuation budgets issued by the allocator (one per accepted
    /// prompt).
    pub prompts_allocated: u64,
    /// Continuation rows allocated across those budgets (the fixed
    /// allocator makes this `prompts_allocated * n_cont` exactly).
    pub cont_rows_allocated: u64,
    /// Histogram of allocated continuation budgets: 1-4, 5-8, 9-16, 17-32,
    /// 33-64, >64 rows.
    pub alloc_hist: [u64; 6],
    /// Sum of squared (forecast reward variance - realized group variance)
    /// over `alloc_calib_n` completed groups: how well the variance
    /// forecasts that sized the budgets tracked reality.
    pub alloc_calib_sum: f64,
    pub alloc_calib_n: u64,
}

impl InferenceCounters {
    pub fn utilization(&self) -> f64 {
        if self.rows_capacity == 0 {
            0.0
        } else {
            self.rows_used as f64 / self.rows_capacity as f64
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.prompts_screened == 0 {
            0.0
        } else {
            self.prompts_accepted as f64 / self.prompts_screened as f64
        }
    }

    /// Mean Brier score of the predictor's acceptance forecasts (0 =
    /// perfect; 0.25 = always saying 0.5; 0 when nothing was scored).
    pub fn predictor_brier(&self) -> f64 {
        if self.brier_n == 0 {
            0.0
        } else {
            self.brier_sum / self.brier_n as f64
        }
    }

    /// Of the screened prompts the skip rule *would* have dropped, the
    /// fraction screening really rejected (0 when none were measured).
    pub fn predictor_precision(&self) -> f64 {
        let denom = self.pred_tp + self.pred_fp;
        if denom == 0 {
            0.0
        } else {
            self.pred_tp as f64 / denom as f64
        }
    }

    /// Of the screened prompts screening rejected, the fraction the skip
    /// rule would have dropped (0 when none were measured).
    pub fn predictor_recall(&self) -> f64 {
        let denom = self.pred_tp + self.pred_fn;
        if denom == 0 {
            0.0
        } else {
            self.pred_tp as f64 / denom as f64
        }
    }

    /// Histogram bucket index for an allocated continuation budget.
    pub fn alloc_hist_bucket(n_cont: usize) -> usize {
        match n_cont {
            0..=4 => 0,
            5..=8 => 1,
            9..=16 => 2,
            17..=32 => 3,
            33..=64 => 4,
            _ => 5,
        }
    }

    /// Account one continuation-budget allocation.
    pub fn record_allocation(&mut self, n_cont: usize) {
        self.prompts_allocated += 1;
        self.cont_rows_allocated += n_cont as u64;
        self.alloc_hist[Self::alloc_hist_bucket(n_cont)] += 1;
    }

    /// Score a completed group's realized variance against the forecast
    /// that sized its budget.
    pub fn record_alloc_outcome(&mut self, forecast_var: f64, realized_pass_rate: f64) {
        let realized_var = realized_pass_rate * (1.0 - realized_pass_rate);
        let err = forecast_var - realized_var;
        self.alloc_calib_sum += err * err;
        self.alloc_calib_n += 1;
    }

    /// Mean continuation rows allocated per accepted prompt (0 when none).
    pub fn mean_cont_alloc(&self) -> f64 {
        if self.prompts_allocated == 0 {
            0.0
        } else {
            self.cont_rows_allocated as f64 / self.prompts_allocated as f64
        }
    }

    /// Mean squared budget-vs-realized-variance calibration error (0 when
    /// nothing completed; lower is better, 0.0625 = always off by 0.25).
    pub fn alloc_calibration(&self) -> f64 {
        if self.alloc_calib_n == 0 {
            0.0
        } else {
            self.alloc_calib_sum / self.alloc_calib_n as f64
        }
    }

    /// Full raw-field serialization (run records and warm-resume
    /// checkpoints). Derived ratios are NOT stored — they are recomputed —
    /// so a parsed counter set keeps producing consistent ratios as more
    /// evidence accumulates on top of it after a resume.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("calls", Json::num(self.calls as f64)),
            ("rows_used", Json::num(self.rows_used as f64)),
            ("rows_capacity", Json::num(self.rows_capacity as f64)),
            ("cost_s", Json::num(self.cost_s)),
            ("prompts_screened", Json::num(self.prompts_screened as f64)),
            ("prompts_accepted", Json::num(self.prompts_accepted as f64)),
            ("rollouts", Json::num(self.rollouts as f64)),
            ("busy_s", Json::num(self.busy_s)),
            ("prompts_skipped", Json::num(self.prompts_skipped as f64)),
            ("prompts_explored", Json::num(self.prompts_explored as f64)),
            ("rollouts_saved", Json::num(self.rollouts_saved as f64)),
            ("pred_tp", Json::num(self.pred_tp as f64)),
            ("pred_fp", Json::num(self.pred_fp as f64)),
            ("pred_tn", Json::num(self.pred_tn as f64)),
            ("pred_fn", Json::num(self.pred_fn as f64)),
            ("brier_sum", Json::num(self.brier_sum)),
            ("brier_n", Json::num(self.brier_n as f64)),
            ("prompts_allocated", Json::num(self.prompts_allocated as f64)),
            ("cont_rows_allocated", Json::num(self.cont_rows_allocated as f64)),
            ("alloc_hist", Json::arr(self.alloc_hist.iter().map(|c| Json::num(*c as f64)))),
            ("alloc_calib_sum", Json::num(self.alloc_calib_sum)),
            ("alloc_calib_n", Json::num(self.alloc_calib_n as f64)),
        ])
    }

    /// Parse counters written by [`to_json`](Self::to_json). Every field
    /// defaults to zero so records from earlier formats (which stored only
    /// a subset, or only derived ratios) parse instead of erroring.
    pub fn from_json(j: &Json) -> InferenceCounters {
        let f = |k: &str| j.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
        let u = |k: &str| f(k) as u64;
        let mut alloc_hist = [0u64; 6];
        if let Some(arr) = j.get("alloc_hist").and_then(|x| x.as_arr()) {
            for (slot, v) in alloc_hist.iter_mut().zip(arr) {
                *slot = v.as_f64().unwrap_or(0.0) as u64;
            }
        }
        InferenceCounters {
            calls: u("calls"),
            rows_used: u("rows_used"),
            rows_capacity: u("rows_capacity"),
            // Older records named the field "inference_cost_s".
            cost_s: if j.get("cost_s").is_some() { f("cost_s") } else { f("inference_cost_s") },
            prompts_screened: u("prompts_screened"),
            prompts_accepted: u("prompts_accepted"),
            rollouts: u("rollouts"),
            busy_s: f("busy_s"),
            prompts_skipped: u("prompts_skipped"),
            prompts_explored: u("prompts_explored"),
            rollouts_saved: u("rollouts_saved"),
            pred_tp: u("pred_tp"),
            pred_fp: u("pred_fp"),
            pred_tn: u("pred_tn"),
            pred_fn: u("pred_fn"),
            brier_sum: f("brier_sum"),
            brier_n: u("brier_n"),
            prompts_allocated: u("prompts_allocated"),
            cont_rows_allocated: u("cont_rows_allocated"),
            alloc_hist,
            alloc_calib_sum: f("alloc_calib_sum"),
            alloc_calib_n: u("alloc_calib_n"),
        }
    }

    /// Accumulate another counter set (per-worker totals -> run totals).
    pub fn merge(&mut self, o: &InferenceCounters) {
        self.calls += o.calls;
        self.rows_used += o.rows_used;
        self.rows_capacity += o.rows_capacity;
        self.cost_s += o.cost_s;
        self.prompts_screened += o.prompts_screened;
        self.prompts_accepted += o.prompts_accepted;
        self.rollouts += o.rollouts;
        self.busy_s += o.busy_s;
        self.prompts_skipped += o.prompts_skipped;
        self.prompts_explored += o.prompts_explored;
        self.rollouts_saved += o.rollouts_saved;
        self.pred_tp += o.pred_tp;
        self.pred_fp += o.pred_fp;
        self.pred_tn += o.pred_tn;
        self.pred_fn += o.pred_fn;
        self.brier_sum += o.brier_sum;
        self.brier_n += o.brier_n;
        self.prompts_allocated += o.prompts_allocated;
        self.cont_rows_allocated += o.cont_rows_allocated;
        for (slot, v) in self.alloc_hist.iter_mut().zip(o.alloc_hist) {
            *slot += v;
        }
        self.alloc_calib_sum += o.alloc_calib_sum;
        self.alloc_calib_n += o.alloc_calib_n;
    }
}

/// Thread-safe accumulator for [`InferenceCounters`]: K rollout workers
/// `add` their local deltas, the learner `snapshot`s live totals. f64
/// fields are stored as bit-cast `AtomicU64`s updated via CAS.
#[derive(Debug, Default)]
pub struct AtomicCounters {
    calls: AtomicU64,
    rows_used: AtomicU64,
    rows_capacity: AtomicU64,
    cost_s_bits: AtomicU64,
    prompts_screened: AtomicU64,
    prompts_accepted: AtomicU64,
    rollouts: AtomicU64,
    busy_s_bits: AtomicU64,
    prompts_skipped: AtomicU64,
    prompts_explored: AtomicU64,
    rollouts_saved: AtomicU64,
    pred_tp: AtomicU64,
    pred_fp: AtomicU64,
    pred_tn: AtomicU64,
    pred_fn: AtomicU64,
    brier_sum_bits: AtomicU64,
    brier_n: AtomicU64,
    prompts_allocated: AtomicU64,
    cont_rows_allocated: AtomicU64,
    alloc_hist: [AtomicU64; 6],
    alloc_calib_sum_bits: AtomicU64,
    alloc_calib_n: AtomicU64,
}

fn atomic_f64_add(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + delta).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

impl AtomicCounters {
    pub fn add(&self, c: &InferenceCounters) {
        self.calls.fetch_add(c.calls, Ordering::Relaxed);
        self.rows_used.fetch_add(c.rows_used, Ordering::Relaxed);
        self.rows_capacity.fetch_add(c.rows_capacity, Ordering::Relaxed);
        self.prompts_screened.fetch_add(c.prompts_screened, Ordering::Relaxed);
        self.prompts_accepted.fetch_add(c.prompts_accepted, Ordering::Relaxed);
        self.rollouts.fetch_add(c.rollouts, Ordering::Relaxed);
        atomic_f64_add(&self.cost_s_bits, c.cost_s);
        atomic_f64_add(&self.busy_s_bits, c.busy_s);
        self.prompts_skipped.fetch_add(c.prompts_skipped, Ordering::Relaxed);
        self.prompts_explored.fetch_add(c.prompts_explored, Ordering::Relaxed);
        self.rollouts_saved.fetch_add(c.rollouts_saved, Ordering::Relaxed);
        self.pred_tp.fetch_add(c.pred_tp, Ordering::Relaxed);
        self.pred_fp.fetch_add(c.pred_fp, Ordering::Relaxed);
        self.pred_tn.fetch_add(c.pred_tn, Ordering::Relaxed);
        self.pred_fn.fetch_add(c.pred_fn, Ordering::Relaxed);
        atomic_f64_add(&self.brier_sum_bits, c.brier_sum);
        self.brier_n.fetch_add(c.brier_n, Ordering::Relaxed);
        self.prompts_allocated.fetch_add(c.prompts_allocated, Ordering::Relaxed);
        self.cont_rows_allocated.fetch_add(c.cont_rows_allocated, Ordering::Relaxed);
        for (slot, v) in self.alloc_hist.iter().zip(c.alloc_hist) {
            slot.fetch_add(v, Ordering::Relaxed);
        }
        atomic_f64_add(&self.alloc_calib_sum_bits, c.alloc_calib_sum);
        self.alloc_calib_n.fetch_add(c.alloc_calib_n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> InferenceCounters {
        InferenceCounters {
            calls: self.calls.load(Ordering::Relaxed),
            rows_used: self.rows_used.load(Ordering::Relaxed),
            rows_capacity: self.rows_capacity.load(Ordering::Relaxed),
            cost_s: f64::from_bits(self.cost_s_bits.load(Ordering::Relaxed)),
            prompts_screened: self.prompts_screened.load(Ordering::Relaxed),
            prompts_accepted: self.prompts_accepted.load(Ordering::Relaxed),
            rollouts: self.rollouts.load(Ordering::Relaxed),
            busy_s: f64::from_bits(self.busy_s_bits.load(Ordering::Relaxed)),
            prompts_skipped: self.prompts_skipped.load(Ordering::Relaxed),
            prompts_explored: self.prompts_explored.load(Ordering::Relaxed),
            rollouts_saved: self.rollouts_saved.load(Ordering::Relaxed),
            pred_tp: self.pred_tp.load(Ordering::Relaxed),
            pred_fp: self.pred_fp.load(Ordering::Relaxed),
            pred_tn: self.pred_tn.load(Ordering::Relaxed),
            pred_fn: self.pred_fn.load(Ordering::Relaxed),
            brier_sum: f64::from_bits(self.brier_sum_bits.load(Ordering::Relaxed)),
            brier_n: self.brier_n.load(Ordering::Relaxed),
            prompts_allocated: self.prompts_allocated.load(Ordering::Relaxed),
            cont_rows_allocated: self.cont_rows_allocated.load(Ordering::Relaxed),
            alloc_hist: {
                let mut hist = [0u64; 6];
                for (slot, v) in hist.iter_mut().zip(&self.alloc_hist) {
                    *slot = v.load(Ordering::Relaxed);
                }
                hist
            },
            alloc_calib_sum: f64::from_bits(self.alloc_calib_sum_bits.load(Ordering::Relaxed)),
            alloc_calib_n: self.alloc_calib_n.load(Ordering::Relaxed),
        }
    }
}

/// Hard cap on engine-pool replicas: the per-replica counters below are
/// fixed-size arrays so [`ServiceCounters`] stays `Copy` (cheap per-step
/// snapshots). The service and `--engines` validation both enforce it.
pub const MAX_POOL: usize = 8;

/// The wall-clock-valued [`ServiceCounters`] fields: real-time telemetry
/// that differs between ANY two runs of the same seed, which the chaos
/// smoke in `rust/ci.sh` strips from the `service` JSON block before its
/// byte comparison (the python `WALL` normalization set there). This
/// const is the single declaration the `speed-rl lint` L2 pass
/// cross-checks — every name must be a real [`ServiceCounters`] field
/// AND must appear in the ci.sh `WALL` set — so a new wall-clock counter
/// cannot silently break the chaos equivalence rail (DESIGN.md §15).
pub const WALL_CLOCK_SERVICE_FIELDS: &[&str] =
    &["queue_wait_s", "ewma_gap_s", "queue_wait_hist", "exec_hist"];

/// Cumulative counters of the shared [`InferenceService`]: an engine pool
/// behind one submission queue, coalescing requests across rollout workers.
/// `Copy` so per-step snapshots are cheap.
///
/// [`InferenceService`]: crate::policy::service::InferenceService
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceCounters {
    /// Engine calls actually executed (after coalescing).
    pub calls: u64,
    /// Submissions received from workers (before coalescing).
    pub submissions: u64,
    /// Rows carrying data across all executed calls.
    pub rows_used: u64,
    /// Engine capacity summed over executed calls (the fill denominator).
    pub rows_capacity: u64,
    /// Largest single executed call, in rows (must stay <= capacity).
    pub max_call_rows: u64,
    /// Total submission-to-execution wait, seconds (real time).
    pub queue_wait_s: f64,
    /// Weight installs performed at the engine (once per version, however
    /// many workers requested it).
    pub installs: u64,
    /// Calls dispatched by the `coalesce_wait_ms` deadline before the fill
    /// waterline was reached (the anti-starvation path).
    pub deadline_dispatches: u64,
    /// Engine calls spent splitting oversized submissions across successive
    /// invocations (each split chunk counts here AND in `calls`).
    pub split_calls: u64,
    /// Latest EWMA of the inter-submission gap, seconds (drives the
    /// adaptive coalesce deadline; 0 until two submissions were observed).
    pub ewma_gap_s: f64,
    /// Histogram of submissions coalesced per call: 1, 2, 3, 4, 5-8, >8.
    pub coalesced_hist: [u64; 6],
    /// Engine replicas behind the service (gauge; 1 for the single-engine
    /// service, 0 in records predating the pool).
    pub engines: u64,
    /// Plans an idle replica pulled from another replica's queue instead of
    /// waiting for the router (work-stealing dispatches).
    pub steals: u64,
    /// Router dispatches (the pool-balance denominator).
    pub pool_dispatches: u64,
    /// Replicas already busy (queued or executing rows) summed over
    /// dispatches (the pool-balance numerator).
    pub pool_busy_sum: u64,
    /// Histogram of busy replicas observed at dispatch: 0, 1, 2, 3, 4, >=5.
    pub pool_hist: [u64; 6],
    /// Per-replica executed calls. Replica index IS the sort key: segmented
    /// runs merge these slot-by-slot, so resumed pool runs report stable
    /// per-replica totals regardless of merge order.
    pub replica_calls: [u64; MAX_POOL],
    /// Per-replica rows carrying data across executed calls.
    pub replica_rows: [u64; MAX_POOL],
    /// Per-replica weight installs (each replica installs each announced
    /// version once; the run total is `installs`).
    pub replica_installs: [u64; MAX_POOL],
    /// Per-replica stolen plans (counted at the thief).
    pub replica_steals: [u64; MAX_POOL],
    /// Per-replica installed weight version (gauge; never exceeds the
    /// service's announced version — the staleness bound).
    pub replica_weight_version: [u64; MAX_POOL],
    /// Engine faults observed: failed generate attempts, execute-watchdog
    /// expiries, and replica panics. Under a scripted
    /// [`crate::policy::fault::FaultPlan`] this counts exactly the events
    /// that fired (the chaos-smoke accounting rail).
    pub faults_injected: u64,
    /// Failed execute attempts retried on the same replica (the bounded
    /// per-plan retry of `RecoveryConfig::retry_max`).
    pub retries: u64,
    /// Plans moved off a quarantined replica to healthy peers (in-flight
    /// shadow plans and queued plans both count, one per plan).
    pub redispatches: u64,
    /// Replicas quarantined (retry exhaustion, watchdog timeout, or hard
    /// death); each replica counts at most once per pool generation.
    pub quarantines: u64,
    /// Quarantined replicas replaced by activating a pre-forked spare.
    pub respawns: u64,
    /// Per-replica fault events observed at that replica (slot-by-slot
    /// merge, same ordering contract as the other per-replica counters).
    pub replica_faults: [u64; MAX_POOL],
    /// Log-bucketed histogram of per-submission queue waits (seconds;
    /// bucket edges in [`crate::trace::latency_bucket`]). Always on — the
    /// same real-time measurement as `queue_wait_s`, so traced and
    /// untraced runs build records identically.
    pub queue_wait_hist: [u64; crate::trace::HIST_BUCKETS],
    /// Log-bucketed histogram of engine-call execution durations (real
    /// seconds per executed call, splits counted per chunk). Always on.
    pub exec_hist: [u64; crate::trace::HIST_BUCKETS],
    /// Rollout-group plans admitted into a replica slot (one per generate
    /// plan routed, including redispatched placements; evaluation plans
    /// occupy no slots). Always on in both batching modes so deadline and
    /// slots runs chart the same occupancy curves.
    pub slot_admissions: u64,
    /// Admitted plans whose execution completed and freed their slot rows.
    /// `slot_admissions - slot_retires` = placements lost to faults.
    pub slot_retires: u64,
    /// Rollout rows resident on the chosen replica (queued + in-flight)
    /// summed over admissions — the slot-occupancy numerator. Pure row
    /// arithmetic, no clocks: deterministic across reruns.
    pub slot_occupancy_sum: u64,
    /// Engine capacity summed over admissions (the occupancy denominator).
    pub slot_capacity_sum: u64,
    /// Histogram of replica occupancy observed at admission, in eighths of
    /// engine capacity (last bucket = at or beyond full capacity).
    pub slot_occupancy_hist: [u64; 8],
    /// 1 when the service ran slot-level continuous batching (gauge; 0 for
    /// deadline mode and records predating batching modes).
    pub slots_mode: u64,
}

impl ServiceCounters {
    /// Histogram bucket index for `n` submissions in one call.
    pub fn hist_bucket(n: usize) -> usize {
        match n {
            0 | 1 => 0,
            2 => 1,
            3 => 2,
            4 => 3,
            5..=8 => 4,
            _ => 5,
        }
    }

    /// Mean call fill: rows carrying data / rows executed.
    pub fn mean_fill(&self) -> f64 {
        if self.rows_capacity == 0 {
            0.0
        } else {
            self.rows_used as f64 / self.rows_capacity as f64
        }
    }

    /// Histogram bucket for a replica holding `occupied` rollout rows
    /// (queued + in-flight) out of `capacity`: eighths of capacity, with
    /// everything at or beyond full capacity in the last bucket.
    pub fn occupancy_bucket(occupied: usize, capacity: usize) -> usize {
        ((occupied * 8) / capacity.max(1)).min(7)
    }

    /// Mean replica occupancy observed at admission, as a fraction of
    /// engine capacity (can exceed 1.0 when admissions queue behind a busy
    /// replica). 0 when nothing was admitted.
    pub fn mean_slot_occupancy(&self) -> f64 {
        if self.slot_capacity_sum == 0 {
            0.0
        } else {
            self.slot_occupancy_sum as f64 / self.slot_capacity_sum as f64
        }
    }

    /// Mean submission-to-execution wait, seconds.
    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.submissions == 0 {
            0.0
        } else {
            self.queue_wait_s / self.submissions as f64
        }
    }

    /// Mean submissions coalesced per executed call.
    pub fn mean_coalesced(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.submissions as f64 / self.calls as f64
        }
    }

    /// Mean fraction of replicas already busy when the router dispatched:
    /// ~0 = an idle pool absorbing everything, ->1 = every replica loaded
    /// (the queue is the bottleneck). 0 when no pool dispatched anything.
    pub fn pool_balance(&self) -> f64 {
        if self.pool_dispatches == 0 || self.engines == 0 {
            0.0
        } else {
            self.pool_busy_sum as f64 / (self.pool_dispatches * self.engines) as f64
        }
    }

    /// Fold an earlier service generation's totals in (a resumed or
    /// save-segmented pipelined run spawns a fresh `InferenceService` per
    /// segment; without merging, the final record would report only the
    /// last segment's service activity). `self` is the newer generation:
    /// its EWMA gap — a latest-value gauge, not a total — wins.
    pub fn merge(&mut self, earlier: &ServiceCounters) {
        self.calls += earlier.calls;
        self.submissions += earlier.submissions;
        self.rows_used += earlier.rows_used;
        self.rows_capacity += earlier.rows_capacity;
        self.max_call_rows = self.max_call_rows.max(earlier.max_call_rows);
        self.queue_wait_s += earlier.queue_wait_s;
        self.installs += earlier.installs;
        self.deadline_dispatches += earlier.deadline_dispatches;
        self.split_calls += earlier.split_calls;
        if self.ewma_gap_s == 0.0 {
            self.ewma_gap_s = earlier.ewma_gap_s;
        }
        for (slot, v) in self.coalesced_hist.iter_mut().zip(earlier.coalesced_hist) {
            *slot += v;
        }
        self.engines = self.engines.max(earlier.engines);
        self.steals += earlier.steals;
        self.pool_dispatches += earlier.pool_dispatches;
        self.pool_busy_sum += earlier.pool_busy_sum;
        for (slot, v) in self.pool_hist.iter_mut().zip(earlier.pool_hist) {
            *slot += v;
        }
        // Per-replica counters merge slot-by-slot: replica index is the
        // deterministic sort order, so segment totals commute.
        for (slot, v) in self.replica_calls.iter_mut().zip(earlier.replica_calls) {
            *slot += v;
        }
        for (slot, v) in self.replica_rows.iter_mut().zip(earlier.replica_rows) {
            *slot += v;
        }
        for (slot, v) in self.replica_installs.iter_mut().zip(earlier.replica_installs) {
            *slot += v;
        }
        for (slot, v) in self.replica_steals.iter_mut().zip(earlier.replica_steals) {
            *slot += v;
        }
        // Versions are gauges: the highest ever installed per slot wins.
        for (slot, v) in self.replica_weight_version.iter_mut().zip(earlier.replica_weight_version)
        {
            *slot = (*slot).max(v);
        }
        for (slot, v) in self.queue_wait_hist.iter_mut().zip(earlier.queue_wait_hist) {
            *slot += v;
        }
        for (slot, v) in self.exec_hist.iter_mut().zip(earlier.exec_hist) {
            *slot += v;
        }
        self.faults_injected += earlier.faults_injected;
        self.retries += earlier.retries;
        self.redispatches += earlier.redispatches;
        self.quarantines += earlier.quarantines;
        self.respawns += earlier.respawns;
        for (slot, v) in self.replica_faults.iter_mut().zip(earlier.replica_faults) {
            *slot += v;
        }
        self.slot_admissions += earlier.slot_admissions;
        self.slot_retires += earlier.slot_retires;
        self.slot_occupancy_sum += earlier.slot_occupancy_sum;
        self.slot_capacity_sum += earlier.slot_capacity_sum;
        for (slot, v) in self.slot_occupancy_hist.iter_mut().zip(earlier.slot_occupancy_hist) {
            *slot += v;
        }
        // The batching mode is a gauge: segments of one run share it.
        self.slots_mode = self.slots_mode.max(earlier.slots_mode);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("calls", Json::num(self.calls as f64)),
            ("submissions", Json::num(self.submissions as f64)),
            ("rows_used", Json::num(self.rows_used as f64)),
            ("rows_capacity", Json::num(self.rows_capacity as f64)),
            ("max_call_rows", Json::num(self.max_call_rows as f64)),
            ("queue_wait_s", Json::num(self.queue_wait_s)),
            ("installs", Json::num(self.installs as f64)),
            ("deadline_dispatches", Json::num(self.deadline_dispatches as f64)),
            ("split_calls", Json::num(self.split_calls as f64)),
            ("ewma_gap_s", Json::num(self.ewma_gap_s)),
            ("mean_fill", Json::num(self.mean_fill())),
            ("mean_coalesced", Json::num(self.mean_coalesced())),
            (
                "coalesced_hist",
                Json::arr(self.coalesced_hist.iter().map(|c| Json::num(*c as f64))),
            ),
            ("engines", Json::num(self.engines as f64)),
            ("steals", Json::num(self.steals as f64)),
            ("pool_dispatches", Json::num(self.pool_dispatches as f64)),
            ("pool_busy_sum", Json::num(self.pool_busy_sum as f64)),
            ("pool_balance", Json::num(self.pool_balance())),
            ("pool_hist", Json::arr(self.pool_hist.iter().map(|c| Json::num(*c as f64)))),
            (
                "replica_calls",
                Json::arr(self.replica_calls.iter().map(|c| Json::num(*c as f64))),
            ),
            ("replica_rows", Json::arr(self.replica_rows.iter().map(|c| Json::num(*c as f64)))),
            (
                "replica_installs",
                Json::arr(self.replica_installs.iter().map(|c| Json::num(*c as f64))),
            ),
            (
                "replica_steals",
                Json::arr(self.replica_steals.iter().map(|c| Json::num(*c as f64))),
            ),
            (
                "replica_weight_version",
                Json::arr(self.replica_weight_version.iter().map(|c| Json::num(*c as f64))),
            ),
            (
                "queue_wait_hist",
                Json::arr(self.queue_wait_hist.iter().map(|c| Json::num(*c as f64))),
            ),
            ("exec_hist", Json::arr(self.exec_hist.iter().map(|c| Json::num(*c as f64)))),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("redispatches", Json::num(self.redispatches as f64)),
            ("quarantines", Json::num(self.quarantines as f64)),
            ("respawns", Json::num(self.respawns as f64)),
            (
                "replica_faults",
                Json::arr(self.replica_faults.iter().map(|c| Json::num(*c as f64))),
            ),
            (
                "queue_wait_p95_s",
                Json::num(crate::trace::hist_quantile(&self.queue_wait_hist, 0.95)),
            ),
            ("exec_p95_s", Json::num(crate::trace::hist_quantile(&self.exec_hist, 0.95))),
            ("slot_admissions", Json::num(self.slot_admissions as f64)),
            ("slot_retires", Json::num(self.slot_retires as f64)),
            ("slot_occupancy_sum", Json::num(self.slot_occupancy_sum as f64)),
            ("slot_capacity_sum", Json::num(self.slot_capacity_sum as f64)),
            (
                "slot_occupancy_hist",
                Json::arr(self.slot_occupancy_hist.iter().map(|c| Json::num(*c as f64))),
            ),
            ("mean_slot_occupancy", Json::num(self.mean_slot_occupancy())),
            ("slots_mode", Json::num(self.slots_mode as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> ServiceCounters {
        let f = |k: &str| j.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
        fn u64s<const N: usize>(j: &Json, k: &str) -> [u64; N] {
            let mut out = [0u64; N];
            if let Some(arr) = j.get(k).and_then(|x| x.as_arr()) {
                for (slot, v) in out.iter_mut().zip(arr) {
                    *slot = v.as_f64().unwrap_or(0.0) as u64;
                }
            }
            out
        }
        ServiceCounters {
            calls: f("calls") as u64,
            submissions: f("submissions") as u64,
            rows_used: f("rows_used") as u64,
            rows_capacity: f("rows_capacity") as u64,
            max_call_rows: f("max_call_rows") as u64,
            queue_wait_s: f("queue_wait_s"),
            installs: f("installs") as u64,
            deadline_dispatches: f("deadline_dispatches") as u64,
            split_calls: f("split_calls") as u64,
            ewma_gap_s: f("ewma_gap_s"),
            coalesced_hist: u64s(j, "coalesced_hist"),
            engines: f("engines") as u64,
            steals: f("steals") as u64,
            pool_dispatches: f("pool_dispatches") as u64,
            pool_busy_sum: f("pool_busy_sum") as u64,
            pool_hist: u64s(j, "pool_hist"),
            replica_calls: u64s(j, "replica_calls"),
            replica_rows: u64s(j, "replica_rows"),
            replica_installs: u64s(j, "replica_installs"),
            replica_steals: u64s(j, "replica_steals"),
            replica_weight_version: u64s(j, "replica_weight_version"),
            queue_wait_hist: u64s(j, "queue_wait_hist"),
            exec_hist: u64s(j, "exec_hist"),
            faults_injected: f("faults_injected") as u64,
            retries: f("retries") as u64,
            redispatches: f("redispatches") as u64,
            quarantines: f("quarantines") as u64,
            respawns: f("respawns") as u64,
            replica_faults: u64s(j, "replica_faults"),
            slot_admissions: f("slot_admissions") as u64,
            slot_retires: f("slot_retires") as u64,
            slot_occupancy_sum: f("slot_occupancy_sum") as u64,
            slot_capacity_sum: f("slot_capacity_sum") as u64,
            slot_occupancy_hist: u64s(j, "slot_occupancy_hist"),
            slots_mode: f("slots_mode") as u64,
        }
    }
}

/// One training step's record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    /// Cumulative training time (inference + update, excl. eval), seconds.
    pub time_s: f64,
    /// Cumulative inference-only seconds (Fig. 2-right split).
    pub inference_s: f64,
    /// Cumulative update-only seconds.
    pub update_s: f64,
    /// Mean pass rate over the prompts actually trained on (Fig. 4-left).
    pub train_pass_rate: f64,
    pub grad_norm: f64,
    pub loss: f64,
    pub clip_frac: f64,
    /// Prompts consumed from the loader so far.
    pub prompts_consumed: usize,
    /// Buffer size after the step (SPEED only; 0 otherwise).
    pub buffer_len: usize,
    /// Mean steps-in-buffer over groups consumed so far (off-policy
    /// staleness diagnostic, §4.3; 0 for unbuffered curricula).
    pub mean_staleness: f64,
    /// Prompts the difficulty predictor has skipped so far (cumulative;
    /// predictive-speed only, 0 otherwise).
    pub prompts_skipped: u64,
    /// Screening rollouts saved by those skips so far (cumulative).
    pub rollouts_saved: u64,
    /// Mean Brier score of the predictor's acceptance forecasts so far (0
    /// when nothing has been scored).
    pub predictor_brier: f64,
    /// Fraction of THIS step's candidate prompts the predictor skipped
    /// (skipped / (skipped + screened) over the step's deltas; 0 when no
    /// candidates were drawn — unlike `prompts_skipped`, not cumulative).
    pub step_skip_rate: f64,
    /// Of this step's skip-rule firings, the fraction screened anyway
    /// (explored / (skipped + explored) over the step's deltas).
    pub step_explore_rate: f64,
    /// Engine calls the shared inference service executed DURING this step
    /// (delta between step snapshots; 0 when no service is running — the
    /// run-level totals live in [`RunRecord::service`]).
    pub service_calls: u64,
    /// Mean fill of THIS step's service calls (rows used / rows executed
    /// over the step's deltas; 0 when no call landed in the step).
    pub service_fill: f64,
    /// Mean submission-to-execution wait of THIS step's submissions,
    /// seconds (0 when none landed in the step).
    pub service_queue_wait_s: f64,
    /// Mean busy-replica fraction over THIS step's pool dispatches (delta
    /// between step snapshots; 0 without a service or with E=1's lone
    /// replica idle at dispatch — see [`ServiceCounters::pool_balance`]).
    pub pool_balance: f64,
    /// p95 submission-to-execution queue wait over THIS step's service
    /// submissions, seconds (upper bucket edge of the step's
    /// `queue_wait_hist` delta; 0 when no service ran or none landed).
    pub service_queue_wait_p95_s: f64,
    /// p95 engine-call execution duration over THIS step's service calls,
    /// real seconds (from the step's `exec_hist` delta; 0 without a
    /// service).
    pub service_exec_p95_s: f64,
    /// Rollouts generated so far (cumulative; the x-axis of the
    /// fixed-vs-adaptive allocation comparison).
    pub rollouts: u64,
    /// Continuation rows allocated DURING this step (delta between step
    /// snapshots; 0 for non-screening curricula).
    pub step_alloc_rows: u64,
    /// Mean squared budget-vs-realized-variance calibration error so far
    /// (cumulative; 0 when no allocated group completed yet).
    pub alloc_calibration: f64,
    /// Engine faults the service observed DURING this step (delta between
    /// step snapshots; 0 without a service or in a fault-free run).
    pub service_faults: u64,
    /// Failed execute attempts the service retried DURING this step (delta
    /// between step snapshots; 0 without a service).
    pub service_retries: u64,
    /// Mean replica slot occupancy over THIS step's admissions, as a
    /// fraction of engine capacity (delta of the service's occupancy sums;
    /// 0 without a service or when nothing was admitted in the step).
    pub slot_occupancy: f64,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("time_s", Json::num(self.time_s)),
            ("inference_s", Json::num(self.inference_s)),
            ("update_s", Json::num(self.update_s)),
            ("train_pass_rate", Json::num(self.train_pass_rate)),
            ("grad_norm", Json::num(self.grad_norm)),
            ("loss", Json::num(self.loss)),
            ("clip_frac", Json::num(self.clip_frac)),
            ("prompts_consumed", Json::num(self.prompts_consumed as f64)),
            ("buffer_len", Json::num(self.buffer_len as f64)),
            ("mean_staleness", Json::num(self.mean_staleness)),
            ("prompts_skipped", Json::num(self.prompts_skipped as f64)),
            ("rollouts_saved", Json::num(self.rollouts_saved as f64)),
            ("predictor_brier", Json::num(self.predictor_brier)),
            ("step_skip_rate", Json::num(self.step_skip_rate)),
            ("step_explore_rate", Json::num(self.step_explore_rate)),
            ("service_calls", Json::num(self.service_calls as f64)),
            ("service_fill", Json::num(self.service_fill)),
            ("service_queue_wait_s", Json::num(self.service_queue_wait_s)),
            ("pool_balance", Json::num(self.pool_balance)),
            ("service_queue_wait_p95_s", Json::num(self.service_queue_wait_p95_s)),
            ("service_exec_p95_s", Json::num(self.service_exec_p95_s)),
            ("rollouts", Json::num(self.rollouts as f64)),
            ("step_alloc_rows", Json::num(self.step_alloc_rows as f64)),
            ("alloc_calibration", Json::num(self.alloc_calibration)),
            ("service_faults", Json::num(self.service_faults as f64)),
            ("service_retries", Json::num(self.service_retries as f64)),
            ("slot_occupancy", Json::num(self.slot_occupancy)),
        ])
    }
}

/// One evaluation point on one benchmark.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub step: usize,
    pub time_s: f64,
    pub benchmark: String,
    pub accuracy: f64,
}

impl EvalRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("time_s", Json::num(self.time_s)),
            ("benchmark", Json::str(self.benchmark.clone())),
            ("accuracy", Json::num(self.accuracy)),
        ])
    }
}

/// Full record of one training run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub label: String,
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub counters: InferenceCounters,
    /// Shared-inference-service counters (runs routed through the
    /// coalescing [`crate::policy::service::InferenceService`] only).
    pub service: Option<ServiceCounters>,
}

impl RunRecord {
    /// Training time (seconds) at which `benchmark`'s accuracy first reaches
    /// `target` — the Table 1 metric. Eval time is already excluded because
    /// `time_s` only accumulates inference + update.
    pub fn time_to_target(&self, benchmark: &str, target: f64) -> Option<f64> {
        self.evals
            .iter()
            .filter(|e| e.benchmark == benchmark)
            .find(|e| e.accuracy >= target)
            .map(|e| e.time_s)
    }

    /// Rollouts generated by the time `benchmark` first reached `target`
    /// (the compute axis of the fixed-vs-adaptive allocation comparison:
    /// same accuracy, fewer rollouts = better allocation). Uses the last
    /// step record preceding the qualifying eval.
    pub fn rollouts_to_target(&self, benchmark: &str, target: f64) -> Option<u64> {
        let eval = self.evals.iter().find(|e| e.benchmark == benchmark && e.accuracy >= target)?;
        let last_step = self.steps.iter().rev().find(|s| s.step < eval.step);
        Some(last_step.map(|s| s.rollouts).unwrap_or(0))
    }

    /// Final accuracy on a benchmark.
    pub fn final_accuracy(&self, benchmark: &str) -> Option<f64> {
        self.evals.iter().rev().find(|e| e.benchmark == benchmark).map(|e| e.accuracy)
    }

    /// Accuracy curve (time, accuracy) for one benchmark.
    pub fn curve(&self, benchmark: &str) -> Vec<(f64, f64)> {
        self.evals
            .iter()
            .filter(|e| e.benchmark == benchmark)
            .map(|e| (e.time_s, e.accuracy))
            .collect()
    }

    pub fn total_time(&self) -> f64 {
        self.steps.last().map(|s| s.time_s).unwrap_or(0.0)
    }

    /// Mean steps-in-buffer over all consumed groups (the cumulative
    /// staleness diagnostic as of the last step).
    pub fn mean_staleness(&self) -> f64 {
        self.steps.last().map(|s| s.mean_staleness).unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        // The counters block is the full raw-field serialization plus the
        // derived ratios (kept for human readers and for older tooling
        // that charted them; parsers recompute ratios from the raw
        // fields). "inference_cost_s" is the pre-checkpoint name of
        // `cost_s`, kept so old readers keep working.
        let counters = {
            let Json::Obj(mut m) = self.counters.to_json() else { unreachable!() };
            m.insert("inference_cost_s".into(), Json::num(self.counters.cost_s));
            m.insert("predictor_brier".into(), Json::num(self.counters.predictor_brier()));
            m.insert(
                "predictor_precision".into(),
                Json::num(self.counters.predictor_precision()),
            );
            m.insert("predictor_recall".into(), Json::num(self.counters.predictor_recall()));
            m.insert("mean_cont_alloc".into(), Json::num(self.counters.mean_cont_alloc()));
            m.insert("alloc_calibration".into(), Json::num(self.counters.alloc_calibration()));
            Json::Obj(m)
        };
        let mut fields = vec![
            ("label", Json::str(self.label.clone())),
            ("steps", Json::arr(self.steps.iter().map(|s| s.to_json()))),
            ("evals", Json::arr(self.evals.iter().map(|e| e.to_json()))),
            ("counters", counters),
        ];
        if let Some(service) = &self.service {
            fields.push(("service", service.to_json()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(step: usize, t: f64, b: &str, acc: f64) -> EvalRecord {
        EvalRecord { step, time_s: t, benchmark: b.to_string(), accuracy: acc }
    }

    #[test]
    fn time_to_target() {
        let rec = RunRecord {
            label: "x".into(),
            evals: vec![
                eval(1, 10.0, "math500", 0.2),
                eval(2, 20.0, "math500", 0.45),
                eval(3, 30.0, "math500", 0.6),
                eval(1, 10.0, "aime", 0.0),
            ],
            ..Default::default()
        };
        assert_eq!(rec.time_to_target("math500", 0.4), Some(20.0));
        assert_eq!(rec.time_to_target("math500", 0.9), None);
        assert_eq!(rec.time_to_target("aime", 0.1), None);
        assert_eq!(rec.final_accuracy("math500"), Some(0.6));
        assert_eq!(rec.curve("math500").len(), 3);
    }

    #[test]
    fn counters_ratios() {
        let c = InferenceCounters {
            rows_used: 50,
            rows_capacity: 100,
            prompts_screened: 10,
            prompts_accepted: 4,
            ..Default::default()
        };
        assert_eq!(c.utilization(), 0.5);
        assert_eq!(c.acceptance_rate(), 0.4);
    }

    #[test]
    fn json_serializes() {
        let rec = RunRecord { label: "t".into(), ..Default::default() };
        let j = rec.to_json();
        assert!(j.get("steps").is_some());
        // the service block appears only when a service actually ran
        assert!(j.get("service").is_none());
        let rec = RunRecord {
            label: "t".into(),
            service: Some(ServiceCounters { calls: 3, ..Default::default() }),
            ..Default::default()
        };
        assert!(rec.to_json().get("service").is_some());
    }

    #[test]
    fn service_counters_ratios_buckets_and_json() {
        let mut c = ServiceCounters {
            calls: 4,
            submissions: 10,
            rows_used: 300,
            rows_capacity: 400,
            max_call_rows: 96,
            queue_wait_s: 0.5,
            installs: 2,
            deadline_dispatches: 1,
            split_calls: 2,
            ewma_gap_s: 0.003,
            coalesced_hist: [1, 0, 1, 2, 0, 0],
            queue_wait_hist: [0, 3, 5, 2, 0, 0, 0, 0],
            exec_hist: [0, 0, 1, 3, 0, 0, 0, 0],
            slot_admissions: 4,
            slot_retires: 3,
            slot_occupancy_sum: 120,
            slot_capacity_sum: 256,
            slot_occupancy_hist: [1, 0, 2, 0, 0, 0, 0, 1],
            slots_mode: 1,
            ..Default::default()
        };
        assert!((c.mean_fill() - 0.75).abs() < 1e-12);
        assert!((c.mean_slot_occupancy() - 120.0 / 256.0).abs() < 1e-12);
        for (occ, cap, bucket) in [(0, 64, 0), (7, 64, 0), (8, 64, 1), (32, 64, 4), (64, 64, 7)] {
            assert_eq!(ServiceCounters::occupancy_bucket(occ, cap), bucket, "occ={occ}");
        }
        // Over-capacity backlog and a zero-capacity engine both clamp to
        // the last bucket instead of indexing out of bounds.
        assert_eq!(ServiceCounters::occupancy_bucket(200, 64), 7);
        assert_eq!(ServiceCounters::occupancy_bucket(5, 0), 7);
        assert!((c.mean_queue_wait_s() - 0.05).abs() < 1e-12);
        assert!((c.mean_coalesced() - 2.5).abs() < 1e-12);
        for (n, bucket) in [(1, 0), (2, 1), (3, 2), (4, 3), (5, 4), (8, 4), (9, 5)] {
            assert_eq!(ServiceCounters::hist_bucket(n), bucket, "n={n}");
        }
        c.coalesced_hist[ServiceCounters::hist_bucket(7)] += 1;
        let back = ServiceCounters::from_json(&c.to_json());
        assert_eq!(back.calls, c.calls);
        assert_eq!(back.submissions, c.submissions);
        assert_eq!(back.rows_used, c.rows_used);
        assert_eq!(back.rows_capacity, c.rows_capacity);
        assert_eq!(back.max_call_rows, c.max_call_rows);
        assert_eq!(back.installs, c.installs);
        assert_eq!(back.deadline_dispatches, c.deadline_dispatches);
        assert_eq!(back.split_calls, c.split_calls);
        assert!((back.ewma_gap_s - c.ewma_gap_s).abs() < 1e-12);
        assert_eq!(back.coalesced_hist, c.coalesced_hist);
        assert!((back.queue_wait_s - c.queue_wait_s).abs() < 1e-12);
        // The latency histograms round-trip raw; the p95 summaries in the
        // JSON are derived (recomputed, never stored authoritatively).
        assert_eq!(back.queue_wait_hist, c.queue_wait_hist);
        assert_eq!(back.exec_hist, c.exec_hist);
        assert_eq!(back.slot_admissions, c.slot_admissions);
        assert_eq!(back.slot_retires, c.slot_retires);
        assert_eq!(back.slot_occupancy_sum, c.slot_occupancy_sum);
        assert_eq!(back.slot_capacity_sum, c.slot_capacity_sum);
        assert_eq!(back.slot_occupancy_hist, c.slot_occupancy_hist);
        assert_eq!(back.slots_mode, c.slots_mode);
        let j = c.to_json();
        assert_eq!(
            j.get("queue_wait_p95_s").unwrap().as_f64().unwrap(),
            crate::trace::hist_quantile(&c.queue_wait_hist, 0.95)
        );
        let empty = ServiceCounters::default();
        assert_eq!(empty.mean_fill(), 0.0);
        assert_eq!(empty.mean_queue_wait_s(), 0.0);
        assert_eq!(empty.mean_coalesced(), 0.0);
        assert_eq!(empty.mean_slot_occupancy(), 0.0);
    }

    #[test]
    fn service_counters_merge_sums_totals_and_keeps_latest_gauge() {
        let earlier = ServiceCounters {
            calls: 4,
            submissions: 10,
            rows_used: 300,
            rows_capacity: 400,
            max_call_rows: 96,
            queue_wait_s: 0.5,
            installs: 2,
            deadline_dispatches: 1,
            split_calls: 1,
            ewma_gap_s: 0.004,
            coalesced_hist: [1, 0, 1, 2, 0, 0],
            queue_wait_hist: [1, 2, 0, 0, 0, 0, 0, 0],
            exec_hist: [0, 1, 1, 0, 0, 0, 0, 0],
            slot_admissions: 4,
            slot_retires: 4,
            slot_occupancy_sum: 100,
            slot_capacity_sum: 200,
            slot_occupancy_hist: [2, 2, 0, 0, 0, 0, 0, 0],
            slots_mode: 1,
            ..Default::default()
        };
        let mut newer = ServiceCounters {
            calls: 2,
            submissions: 3,
            rows_used: 100,
            rows_capacity: 150,
            max_call_rows: 80,
            queue_wait_s: 0.25,
            ewma_gap_s: 0.002,
            coalesced_hist: [1, 1, 0, 0, 0, 0],
            queue_wait_hist: [0, 1, 1, 0, 0, 0, 0, 0],
            exec_hist: [0, 0, 2, 0, 0, 0, 0, 0],
            slot_admissions: 2,
            slot_retires: 1,
            slot_occupancy_sum: 30,
            slot_capacity_sum: 100,
            slot_occupancy_hist: [1, 1, 0, 0, 0, 0, 0, 0],
            ..Default::default()
        };
        newer.merge(&earlier);
        assert_eq!(newer.calls, 6);
        assert_eq!(newer.submissions, 13);
        assert_eq!(newer.rows_used, 400);
        assert_eq!(newer.rows_capacity, 550);
        assert_eq!(newer.max_call_rows, 96);
        assert!((newer.queue_wait_s - 0.75).abs() < 1e-12);
        assert_eq!(newer.installs, 2);
        assert_eq!(newer.split_calls, 1);
        assert_eq!(newer.coalesced_hist, [2, 1, 1, 2, 0, 0]);
        assert_eq!(newer.queue_wait_hist, [1, 3, 1, 0, 0, 0, 0, 0]);
        assert_eq!(newer.exec_hist, [0, 1, 3, 0, 0, 0, 0, 0]);
        assert_eq!(newer.slot_admissions, 6);
        assert_eq!(newer.slot_retires, 5);
        assert_eq!(newer.slot_occupancy_sum, 130);
        assert_eq!(newer.slot_capacity_sum, 300);
        assert_eq!(newer.slot_occupancy_hist, [3, 3, 0, 0, 0, 0, 0, 0]);
        // The batching-mode gauge survives merging deadline-mode segments.
        assert_eq!(newer.slots_mode, 1);
        // latest-value gauge: the newer generation's EWMA wins...
        assert!((newer.ewma_gap_s - 0.002).abs() < 1e-12);
        // ...unless it never observed a gap
        let mut idle = ServiceCounters::default();
        idle.merge(&earlier);
        assert!((idle.ewma_gap_s - 0.004).abs() < 1e-12);
    }

    #[test]
    fn pool_counters_roundtrip_and_merge_in_replica_order() {
        let a = ServiceCounters {
            engines: 2,
            steals: 3,
            pool_dispatches: 10,
            pool_busy_sum: 6,
            pool_hist: [4, 6, 0, 0, 0, 0],
            replica_calls: [6, 4, 0, 0, 0, 0, 0, 0],
            replica_rows: [60, 40, 0, 0, 0, 0, 0, 0],
            replica_installs: [5, 5, 0, 0, 0, 0, 0, 0],
            replica_steals: [1, 2, 0, 0, 0, 0, 0, 0],
            replica_weight_version: [5, 4, 0, 0, 0, 0, 0, 0],
            ..Default::default()
        };
        // busy fraction: 6 busy-replica observations over 10 dispatches x 2
        assert!((a.pool_balance() - 0.3).abs() < 1e-12);
        assert_eq!(ServiceCounters::default().pool_balance(), 0.0);
        let parsed = crate::util::json::Json::parse(&a.to_json().to_string()).unwrap();
        let back = ServiceCounters::from_json(&parsed);
        assert_eq!(back.engines, 2);
        assert_eq!(back.steals, 3);
        assert_eq!(back.pool_dispatches, 10);
        assert_eq!(back.pool_busy_sum, 6);
        assert_eq!(back.pool_hist, a.pool_hist);
        assert_eq!(back.replica_calls, a.replica_calls);
        assert_eq!(back.replica_rows, a.replica_rows);
        assert_eq!(back.replica_installs, a.replica_installs);
        assert_eq!(back.replica_steals, a.replica_steals);
        assert_eq!(back.replica_weight_version, a.replica_weight_version);

        // Merging two segments' pool counters: per-replica slots sum
        // index-by-index (replica index = the sorted merge order), version
        // gauges take the per-slot max — and the result is the same
        // whichever segment folds into which, so resumed pool runs report
        // stable totals.
        let b = ServiceCounters {
            engines: 2,
            steals: 1,
            pool_dispatches: 4,
            pool_busy_sum: 4,
            pool_hist: [0, 4, 0, 0, 0, 0],
            replica_calls: [2, 7, 0, 0, 0, 0, 0, 0],
            replica_rows: [20, 70, 0, 0, 0, 0, 0, 0],
            replica_installs: [3, 3, 0, 0, 0, 0, 0, 0],
            replica_steals: [0, 1, 0, 0, 0, 0, 0, 0],
            replica_weight_version: [9, 3, 0, 0, 0, 0, 0, 0],
            ..Default::default()
        };
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab.replica_calls, [8, 11, 0, 0, 0, 0, 0, 0]);
        assert_eq!(ab.replica_calls, ba.replica_calls);
        assert_eq!(ab.replica_rows, ba.replica_rows);
        assert_eq!(ab.replica_installs, [8, 8, 0, 0, 0, 0, 0, 0]);
        assert_eq!(ab.replica_steals, ba.replica_steals);
        assert_eq!(ab.replica_weight_version, [9, 4, 0, 0, 0, 0, 0, 0]);
        assert_eq!(ab.replica_weight_version, ba.replica_weight_version);
        assert_eq!(ab.engines, 2);
        assert_eq!(ab.steals, 4);
        assert_eq!(ab.pool_dispatches, 14);
        assert_eq!(ab.pool_hist, ba.pool_hist);
        assert!((ab.pool_balance() - 10.0 / 28.0).abs() < 1e-12);
    }

    #[test]
    fn fault_counters_roundtrip_and_merge_slot_by_slot() {
        let a = ServiceCounters {
            engines: 3,
            faults_injected: 3,
            retries: 2,
            redispatches: 4,
            quarantines: 2,
            respawns: 1,
            replica_faults: [0, 1, 2, 0, 0, 0, 0, 0],
            ..Default::default()
        };
        let parsed = crate::util::json::Json::parse(&a.to_json().to_string()).unwrap();
        let back = ServiceCounters::from_json(&parsed);
        assert_eq!(back.faults_injected, 3);
        assert_eq!(back.retries, 2);
        assert_eq!(back.redispatches, 4);
        assert_eq!(back.quarantines, 2);
        assert_eq!(back.respawns, 1);
        assert_eq!(back.replica_faults, a.replica_faults);
        // A fault-free record parses back to all-zero fault counters (and
        // legacy records without the fields do too).
        let clean = ServiceCounters::default();
        let clean_back = ServiceCounters::from_json(
            &crate::util::json::Json::parse(&clean.to_json().to_string()).unwrap(),
        );
        assert_eq!(clean_back.faults_injected, 0);
        assert_eq!(clean_back.replica_faults, [0; MAX_POOL]);

        // Segmented save/resume runs fold fault counters deterministically:
        // totals sum, per-replica slots sum index-by-index, and the result
        // is independent of merge direction.
        let b = ServiceCounters {
            engines: 3,
            faults_injected: 1,
            retries: 3,
            redispatches: 0,
            quarantines: 1,
            respawns: 0,
            replica_faults: [1, 0, 0, 0, 0, 0, 0, 0],
            ..Default::default()
        };
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab.faults_injected, 4);
        assert_eq!(ab.retries, 5);
        assert_eq!(ab.redispatches, 4);
        assert_eq!(ab.quarantines, 3);
        assert_eq!(ab.respawns, 1);
        assert_eq!(ab.replica_faults, [1, 1, 2, 0, 0, 0, 0, 0]);
        assert_eq!(ab.faults_injected, ba.faults_injected);
        assert_eq!(ab.retries, ba.retries);
        assert_eq!(ab.redispatches, ba.redispatches);
        assert_eq!(ab.quarantines, ba.quarantines);
        assert_eq!(ab.respawns, ba.respawns);
        assert_eq!(ab.replica_faults, ba.replica_faults);
    }

    #[test]
    fn counters_json_roundtrip_preserves_every_raw_field() {
        let c = InferenceCounters {
            calls: 3,
            rows_used: 10,
            rows_capacity: 20,
            cost_s: 0.1 + 0.2, // no short decimal form: exercises exact f64 round-trip
            prompts_screened: 9,
            prompts_accepted: 4,
            rollouts: 100,
            busy_s: 1.5,
            prompts_skipped: 2,
            prompts_explored: 1,
            rollouts_saved: 16,
            pred_tp: 1,
            pred_fp: 2,
            pred_tn: 3,
            pred_fn: 4,
            brier_sum: 0.375,
            brier_n: 9,
            prompts_allocated: 4,
            cont_rows_allocated: 60,
            alloc_hist: [0, 1, 2, 1, 0, 0],
            alloc_calib_sum: 0.5,
            alloc_calib_n: 2,
        };
        let text = c.to_json().to_string();
        let back = InferenceCounters::from_json(&crate::util::json::Json::parse(&text).unwrap());
        let mut merged = back;
        merged.merge(&InferenceCounters::default());
        assert_eq!(merged.calls, c.calls);
        assert_eq!(merged.cost_s.to_bits(), c.cost_s.to_bits());
        assert_eq!(merged.busy_s.to_bits(), c.busy_s.to_bits());
        assert_eq!(merged.brier_sum.to_bits(), c.brier_sum.to_bits());
        assert_eq!(merged.pred_tp, 1);
        assert_eq!(merged.pred_fn, 4);
        assert_eq!(merged.alloc_hist, c.alloc_hist);
        assert_eq!(merged.alloc_calib_n, 2);
        assert_eq!(merged.prompts_explored, 1);
        // legacy records spelled cost_s "inference_cost_s"
        let legacy = crate::util::json::Json::obj(vec![
            ("calls", Json::num(2)),
            ("inference_cost_s", Json::num(3.5)),
        ]);
        let parsed = InferenceCounters::from_json(&legacy);
        assert_eq!(parsed.calls, 2);
        assert_eq!(parsed.cost_s, 3.5);
    }

    #[test]
    fn merge_and_atomic_add_stay_in_sync() {
        // Guard: a field added to InferenceCounters must be carried by both
        // accumulation paths (plain merge and the atomic worker path).
        let a = InferenceCounters {
            calls: 1,
            rows_used: 2,
            rows_capacity: 3,
            cost_s: 0.5,
            prompts_screened: 4,
            prompts_accepted: 2,
            rollouts: 7,
            busy_s: 0.25,
            prompts_skipped: 5,
            prompts_explored: 1,
            rollouts_saved: 40,
            pred_tp: 3,
            pred_fp: 1,
            pred_tn: 2,
            pred_fn: 1,
            brier_sum: 0.375,
            brier_n: 7,
            prompts_allocated: 2,
            cont_rows_allocated: 36,
            alloc_hist: [0, 1, 1, 0, 0, 0],
            alloc_calib_sum: 0.5,
            alloc_calib_n: 2,
        };
        let b = InferenceCounters {
            calls: 10,
            cost_s: 1.5,
            busy_s: 0.75,
            prompts_skipped: 2,
            rollouts_saved: 16,
            brier_sum: 0.125,
            brier_n: 3,
            prompts_allocated: 1,
            cont_rows_allocated: 40,
            alloc_hist: [0, 0, 0, 0, 1, 0],
            alloc_calib_sum: 0.25,
            alloc_calib_n: 1,
            ..Default::default()
        };
        let mut merged = a;
        merged.merge(&b);

        let atomic = AtomicCounters::default();
        atomic.add(&a);
        atomic.add(&b);
        let snap = atomic.snapshot();

        assert_eq!(merged.calls, snap.calls);
        assert_eq!(merged.rows_used, snap.rows_used);
        assert_eq!(merged.rows_capacity, snap.rows_capacity);
        assert_eq!(merged.prompts_screened, snap.prompts_screened);
        assert_eq!(merged.prompts_accepted, snap.prompts_accepted);
        assert_eq!(merged.rollouts, snap.rollouts);
        assert!((merged.cost_s - snap.cost_s).abs() < 1e-12);
        assert!((merged.busy_s - snap.busy_s).abs() < 1e-12);
        assert_eq!(merged.prompts_skipped, snap.prompts_skipped);
        assert_eq!(merged.prompts_explored, snap.prompts_explored);
        assert_eq!(merged.rollouts_saved, snap.rollouts_saved);
        assert_eq!(merged.pred_tp, snap.pred_tp);
        assert_eq!(merged.pred_fp, snap.pred_fp);
        assert_eq!(merged.pred_tn, snap.pred_tn);
        assert_eq!(merged.pred_fn, snap.pred_fn);
        assert!((merged.brier_sum - snap.brier_sum).abs() < 1e-12);
        assert_eq!(merged.brier_n, snap.brier_n);
        assert_eq!(merged.prompts_allocated, snap.prompts_allocated);
        assert_eq!(merged.cont_rows_allocated, snap.cont_rows_allocated);
        assert_eq!(merged.alloc_hist, snap.alloc_hist);
        assert!((merged.alloc_calib_sum - snap.alloc_calib_sum).abs() < 1e-12);
        assert_eq!(merged.alloc_calib_n, snap.alloc_calib_n);
    }

    #[test]
    fn allocation_accounting_and_ratios() {
        let mut c = InferenceCounters::default();
        assert_eq!(c.mean_cont_alloc(), 0.0);
        assert_eq!(c.alloc_calibration(), 0.0);
        c.record_allocation(4);
        c.record_allocation(20);
        c.record_allocation(70);
        assert_eq!(c.prompts_allocated, 3);
        assert_eq!(c.cont_rows_allocated, 94);
        assert_eq!(c.alloc_hist, [1, 0, 0, 1, 0, 1]);
        assert!((c.mean_cont_alloc() - 94.0 / 3.0).abs() < 1e-12);
        // forecast 0.25 vs realized pass rate 0.5 (var 0.25): perfect
        c.record_alloc_outcome(0.25, 0.5);
        assert_eq!(c.alloc_calibration(), 0.0);
        // forecast 0.25 vs realized 0.0 (var 0.0): sq err 0.0625
        c.record_alloc_outcome(0.25, 0.0);
        assert!((c.alloc_calibration() - 0.0625 / 2.0).abs() < 1e-12);
        let cases =
            [(1, 0), (4, 0), (5, 1), (8, 1), (9, 2), (16, 2), (17, 3), (32, 3), (33, 4), (65, 5)];
        for (n, bucket) in cases {
            assert_eq!(InferenceCounters::alloc_hist_bucket(n), bucket, "n={n}");
        }
    }

    #[test]
    fn predictor_quality_ratios() {
        let c = InferenceCounters {
            pred_tp: 6,
            pred_fp: 2,
            pred_tn: 5,
            pred_fn: 3,
            brier_sum: 1.6,
            brier_n: 16,
            ..Default::default()
        };
        assert!((c.predictor_precision() - 0.75).abs() < 1e-12);
        assert!((c.predictor_recall() - 6.0 / 9.0).abs() < 1e-12);
        assert!((c.predictor_brier() - 0.1).abs() < 1e-12);
        let empty = InferenceCounters::default();
        assert_eq!(empty.predictor_precision(), 0.0);
        assert_eq!(empty.predictor_recall(), 0.0);
        assert_eq!(empty.predictor_brier(), 0.0);
    }
}
