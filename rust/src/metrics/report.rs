//! ASCII report rendering for run records: accuracy-vs-time curves and
//! side-by-side run comparison (the terminal stand-in for the paper's
//! matplotlib figures). Used by `speed-rl report` and the benches.

use crate::metrics::RunRecord;
use crate::util::json::Json;

/// Render one benchmark's curves for several runs as an ASCII chart.
pub fn ascii_chart(
    records: &[&RunRecord],
    benchmark: &str,
    width: usize,
    height: usize,
) -> String {
    let curves: Vec<(&str, Vec<(f64, f64)>)> = records
        .iter()
        .map(|r| (r.label.as_str(), r.curve(benchmark)))
        .filter(|(_, c)| !c.is_empty())
        .collect();
    if curves.is_empty() {
        return format!("(no data for {benchmark})\n");
    }
    let t_max = curves
        .iter()
        .flat_map(|(_, c)| c.iter().map(|(t, _)| *t))
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let a_min = 0.0f64;
    let a_max = curves
        .iter()
        .flat_map(|(_, c)| c.iter().map(|(_, a)| *a))
        .fold(0.0f64, f64::max)
        .max(1e-9)
        * 1.05;

    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        let mark = marks[ci % marks.len()];
        // linear interpolation across columns for continuous lines
        for col in 0..width {
            let t = t_max * col as f64 / (width - 1) as f64;
            let a = interp(curve, t);
            let row = ((a - a_min) / (a_max - a_min) * (height - 1) as f64).round() as usize;
            let row = (height - 1).saturating_sub(row.min(height - 1));
            if grid[row][col] == ' ' || ci > 0 {
                grid[row][col] = mark;
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{benchmark} (accuracy vs time; max t = {:.2} h)\n", t_max / 3600.0));
    for (i, row) in grid.iter().enumerate() {
        let yval = a_max * (height - 1 - i) as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:5.2} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("      +{}+\n", "-".repeat(width)));
    for (ci, (label, _)) in curves.iter().enumerate() {
        out.push_str(&format!("        {} {label}\n", marks[ci % marks.len()]));
    }
    out
}

fn interp(curve: &[(f64, f64)], t: f64) -> f64 {
    if curve.is_empty() {
        return 0.0;
    }
    if t <= curve[0].0 {
        return curve[0].1;
    }
    for w in curve.windows(2) {
        let (t0, a0) = w[0];
        let (t1, a1) = w[1];
        if t <= t1 {
            if t1 - t0 < 1e-12 {
                return a1;
            }
            return a0 + (a1 - a0) * (t - t0) / (t1 - t0);
        }
    }
    curve.last().unwrap().1
}

/// Parse a run record back from the JSON written by `RunRecord::to_json`.
pub fn record_from_json(j: &Json) -> anyhow::Result<RunRecord> {
    use crate::metrics::{EvalRecord, StepRecord};
    let mut rec = RunRecord {
        label: j.get("label").and_then(|x| x.as_str()).unwrap_or("run").to_string(),
        ..Default::default()
    };
    if let Some(steps) = j.get("steps").and_then(|x| x.as_arr()) {
        for s in steps {
            let f = |k: &str| s.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            rec.steps.push(StepRecord {
                step: f("step") as usize,
                time_s: f("time_s"),
                inference_s: f("inference_s"),
                update_s: f("update_s"),
                train_pass_rate: f("train_pass_rate"),
                grad_norm: f("grad_norm"),
                loss: f("loss"),
                clip_frac: f("clip_frac"),
                prompts_consumed: f("prompts_consumed") as usize,
                buffer_len: f("buffer_len") as usize,
                mean_staleness: f("mean_staleness"),
                prompts_skipped: f("prompts_skipped") as u64,
                rollouts_saved: f("rollouts_saved") as u64,
                predictor_brier: f("predictor_brier"),
            });
        }
    }
    if let Some(evals) = j.get("evals").and_then(|x| x.as_arr()) {
        for e in evals {
            let f = |k: &str| e.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            rec.evals.push(EvalRecord {
                step: f("step") as usize,
                time_s: f("time_s"),
                benchmark: e
                    .get("benchmark")
                    .and_then(|x| x.as_str())
                    .unwrap_or("")
                    .to_string(),
                accuracy: f("accuracy"),
            });
        }
    }
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalRecord;

    fn rec(label: &str, pts: &[(f64, f64)]) -> RunRecord {
        RunRecord {
            label: label.to_string(),
            evals: pts
                .iter()
                .enumerate()
                .map(|(i, (t, a))| EvalRecord {
                    step: i,
                    time_s: *t,
                    benchmark: "b".into(),
                    accuracy: *a,
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn chart_renders_marks_and_legend() {
        let a = rec("fast", &[(0.0, 0.1), (100.0, 0.8)]);
        let b = rec("slow", &[(0.0, 0.1), (100.0, 0.4)]);
        let chart = ascii_chart(&[&a, &b], "b", 40, 10);
        assert!(chart.contains('*') && chart.contains('+'));
        assert!(chart.contains("fast") && chart.contains("slow"));
    }

    #[test]
    fn interp_endpoints_and_midpoint() {
        let c = [(0.0, 0.0), (10.0, 1.0)];
        assert_eq!(interp(&c, -5.0), 0.0);
        assert_eq!(interp(&c, 20.0), 1.0);
        assert!((interp(&c, 5.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_record() {
        let a = rec("x", &[(0.0, 0.2), (50.0, 0.6)]);
        let back = record_from_json(&a.to_json()).unwrap();
        assert_eq!(back.label, "x");
        assert_eq!(back.curve("b"), a.curve("b"));
    }
}
