//! ASCII report rendering for run records: accuracy-vs-time curves,
//! per-step diagnostic curves (skip/exploration rates, service fill), and
//! side-by-side run comparison (the terminal stand-in for the paper's
//! matplotlib figures). Used by `speed-rl report` and the benches.

use crate::metrics::{RunRecord, ServiceCounters, StepRecord};
use crate::util::json::Json;

/// Render one benchmark's curves for several runs as an ASCII chart.
pub fn ascii_chart(
    records: &[&RunRecord],
    benchmark: &str,
    width: usize,
    height: usize,
) -> String {
    let curves: Vec<(&str, Vec<(f64, f64)>)> = records
        .iter()
        .map(|r| (r.label.as_str(), r.curve(benchmark)))
        .filter(|(_, c)| !c.is_empty())
        .collect();
    if curves.is_empty() {
        return format!("(no data for {benchmark})\n");
    }
    render_chart(&format!("{benchmark} (accuracy vs time)"), &curves, width, height, 3600.0, "h")
}

/// The per-step metric table `speed-rl report --metric` charts from
/// [`StepRecord`] (ROADMAP item: the cumulative counters hid how the
/// predictor's skip rate warms up and how full the service keeps calls).
/// One row per metric, so the chart dispatch and the unknown-metric error
/// listing can never drift apart.
pub const STEP_METRICS: &[(&str, fn(&StepRecord) -> f64)] = &[
    ("skip-rate", |s: &StepRecord| s.step_skip_rate),
    ("explore-rate", |s: &StepRecord| s.step_explore_rate),
    ("service-fill", |s: &StepRecord| s.service_fill),
    ("pool-balance", |s: &StepRecord| s.pool_balance),
    ("staleness", |s: &StepRecord| s.mean_staleness),
    ("alloc-rows", |s: &StepRecord| s.step_alloc_rows as f64),
    ("alloc-calibration", |s: &StepRecord| s.alloc_calibration),
    ("queue-wait-p95", |s: &StepRecord| s.service_queue_wait_p95_s),
    ("exec-p95", |s: &StepRecord| s.service_exec_p95_s),
    ("faults", |s: &StepRecord| s.service_faults as f64),
    ("retries", |s: &StepRecord| s.service_retries as f64),
    ("slot-occupancy", |s: &StepRecord| s.slot_occupancy),
];

/// Numeric [`StepRecord`] fields intentionally NOT charted by
/// `speed-rl report --metric`: each already has a better surface — the
/// x-axes of the charts themselves, the headline accuracy-vs-time
/// curves, `print_summary` lines, or a charted per-step ratio derived
/// from it. The `speed-rl lint` L5 pass requires every numeric
/// [`StepRecord`] field to be reachable from [`STEP_METRICS`] or listed
/// here, so per-step telemetry cannot land unreachable from every chart
/// without an explicit exemption (DESIGN.md §15).
pub const STEP_METRICS_EXEMPT: &[&str] = &[
    "step",                  // the x-axis of every per-step chart
    "time_s",                // the x-axis of the accuracy-vs-time charts
    "inference_s",           // print_summary's time split
    "update_s",              // print_summary's time split
    "train_pass_rate",       // headline band-composition diagnostic
    "grad_norm",             // Fig. 4-right comparison output
    "loss",                  // print_summary
    "clip_frac",             // print_summary
    "prompts_consumed",      // feeds the skip-rate ratio
    "buffer_len",            // staleness chart's companion gauge
    "prompts_skipped",       // cumulative twin of skip-rate
    "rollouts_saved",        // cumulative twin of skip-rate
    "predictor_brier",       // print_summary calibration line
    "service_calls",         // cumulative twin of service-fill
    "service_queue_wait_s",  // mean twin of queue-wait-p95
    "rollouts",              // the x-axis of the allocation comparison
];

/// Look up a per-step metric by its `--metric` name.
pub fn step_metric(metric: &str) -> Option<fn(&StepRecord) -> f64> {
    STEP_METRICS.iter().find(|(name, _)| *name == metric).map(|(_, f)| *f)
}

/// Every valid `--metric` name, comma-joined (for help/error text).
pub fn step_metric_names() -> String {
    STEP_METRICS.iter().map(|(name, _)| *name).collect::<Vec<_>>().join(", ")
}

/// Render one per-step metric for several runs (x = step, y = metric).
pub fn step_chart(
    records: &[&RunRecord],
    metric: &str,
    width: usize,
    height: usize,
) -> anyhow::Result<String> {
    let f = step_metric(metric).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown per-step metric '{metric}' (valid: {}; eval curves use the default \
             accuracy mode)",
            step_metric_names()
        )
    })?;
    let curves: Vec<(&str, Vec<(f64, f64)>)> = records
        .iter()
        .map(|r| {
            let pts = r.steps.iter().map(|s| (s.step as f64, f(s))).collect::<Vec<_>>();
            (r.label.as_str(), pts)
        })
        .filter(|(_, c)| !c.is_empty())
        .collect();
    if curves.is_empty() {
        return Ok(format!("(no step data for {metric})\n"));
    }
    Ok(render_chart(&format!("{metric} (per step)"), &curves, width, height, 1.0, "steps"))
}

/// Shared grid renderer: linear interpolation across columns, one mark per
/// run, y scaled to the observed maximum; the header reports the x range
/// as `x_max / x_scale` in `x_unit` (hours for time axes, steps for
/// per-step axes).
fn render_chart(
    title: &str,
    curves: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
    x_scale: f64,
    x_unit: &str,
) -> String {
    let t_max = curves
        .iter()
        .flat_map(|(_, c)| c.iter().map(|(t, _)| *t))
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let a_min = 0.0f64;
    let a_max = curves
        .iter()
        .flat_map(|(_, c)| c.iter().map(|(_, a)| *a))
        .fold(0.0f64, f64::max)
        .max(1e-9)
        * 1.05;

    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        let mark = marks[ci % marks.len()];
        // linear interpolation across columns for continuous lines
        for col in 0..width {
            let t = t_max * col as f64 / (width - 1) as f64;
            let a = interp(curve, t);
            let row = ((a - a_min) / (a_max - a_min) * (height - 1) as f64).round() as usize;
            let row = (height - 1).saturating_sub(row.min(height - 1));
            if grid[row][col] == ' ' || ci > 0 {
                grid[row][col] = mark;
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}; max x = {:.2} {x_unit}\n", t_max / x_scale));
    for (i, row) in grid.iter().enumerate() {
        let yval = a_max * (height - 1 - i) as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:5.2} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("      +{}+\n", "-".repeat(width)));
    for (ci, (label, _)) in curves.iter().enumerate() {
        out.push_str(&format!("        {} {label}\n", marks[ci % marks.len()]));
    }
    out
}

fn interp(curve: &[(f64, f64)], t: f64) -> f64 {
    if curve.is_empty() {
        return 0.0;
    }
    if t <= curve[0].0 {
        return curve[0].1;
    }
    for w in curve.windows(2) {
        let (t0, a0) = w[0];
        let (t1, a1) = w[1];
        if t <= t1 {
            if t1 - t0 < 1e-12 {
                return a1;
            }
            return a0 + (a1 - a0) * (t - t0) / (t1 - t0);
        }
    }
    curve.last().unwrap().1
}

/// Parse a run record back from the JSON written by `RunRecord::to_json`.
///
/// Robust to format age: every step/counter field absent from the record
/// defaults explicitly (pre-PR-3 records lack `step_skip_rate`/service
/// deltas, pre-PR-4 records lack `step_alloc_rows`/`alloc_calibration`/
/// `rollouts`, and only post-checkpoint records carry raw counter fields),
/// so `speed-rl report` keeps working on old logs — including logs a
/// resumed run appends to, which can mix generations in one directory.
pub fn record_from_json(j: &Json) -> anyhow::Result<RunRecord> {
    use crate::metrics::{EvalRecord, InferenceCounters};
    let mut rec = RunRecord {
        label: j.get("label").and_then(|x| x.as_str()).unwrap_or("run").to_string(),
        ..Default::default()
    };
    if let Some(c) = j.get("counters") {
        rec.counters = InferenceCounters::from_json(c);
    }
    if let Some(steps) = j.get("steps").and_then(|x| x.as_arr()) {
        for s in steps {
            let f = |k: &str| s.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            rec.steps.push(StepRecord {
                step: f("step") as usize,
                time_s: f("time_s"),
                inference_s: f("inference_s"),
                update_s: f("update_s"),
                train_pass_rate: f("train_pass_rate"),
                grad_norm: f("grad_norm"),
                loss: f("loss"),
                clip_frac: f("clip_frac"),
                prompts_consumed: f("prompts_consumed") as usize,
                buffer_len: f("buffer_len") as usize,
                mean_staleness: f("mean_staleness"),
                prompts_skipped: f("prompts_skipped") as u64,
                rollouts_saved: f("rollouts_saved") as u64,
                predictor_brier: f("predictor_brier"),
                step_skip_rate: f("step_skip_rate"),
                step_explore_rate: f("step_explore_rate"),
                service_calls: f("service_calls") as u64,
                service_fill: f("service_fill"),
                service_queue_wait_s: f("service_queue_wait_s"),
                pool_balance: f("pool_balance"),
                service_queue_wait_p95_s: f("service_queue_wait_p95_s"),
                service_exec_p95_s: f("service_exec_p95_s"),
                rollouts: f("rollouts") as u64,
                step_alloc_rows: f("step_alloc_rows") as u64,
                alloc_calibration: f("alloc_calibration"),
                service_faults: f("service_faults") as u64,
                service_retries: f("service_retries") as u64,
                slot_occupancy: f("slot_occupancy"),
            });
        }
    }
    rec.service = j.get("service").map(ServiceCounters::from_json);
    if let Some(evals) = j.get("evals").and_then(|x| x.as_arr()) {
        for e in evals {
            let f = |k: &str| e.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            rec.evals.push(EvalRecord {
                step: f("step") as usize,
                time_s: f("time_s"),
                benchmark: e
                    .get("benchmark")
                    .and_then(|x| x.as_str())
                    .unwrap_or("")
                    .to_string(),
                accuracy: f("accuracy"),
            });
        }
    }
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalRecord;

    fn rec(label: &str, pts: &[(f64, f64)]) -> RunRecord {
        RunRecord {
            label: label.to_string(),
            evals: pts
                .iter()
                .enumerate()
                .map(|(i, (t, a))| EvalRecord {
                    step: i,
                    time_s: *t,
                    benchmark: "b".into(),
                    accuracy: *a,
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn chart_renders_marks_and_legend() {
        let a = rec("fast", &[(0.0, 0.1), (100.0, 0.8)]);
        let b = rec("slow", &[(0.0, 0.1), (100.0, 0.4)]);
        let chart = ascii_chart(&[&a, &b], "b", 40, 10);
        assert!(chart.contains('*') && chart.contains('+'));
        assert!(chart.contains("fast") && chart.contains("slow"));
    }

    #[test]
    fn interp_endpoints_and_midpoint() {
        let c = [(0.0, 0.0), (10.0, 1.0)];
        assert_eq!(interp(&c, -5.0), 0.0);
        assert_eq!(interp(&c, 20.0), 1.0);
        assert!((interp(&c, 5.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_record() {
        let a = rec("x", &[(0.0, 0.2), (50.0, 0.6)]);
        let back = record_from_json(&a.to_json()).unwrap();
        assert_eq!(back.label, "x");
        assert_eq!(back.curve("b"), a.curve("b"));
        assert!(back.service.is_none());
    }

    #[test]
    fn json_roundtrip_preserves_step_rates_and_service() {
        let mut a = rec("x", &[(0.0, 0.2)]);
        a.steps.push(StepRecord {
            step: 0,
            time_s: 1.0,
            inference_s: 0.7,
            update_s: 0.3,
            train_pass_rate: 0.5,
            grad_norm: 0.1,
            loss: -0.5,
            clip_frac: 0.0,
            prompts_consumed: 10,
            buffer_len: 2,
            mean_staleness: 0.5,
            prompts_skipped: 3,
            rollouts_saved: 24,
            predictor_brier: 0.1,
            step_skip_rate: 0.25,
            step_explore_rate: 0.1,
            service_calls: 4,
            service_fill: 0.8,
            service_queue_wait_s: 0.002,
            pool_balance: 0.4,
            service_queue_wait_p95_s: 0.01,
            service_exec_p95_s: 0.1,
            rollouts: 768,
            step_alloc_rows: 96,
            alloc_calibration: 0.02,
            service_faults: 2,
            service_retries: 5,
            slot_occupancy: 0.6,
        });
        a.service = Some(ServiceCounters {
            calls: 4,
            submissions: 9,
            rows_used: 300,
            rows_capacity: 400,
            ..Default::default()
        });
        let back = record_from_json(&a.to_json()).unwrap();
        let s = &back.steps[0];
        assert!((s.step_skip_rate - 0.25).abs() < 1e-12);
        assert!((s.step_explore_rate - 0.1).abs() < 1e-12);
        assert_eq!(s.service_calls, 4);
        assert!((s.service_fill - 0.8).abs() < 1e-12);
        assert!((s.pool_balance - 0.4).abs() < 1e-12);
        assert!((s.service_queue_wait_p95_s - 0.01).abs() < 1e-12);
        assert!((s.service_exec_p95_s - 0.1).abs() < 1e-12);
        assert_eq!(s.rollouts, 768);
        assert_eq!(s.step_alloc_rows, 96);
        assert!((s.alloc_calibration - 0.02).abs() < 1e-12);
        assert_eq!(s.service_faults, 2);
        assert_eq!(s.service_retries, 5);
        assert!((s.slot_occupancy - 0.6).abs() < 1e-12);
        let svc = back.service.expect("service parsed");
        assert_eq!(svc.calls, 4);
        assert_eq!(svc.submissions, 9);
        assert!((svc.mean_fill() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn parses_pre_pr3_records_with_explicit_defaults() {
        // A fixture in the PR-2-era format: steps lack every post-PR-3/4
        // field (per-step rates, service deltas, alloc telemetry), the
        // counters block stores only the old subset + derived ratios, and
        // there is no service block at all. The parser must fill explicit
        // defaults, not error — `speed-rl report` runs on old logs that a
        // resumed run appends new-format records next to.
        let fixture = r#"{
            "label": "pre-pr3",
            "steps": [
                {"step": 0, "time_s": 80.5, "inference_s": 55.0, "update_s": 25.5,
                 "train_pass_rate": 0.5, "grad_norm": 0.4, "loss": -0.5, "clip_frac": 0.0,
                 "prompts_consumed": 32, "buffer_len": 3, "mean_staleness": 0.25,
                 "prompts_skipped": 4, "rollouts_saved": 32, "predictor_brier": 0.12}
            ],
            "evals": [
                {"step": 0, "time_s": 0, "benchmark": "dapo1k", "accuracy": 0.37}
            ],
            "counters": {
                "calls": 10, "rows_used": 300, "rows_capacity": 384,
                "inference_cost_s": 55.0, "prompts_screened": 64,
                "prompts_accepted": 30, "rollouts": 752,
                "predictor_brier": 0.12, "predictor_precision": 0.9
            }
        }"#;
        let rec = record_from_json(&Json::parse(fixture).unwrap()).unwrap();
        assert_eq!(rec.label, "pre-pr3");
        assert_eq!(rec.steps.len(), 1);
        let s = &rec.steps[0];
        // present fields survive
        assert_eq!(s.prompts_skipped, 4);
        assert!((s.mean_staleness - 0.25).abs() < 1e-12);
        // absent post-PR-3/PR-4 fields get explicit defaults
        assert_eq!(s.step_skip_rate, 0.0);
        assert_eq!(s.service_calls, 0);
        assert_eq!(s.rollouts, 0);
        assert_eq!(s.step_alloc_rows, 0);
        assert_eq!(s.alloc_calibration, 0.0);
        // the old counters subset parses (including the legacy cost name);
        // raw predictor fields absent from old records default to zero and
        // the derived ratios are recomputed, not trusted
        assert_eq!(rec.counters.calls, 10);
        assert_eq!(rec.counters.rollouts, 752);
        assert_eq!(rec.counters.cost_s, 55.0);
        assert_eq!(rec.counters.brier_n, 0);
        assert_eq!(rec.counters.predictor_brier(), 0.0);
        // no service block: None, and the accuracy chart still renders
        assert!(rec.service.is_none());
        let chart = ascii_chart(&[&rec], "dapo1k", 30, 8);
        assert!(chart.contains("pre-pr3"));
    }

    #[test]
    fn step_chart_renders_and_rejects_unknown_metric() {
        let mut a = rec("run", &[]);
        for step in 0..5 {
            a.steps.push(StepRecord {
                step,
                time_s: step as f64,
                inference_s: 0.0,
                update_s: 0.0,
                train_pass_rate: 0.5,
                grad_norm: 0.0,
                loss: 0.0,
                clip_frac: 0.0,
                prompts_consumed: step,
                buffer_len: 0,
                mean_staleness: 0.0,
                prompts_skipped: 0,
                rollouts_saved: 0,
                predictor_brier: 0.0,
                step_skip_rate: 0.1 * step as f64,
                step_explore_rate: 0.0,
                service_calls: 0,
                service_fill: 0.0,
                service_queue_wait_s: 0.0,
                pool_balance: 0.0,
                service_queue_wait_p95_s: 0.0,
                service_exec_p95_s: 0.0,
                rollouts: 0,
                step_alloc_rows: 0,
                alloc_calibration: 0.0,
                service_faults: 0,
                service_retries: 0,
                slot_occupancy: 0.0,
            });
        }
        let chart = step_chart(&[&a], "skip-rate", 30, 8).unwrap();
        assert!(chart.contains("skip-rate") && chart.contains("run"));
        // The error must list EVERY valid metric (it is derived from
        // STEP_METRICS, so new metrics appear automatically).
        let err = step_chart(&[&a], "bogus", 30, 8).unwrap_err().to_string();
        for (name, _) in STEP_METRICS {
            assert!(err.contains(name), "metric '{name}' missing from error: {err}");
        }
        assert!(err.contains("bogus"), "{err}");
    }

    #[test]
    fn json_roundtrip_preserves_pool_fields_and_replica_arrays() {
        // PR-6 pool telemetry through a full serialize → parse cycle: the
        // per-step pool_balance / p95 deltas and the run-level per-replica
        // arrays must survive `speed-rl report`'s record parser.
        let mut a = rec("pool", &[(0.0, 0.2)]);
        a.steps.push(StepRecord {
            step: 0,
            time_s: 1.0,
            inference_s: 0.7,
            update_s: 0.3,
            train_pass_rate: 0.5,
            grad_norm: 0.1,
            loss: -0.5,
            clip_frac: 0.0,
            prompts_consumed: 10,
            buffer_len: 2,
            mean_staleness: 0.5,
            prompts_skipped: 0,
            rollouts_saved: 0,
            predictor_brier: 0.0,
            step_skip_rate: 0.0,
            step_explore_rate: 0.0,
            service_calls: 6,
            service_fill: 0.9,
            service_queue_wait_s: 0.004,
            pool_balance: 0.75,
            service_queue_wait_p95_s: 0.001,
            service_exec_p95_s: 1.0,
            rollouts: 128,
            step_alloc_rows: 64,
            alloc_calibration: 0.0,
            service_faults: 0,
            service_retries: 0,
            slot_occupancy: 0.45,
        });
        let mut svc = ServiceCounters { calls: 6, submissions: 12, ..Default::default() };
        svc.engines = 2;
        svc.steals = 3;
        svc.pool_dispatches = 6;
        svc.pool_busy_sum = 9;
        svc.replica_calls[0] = 4;
        svc.replica_calls[1] = 2;
        svc.replica_rows[0] = 200;
        svc.replica_rows[1] = 100;
        svc.queue_wait_hist[2] = 5;
        svc.exec_hist[3] = 6;
        svc.slot_admissions = 6;
        svc.slot_retires = 6;
        svc.slot_occupancy_sum = 180;
        svc.slot_capacity_sum = 384;
        svc.slot_occupancy_hist[3] = 6;
        a.service = Some(svc);
        let back = record_from_json(&a.to_json()).unwrap();
        let s = &back.steps[0];
        assert!((s.pool_balance - 0.75).abs() < 1e-12);
        assert!((s.service_queue_wait_p95_s - 0.001).abs() < 1e-12);
        assert!((s.service_exec_p95_s - 1.0).abs() < 1e-12);
        let svc = back.service.expect("service parsed");
        assert_eq!(svc.engines, 2);
        assert_eq!(svc.steals, 3);
        assert_eq!(svc.pool_dispatches, 6);
        assert_eq!(svc.pool_busy_sum, 9);
        assert_eq!(&svc.replica_calls[..2], &[4, 2]);
        assert_eq!(&svc.replica_rows[..2], &[200, 100]);
        assert_eq!(svc.queue_wait_hist[2], 5);
        assert_eq!(svc.exec_hist[3], 6);
        assert!((s.slot_occupancy - 0.45).abs() < 1e-12);
        assert_eq!(svc.slot_admissions, 6);
        assert_eq!(svc.slot_retires, 6);
        assert_eq!(svc.slot_occupancy_hist[3], 6);
        // pool_balance is derived from the dispatch counters, not stored
        assert!((svc.pool_balance() - 9.0 / 12.0).abs() < 1e-12);
        // mean_slot_occupancy is likewise recomputed from the raw sums
        assert!((svc.mean_slot_occupancy() - 180.0 / 384.0).abs() < 1e-12);
    }

    #[test]
    fn parses_pre_slot_records_with_zeroed_occupancy() {
        // A fixture in the PR-8-era serviced format: steps and the service
        // block predate the slot-occupancy telemetry entirely. The parser
        // must fill zeros (deadline-mode semantics), not error — slots-era
        // `speed-rl report --metric slot-occupancy` runs over old logs too.
        let fixture = r#"{
            "label": "pre-slots",
            "steps": [
                {"step": 0, "time_s": 80.5, "inference_s": 55.0, "update_s": 25.5,
                 "train_pass_rate": 0.5, "grad_norm": 0.4, "loss": -0.5, "clip_frac": 0.0,
                 "prompts_consumed": 32, "service_calls": 4, "service_fill": 0.8,
                 "pool_balance": 0.4, "service_faults": 0, "service_retries": 0}
            ],
            "evals": [
                {"step": 0, "time_s": 0, "benchmark": "dapo1k", "accuracy": 0.37}
            ],
            "service": {
                "calls": 4, "submissions": 9, "rows_used": 300, "rows_capacity": 400,
                "installs": 2, "deadline_dispatches": 1,
                "coalesced_hist": [1, 0, 1, 2, 0, 0], "engines": 2, "steals": 1,
                "pool_dispatches": 6, "pool_busy_sum": 3
            }
        }"#;
        let rec = record_from_json(&Json::parse(fixture).unwrap()).unwrap();
        let s = &rec.steps[0];
        // present PR-8 fields survive
        assert_eq!(s.service_calls, 4);
        assert!((s.service_fill - 0.8).abs() < 1e-12);
        // the absent slot delta defaults to zero and still charts
        assert_eq!(s.slot_occupancy, 0.0);
        let chart = step_chart(&[&rec], "slot-occupancy", 30, 8).unwrap();
        assert!(chart.contains("slot-occupancy") && chart.contains("pre-slots"));
        let svc = rec.service.expect("service parsed");
        assert_eq!(svc.calls, 4);
        assert_eq!(svc.steals, 1);
        // absent slot counters parse as zeros: deadline-era records read
        // as "nothing admitted", never as garbage or a parse failure
        assert_eq!(svc.slot_admissions, 0);
        assert_eq!(svc.slot_retires, 0);
        assert_eq!(svc.slot_occupancy_hist, [0u64; 8]);
        assert_eq!(svc.slots_mode, 0, "pre-slot records are deadline-mode");
        assert_eq!(svc.mean_slot_occupancy(), 0.0);
    }
}
