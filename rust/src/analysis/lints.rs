//! The five invariant lints (L1–L5) of `speed-rl lint` (DESIGN.md §15).
//!
//! Every pass is a pure function over source text so the fixture tests can
//! inject synthetic violations without touching the filesystem; the IO and
//! file walking live in [`super::run_lints`].
//!
//! * **L1 lock discipline** — raw `.lock()` / `.wait(guard)` /
//!   `.wait_timeout(` on `std::sync` primitives anywhere outside
//!   `util/sync.rs` is an error (the poison-recovering `plock`/`pwait`
//!   wrappers are the only sanctioned entry points), and nested
//!   acquisitions in the files with a declared lock order must respect it.
//! * **L2 counter-schema completeness** — every field of `ServiceCounters`
//!   and `InferenceCounters` must appear in its `merge`, `to_json`, and
//!   `from_json` bodies, and the declared wall-clock fields must be
//!   normalized by the chaos smoke in `rust/ci.sh`.
//! * **L3 harness registration** — every `rust/tests/*.rs` and
//!   `benches/*.rs` file needs a matching `path = "..."` entry in
//!   `Cargo.toml` (non-autodiscovered layout: an unregistered harness
//!   silently never runs).
//! * **L4 wall-clock hygiene** — `Instant::now` / `SystemTime` confined to
//!   the allowlisted telemetry modules; everywhere else wall time leaks
//!   nondeterminism into records the equivalence rails compare
//!   byte-for-byte.
//! * **L5 metric-table completeness** — every numeric `StepRecord` field
//!   must be reachable from `STEP_METRICS` or listed (with a reason) in
//!   `STEP_METRICS_EXEMPT`.

use super::scanner::CleanSource;
use super::Violation;

/// The one file allowed to touch raw `std::sync` lock primitives.
pub const SYNC_WRAPPER: &str = "src/util/sync.rs";

/// L4: modules allowed to read wall clocks. Everything here is telemetry
/// (trace spans, latency histograms, bench timings) or the real-engine
/// cost accounting — none of it feeds the deterministic record fields the
/// resume/trace/chaos rails diff byte-for-byte.
pub const WALL_CLOCK_ALLOWLIST: &[&str] = &[
    "src/bench/mod.rs",
    "src/coordinator/pipeline.rs",
    "src/main.rs",
    "src/policy/fault.rs",
    "src/policy/real.rs",
    "src/policy/service.rs",
    "src/runtime/exec.rs",
    "src/trace/mod.rs",
    "src/util/logging.rs",
];

/// A declared intra-file lock acquisition order: classes may only be
/// acquired in increasing declared position while another is held, and a
/// class may never nest inside itself.
pub struct LockOrderSpec {
    pub file_suffix: &'static str,
    /// `(class name, substring pattern over the plock argument)`, in
    /// declared acquisition order.
    pub classes: &'static [(&'static str, &'static str)],
    /// Class assumed for acquisitions matching no pattern. `None` makes an
    /// unclassifiable acquisition an error (multi-lock files must keep the
    /// patterns current).
    pub default_class: Option<&'static str>,
}

/// The repo's declared lock orders. The only sanctioned nesting anywhere
/// is the replica steal path in `policy/service.rs`, which takes
/// `shared.stats` while holding `pool.state` — hence `state` before
/// `stats`. `buffer.rs` and `predictor/store.rs` each own a single lock
/// class, so any nesting there is a self-deadlock.
pub const LOCK_ORDERS: &[LockOrderSpec] = &[
    LockOrderSpec {
        file_suffix: "src/policy/service.rs",
        classes: &[
            ("queue", ".queue"),
            ("spares", ".spares"),
            ("state", ".state"),
            ("respawned", ".respawned"),
            ("stats", ".stats"),
        ],
        default_class: None,
    },
    LockOrderSpec {
        file_suffix: "src/coordinator/buffer.rs",
        classes: &[("buffer_state", ".state")],
        default_class: Some("buffer_state"),
    },
    LockOrderSpec {
        file_suffix: "src/predictor/store.rs",
        classes: &[("shard", "shard")],
        default_class: Some("shard"),
    },
];

// ---------------------------------------------------------------------------
// L1a: raw std::sync primitives outside the wrapper module.

pub fn lint_raw_locks(file: &str, cs: &CleanSource) -> Vec<Violation> {
    let mut out = Vec::new();
    if file.ends_with(SYNC_WRAPPER) {
        return out;
    }
    for (ln, line) in cs.shipping_lines() {
        if line.contains(".lock()") {
            out.push(Violation::new(
                "L1",
                file,
                ln,
                "raw Mutex::lock() outside util/sync.rs — use util::sync::plock \
                 (poison-recovering)",
            ));
        }
        if line.contains(".wait_timeout(") {
            out.push(Violation::new(
                "L1",
                file,
                ln,
                "raw Condvar::wait_timeout() outside util/sync.rs — use \
                 util::sync::pwait_timeout",
            ));
        }
        if wait_with_guard_arg(line) {
            out.push(Violation::new(
                "L1",
                file,
                ln,
                "raw Condvar::wait(guard) outside util/sync.rs — use util::sync::pwait",
            ));
        }
    }
    out
}

/// `.wait(` with a non-empty argument is a Condvar wait consuming a
/// `MutexGuard`; argument-less `.wait()` (`Ticket::wait`, `JoinHandle`
/// adjacents) is fine.
fn wait_with_guard_arg(line: &str) -> bool {
    let mut rest = line;
    while let Some(p) = rest.find(".wait(") {
        let after = &rest[p + ".wait(".len()..];
        if !after.trim_start().starts_with(')') {
            return true;
        }
        rest = after;
    }
    false
}

// ---------------------------------------------------------------------------
// L1b: nested acquisitions against a declared lock order.

/// Track `let`-bound `plock` guards through a file and flag any
/// acquisition that violates `spec`'s declared order. The tracker is
/// textual: a guard is live from its whole-statement binding
/// (`let [mut] name = plock(&...);` or `name = plock(&...);`) until
/// `drop(name)` or its binding block closes; statement-temporary
/// `plock(...)` chains count as instantaneous acquisition events.
/// Cross-function nesting is invisible here by design — the exhaustive
/// interleaving models in `tests/loom_sync.rs` cover the protocols
/// themselves.
pub fn lint_lock_order(file: &str, cs: &CleanSource, spec: &LockOrderSpec) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut depth: i32 = 0;
    // (binding name, class index, depth at binding)
    let mut guards: Vec<(String, usize, i32)> = Vec::new();
    for (li, line) in cs.lines.iter().enumerate() {
        let ln = li + 1;
        if !cs.in_test[li] {
            for name in call_args(line, "drop(") {
                guards.retain(|g| g.0 != name);
            }
            for arg in call_args(line, "plock(") {
                match classify(&arg, spec) {
                    Some(ci) => {
                        for (held_name, held_ci, _) in &guards {
                            if *held_ci >= ci {
                                let (new_class, _) = spec.classes[ci];
                                let (held_class, _) = spec.classes[*held_ci];
                                let msg = if *held_ci == ci {
                                    format!(
                                        "lock order violation: acquiring '{new_class}' while \
                                         already holding '{held_class}' (guard `{held_name}`) — \
                                         same-class nesting self-deadlocks"
                                    )
                                } else {
                                    format!(
                                        "lock order violation: acquiring '{new_class}' while \
                                         holding '{held_class}' (guard `{held_name}`); declared \
                                         order: {}",
                                        order_string(spec)
                                    )
                                };
                                out.push(Violation::new("L1", file, ln, &msg));
                            }
                        }
                    }
                    None => out.push(Violation::new(
                        "L1",
                        file,
                        ln,
                        &format!(
                            "lock acquisition `plock({arg})` matches no class of the declared \
                             lock order for this file — extend LOCK_ORDERS in analysis/lints.rs"
                        ),
                    )),
                }
            }
            if let Some(name) = guard_binding(line) {
                if let Some(arg) = call_args(line, "plock(").into_iter().next() {
                    if let Some(ci) = classify(&arg, spec) {
                        guards.retain(|g| g.0 != name);
                        guards.push((name, ci, depth));
                    }
                }
            }
        }
        for ch in line.chars() {
            match ch {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        guards.retain(|g| g.2 <= depth);
    }
    out
}

fn order_string(spec: &LockOrderSpec) -> String {
    spec.classes.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(" < ")
}

fn classify(arg: &str, spec: &LockOrderSpec) -> Option<usize> {
    for (i, (_, pat)) in spec.classes.iter().enumerate() {
        if arg.contains(pat) {
            return Some(i);
        }
    }
    spec.default_class
        .and_then(|d| spec.classes.iter().position(|(name, _)| *name == d))
}

/// All arguments of `needle`-calls on `line` (the text between the call's
/// opening paren and its matching close, or end of line for multi-line
/// calls). The char before the call must not be part of an identifier.
fn call_args(line: &str, needle: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(needle) {
        let abs = from + p;
        let prev_ok = line[..abs]
            .chars()
            .next_back()
            .map(|c| !c.is_alphanumeric() && c != '_')
            .unwrap_or(true);
        if prev_ok {
            let body = &line[abs + needle.len()..];
            out.push(paren_arg(body).to_string());
        }
        from = abs + needle.len();
    }
    out
}

/// The prefix of `body` up to the paren that closes an already-open call.
fn paren_arg(body: &str) -> &str {
    let mut depth = 1i32;
    for (i, c) in body.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return &body[..i];
                }
            }
            _ => {}
        }
    }
    body
}

/// `Some(name)` when the line is a whole-statement guard binding:
/// `let [mut] name = plock(&...);` or `name = plock(&...);`.
fn guard_binding(line: &str) -> Option<String> {
    let t = line.trim();
    let rest = t.strip_prefix("let ").unwrap_or(t);
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let eq = rest.find('=')?;
    let name = rest[..eq].trim();
    if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    let rhs = rest[eq + 1..].trim_start();
    let body = rhs.strip_prefix("plock(")?;
    let arg = paren_arg(body);
    let tail = body[arg.len()..].strip_prefix(')')?;
    if tail.trim() == ";" {
        Some(name.to_string())
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// L2: counter-schema completeness.

pub fn lint_counter_schema(
    metrics_file: &str,
    metrics_src: &str,
    ci_file: &str,
    ci_src: &str,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let cs = super::scanner::clean(metrics_src);
    let mut service_fields: Vec<String> = Vec::new();
    for struct_name in ["ServiceCounters", "InferenceCounters"] {
        let Some((fields, _)) = struct_fields(&cs, struct_name) else {
            out.push(Violation::new(
                "L2",
                metrics_file,
                0,
                &format!("struct {struct_name} not found — the schema lint cannot run"),
            ));
            continue;
        };
        if struct_name == "ServiceCounters" {
            service_fields = fields.iter().map(|(f, _)| f.clone()).collect();
        }
        for method in ["merge", "to_json", "from_json"] {
            let Some((body, decl_ln)) = impl_method_body(&cs, metrics_src, struct_name, method)
            else {
                out.push(Violation::new(
                    "L2",
                    metrics_file,
                    0,
                    &format!("{struct_name} has no `fn {method}` — counters must round-trip"),
                ));
                continue;
            };
            for (field, _) in &fields {
                if !contains_word(&body, field) {
                    out.push(Violation::new(
                        "L2",
                        metrics_file,
                        decl_ln,
                        &format!(
                            "field `{field}` missing from {struct_name}::{method} — every \
                             counter must merge and round-trip through JSON"
                        ),
                    ));
                }
            }
        }
    }
    // Wall-clock declaration vs the chaos-smoke normalization set.
    let declared = const_list_strings(metrics_src, "WALL_CLOCK_SERVICE_FIELDS:");
    let ci_wall = const_list_strings(ci_src, "WALL");
    if declared.is_empty() {
        out.push(Violation::new(
            "L2",
            metrics_file,
            0,
            "WALL_CLOCK_SERVICE_FIELDS declaration not found or empty",
        ));
    }
    if ci_wall.is_empty() {
        out.push(Violation::new("L2", ci_file, 0, "chaos-smoke WALL normalization set not found"));
    }
    for f in &declared {
        if !service_fields.iter().any(|s| s == f) {
            out.push(Violation::new(
                "L2",
                metrics_file,
                0,
                &format!("WALL_CLOCK_SERVICE_FIELDS declares `{f}`, which is not a \
                          ServiceCounters field"),
            ));
        }
        if !ci_wall.iter().any(|s| s == f) {
            out.push(Violation::new(
                "L2",
                ci_file,
                0,
                &format!(
                    "wall-clock field `{f}` is not in the chaos-smoke WALL normalization set — \
                     the --fault-plan none equivalence diff would flake on it"
                ),
            ));
        }
    }
    for f in &ci_wall {
        if service_fields.iter().any(|s| s == f) && !declared.iter().any(|s| s == f) {
            out.push(Violation::new(
                "L2",
                metrics_file,
                0,
                &format!(
                    "ci.sh normalizes ServiceCounters field `{f}` as wall-clock, but \
                     WALL_CLOCK_SERVICE_FIELDS does not declare it"
                ),
            ));
        }
    }
    out
}

/// Field `(name, type)` pairs of `pub struct name { ... }` plus the
/// 1-based line of the struct header.
fn struct_fields(cs: &CleanSource, name: &str) -> Option<(Vec<(String, String)>, usize)> {
    let header = format!("pub struct {name} {{");
    let start = cs.lines.iter().position(|l| !l.trim().is_empty() && l.trim() == header.trim())?;
    let end = block_end(&cs.lines, start)?;
    let mut fields = Vec::new();
    for line in &cs.lines[start + 1..end] {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("pub ") {
            if let Some(colon) = rest.find(':') {
                let fname = rest[..colon].trim();
                if fname.chars().all(|c| c.is_alphanumeric() || c == '_') && !fname.is_empty() {
                    let ty = rest[colon + 1..].trim().trim_end_matches(',').to_string();
                    fields.push((fname.to_string(), ty));
                }
            }
        }
    }
    Some((fields, start + 1))
}

/// Raw text of `fn method` inside `impl name { ... }`, plus the 1-based
/// line of the method header.
fn impl_method_body(
    cs: &CleanSource,
    raw: &str,
    name: &str,
    method: &str,
) -> Option<(String, usize)> {
    let header = format!("impl {name} {{");
    let impl_start = cs.lines.iter().position(|l| l.trim() == header.trim())?;
    let impl_end = block_end(&cs.lines, impl_start)?;
    let needle = format!("fn {method}(");
    let decl = (impl_start..impl_end).find(|&i| cs.lines[i].contains(&needle))?;
    let body_end = block_end(&cs.lines, decl)?;
    let raw_lines: Vec<&str> = raw.lines().collect();
    let body = raw_lines[decl..=body_end.min(raw_lines.len() - 1)].join("\n");
    Some((body, decl + 1))
}

/// Index of the line whose `}` closes the block opened on `start`'s line.
fn block_end(lines: &[String], start: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut opened = false;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some(i);
        }
    }
    None
}

/// Does `hay` contain `word` delimited by non-identifier characters?
fn contains_word(hay: &str, word: &str) -> bool {
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(word) {
        let abs = from + p;
        let before_ok = hay[..abs]
            .chars()
            .next_back()
            .map(|c| !c.is_alphanumeric() && c != '_')
            .unwrap_or(true);
        let after_ok = hay[abs + word.len()..]
            .chars()
            .next()
            .map(|c| !c.is_alphanumeric() && c != '_')
            .unwrap_or(true);
        if before_ok && after_ok {
            return true;
        }
        from = abs + word.len();
    }
    false
}

/// Every `"quoted"` string in the list literal assigned at the first
/// `anchor ... = [...]` / `= {...}` after `anchor` (line comments inside
/// the list are skipped; the list ends at the first `]` or `}` outside a
/// string). Works on both the Rust const declarations and the python
/// `WALL = {...}` set embedded in `rust/ci.sh`.
fn const_list_strings(src: &str, anchor: &str) -> Vec<String> {
    let Some(start) = src.find(anchor) else {
        return Vec::new();
    };
    let after = &src[start + anchor.len()..];
    let Some(eq) = after.find('=') else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut chars = after[eq + 1..].chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                if in_str {
                    out.push(std::mem::take(&mut cur));
                }
                in_str = !in_str;
            }
            '/' if !in_str && chars.peek() == Some(&'/') => {
                for c2 in chars.by_ref() {
                    if c2 == '\n' {
                        break;
                    }
                }
            }
            ']' | '}' if !in_str => break,
            _ => {
                if in_str {
                    cur.push(c);
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// L3: harness registration.

pub fn lint_harness_registration(
    cargo_file: &str,
    cargo_src: &str,
    test_files: &[String],
    bench_files: &[String],
) -> Vec<Violation> {
    let mut out = Vec::new();
    let registered: Vec<String> = cargo_src
        .lines()
        .filter_map(|l| {
            let t = l.trim();
            t.strip_prefix("path = \"").and_then(|r| r.strip_suffix('"')).map(|s| s.to_string())
        })
        .collect();
    for (files, kind) in [(test_files, "[[test]]"), (bench_files, "[[bench]]")] {
        for f in files {
            if !registered.iter().any(|r| r == f) {
                out.push(Violation::new(
                    "L3",
                    cargo_file,
                    0,
                    &format!(
                        "{f} has no {kind} entry in Cargo.toml — with autodiscovery off it \
                         silently never runs"
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// L4: wall-clock hygiene.

pub fn lint_wall_clock(file: &str, cs: &CleanSource) -> Vec<Violation> {
    let mut out = Vec::new();
    if WALL_CLOCK_ALLOWLIST.iter().any(|a| file.ends_with(a)) {
        return out;
    }
    for (ln, line) in cs.shipping_lines() {
        for tok in ["Instant::now", "SystemTime"] {
            if line.contains(tok) {
                out.push(Violation::new(
                    "L4",
                    file,
                    ln,
                    &format!(
                        "{tok} outside the wall-clock allowlist — wall time leaks \
                         nondeterminism into records the equivalence rails diff; route it \
                         through telemetry or extend WALL_CLOCK_ALLOWLIST with a reason"
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// L5: metric-table completeness.

const NUMERIC_TYPES: &[&str] = &["usize", "u64", "u32", "i64", "f64", "f32"];

pub fn lint_step_metrics(
    metrics_file: &str,
    metrics_src: &str,
    report_file: &str,
    report_src: &str,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let cs_m = super::scanner::clean(metrics_src);
    let Some((fields, _)) = struct_fields(&cs_m, "StepRecord") else {
        out.push(Violation::new("L5", metrics_file, 0, "struct StepRecord not found"));
        return out;
    };
    let numeric: Vec<&String> =
        fields.iter().filter(|(_, ty)| NUMERIC_TYPES.contains(&ty.as_str())).map(|(f, _)| f).collect();
    let Some(table) = const_span(report_src, "STEP_METRICS:") else {
        out.push(Violation::new("L5", report_file, 0, "STEP_METRICS table not found"));
        return out;
    };
    let accessors = step_accessors(&table);
    let exempt = const_list_strings(report_src, "STEP_METRICS_EXEMPT:");
    for e in &exempt {
        if !fields.iter().any(|(f, _)| f == e) {
            out.push(Violation::new(
                "L5",
                report_file,
                0,
                &format!("STEP_METRICS_EXEMPT names `{e}`, which is not a StepRecord field"),
            ));
        }
    }
    for f in numeric {
        if !accessors.iter().any(|a| a == f) && !exempt.iter().any(|e| e == f) {
            out.push(Violation::new(
                "L5",
                report_file,
                0,
                &format!(
                    "numeric StepRecord field `{f}` is unreachable from STEP_METRICS and not \
                     exempted in STEP_METRICS_EXEMPT — charts silently miss it"
                ),
            ));
        }
    }
    out
}

/// Raw text of the bracket-balanced `[...]` literal assigned at the first
/// `anchor ... = ... [` (skipping past the `=` keeps the `[...]` of a type
/// annotation like `&[StepMetric]` from being mistaken for the table).
fn const_span(src: &str, anchor: &str) -> Option<String> {
    let start = src.find(anchor)?;
    let after = &src[start + anchor.len()..];
    let eq = after.find('=')?;
    let body = &after[eq + 1..];
    let open = body.find('[')?;
    let mut depth = 0i32;
    for (i, c) in body[open..].char_indices() {
        match c {
            '[' => depth += 1,
            ']' => {
                depth -= 1;
                if depth == 0 {
                    return Some(body[open..open + i + 1].to_string());
                }
            }
            _ => {}
        }
    }
    None
}

/// Every `s.field` accessor in the (cleaned) table text.
fn step_accessors(table: &str) -> Vec<String> {
    let cs = super::scanner::clean(table);
    let mut out = Vec::new();
    for line in &cs.lines {
        let mut from = 0usize;
        while let Some(p) = line[from..].find("s.") {
            let abs = from + p;
            let before_ok = line[..abs]
                .chars()
                .next_back()
                .map(|c| !c.is_alphanumeric() && c != '_')
                .unwrap_or(true);
            if before_ok {
                let ident: String = line[abs + 2..]
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !ident.is_empty() && ident.chars().next().is_some_and(|c| c.is_alphabetic()) {
                    out.push(ident);
                }
            }
            from = abs + 2;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::scanner::clean;
    use super::*;

    #[test]
    fn l1_flags_raw_lock_wait_and_wait_timeout() {
        let src = "fn f(m: &Mutex<u32>, cv: &Condvar) {\n\
                   \x20   let g = m.lock().unwrap();\n\
                   \x20   let g = cv.wait(g).unwrap();\n\
                   \x20   let _ = cv.wait_timeout(g, d);\n\
                   \x20   ticket.wait();\n\
                   }\n";
        let v = lint_raw_locks("rust/src/policy/other.rs", &clean(src));
        assert_eq!(v.len(), 3, "{v:?}");
        assert_eq!(v[0].line, 2);
        assert!(v[0].message.contains("plock"));
        assert_eq!(v[1].line, 3);
        assert!(v[1].message.contains("pwait"));
        assert_eq!(v[2].line, 4);
        assert!(v[2].message.contains("pwait_timeout"));
    }

    #[test]
    fn l1_ignores_sync_wrapper_tests_and_comments() {
        let src = "// m.lock() in a comment\n\
                   let s = \".lock()\";\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   fn t() { let g = m.lock().unwrap(); }\n\
                   }\n";
        assert!(lint_raw_locks("rust/src/x.rs", &clean(src)).is_empty());
        let raw = "fn plock() { m.lock().unwrap(); }\n";
        assert!(lint_raw_locks("rust/src/util/sync.rs", &clean(raw)).is_empty());
    }

    #[test]
    fn l1_lock_order_catches_inverted_nesting() {
        let spec = &LOCK_ORDERS[0]; // policy/service.rs
        let src = "fn f(pool: &Pool, shared: &Shared) {\n\
                   \x20   let mut stats = plock(&shared.stats);\n\
                   \x20   let mut ps = plock(&pool.state);\n\
                   }\n";
        let v = lint_lock_order("rust/src/policy/service.rs", &clean(src), spec);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
        assert!(v[0].message.contains("'state'"), "{}", v[0].message);
        assert!(v[0].message.contains("'stats'"), "{}", v[0].message);
    }

    #[test]
    fn l1_lock_order_allows_declared_nesting_and_scoped_guards() {
        let spec = &LOCK_ORDERS[0];
        // The sanctioned steal-path shape: stats while holding state...
        let ok = "fn f(pool: &Pool, shared: &Shared) {\n\
                  \x20   let mut ps = plock(&pool.state);\n\
                  \x20   {\n\
                  \x20       let mut stats = plock(&shared.stats);\n\
                  \x20   }\n\
                  }\n";
        assert!(lint_lock_order("x/policy/service.rs", &clean(ok), spec).is_empty());
        // ...and sequential acquisition after drop() or scope exit.
        let seq = "fn f(pool: &Pool, shared: &Shared) {\n\
                   \x20   let mut stats = plock(&shared.stats);\n\
                   \x20   drop(stats);\n\
                   \x20   let mut ps = plock(&pool.state);\n\
                   }\n";
        assert!(lint_lock_order("x/policy/service.rs", &clean(seq), spec).is_empty());
    }

    #[test]
    fn l1_lock_order_catches_same_class_self_deadlock() {
        let spec = &LOCK_ORDERS[1]; // coordinator/buffer.rs, single class
        let src = "fn f(&self) {\n\
                   \x20   let mut g = plock(&self.state);\n\
                   \x20   let n = plock(&self.state).q.len();\n\
                   }\n";
        let v = lint_lock_order("x/coordinator/buffer.rs", &clean(src), spec);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
        assert!(v[0].message.contains("self-deadlock"), "{}", v[0].message);
    }

    const METRICS_FIXTURE_OK: &str = "pub const WALL_CLOCK_SERVICE_FIELDS: &[&str] = \
                                      &[\"wait_s\"];\n\
        pub struct ServiceCounters {\n    pub calls: u64,\n    pub wait_s: f64,\n}\n\
        impl ServiceCounters {\n\
        \x20   pub fn merge(&mut self, o: &ServiceCounters) {\n\
        \x20       self.calls += o.calls;\n        self.wait_s += o.wait_s;\n    }\n\
        \x20   pub fn to_json(&self) -> Json {\n\
        \x20       Json::obj(vec![(\"calls\", x), (\"wait_s\", y)])\n    }\n\
        \x20   pub fn from_json(j: &Json) -> ServiceCounters {\n\
        \x20       ServiceCounters { calls: g(\"calls\"), wait_s: g(\"wait_s\") }\n    }\n\
        }\n\
        pub struct InferenceCounters {\n    pub rollouts: u64,\n}\n\
        impl InferenceCounters {\n\
        \x20   pub fn merge(&mut self, o: &InferenceCounters) { self.rollouts += o.rollouts; }\n\
        \x20   pub fn to_json(&self) -> Json { Json::obj(vec![(\"rollouts\", x)]) }\n\
        \x20   pub fn from_json(j: &Json) -> InferenceCounters {\n\
        \x20       InferenceCounters { rollouts: g(\"rollouts\") }\n    }\n\
        }\n";

    #[test]
    fn l2_passes_on_complete_schema_and_flags_dropped_field() {
        let ci = "WALL = {\"wait_s\"}\n";
        assert!(lint_counter_schema("m.rs", METRICS_FIXTURE_OK, "ci.sh", ci).is_empty());
        // Drop `wait_s` from merge: exactly one violation, pointing at merge.
        let broken = METRICS_FIXTURE_OK.replace("self.wait_s += o.wait_s;", "");
        let v = lint_counter_schema("m.rs", &broken, "ci.sh", ci);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("`wait_s`"), "{}", v[0].message);
        assert!(v[0].message.contains("merge"), "{}", v[0].message);
        // Drop it from the ci WALL set: the declaration check fires instead.
        let v = lint_counter_schema("m.rs", METRICS_FIXTURE_OK, "ci.sh", "WALL = {\"other\"}\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("WALL normalization"), "{}", v[0].message);
    }

    #[test]
    fn l3_flags_unregistered_harness_files() {
        let cargo = "[[test]]\nname = \"a\"\npath = \"rust/tests/a.rs\"\n\
                     [[bench]]\nname = \"b\"\npath = \"benches/b.rs\"\n";
        let tests = vec!["rust/tests/a.rs".to_string(), "rust/tests/ghost.rs".to_string()];
        let benches = vec!["benches/b.rs".to_string()];
        let v = lint_harness_registration("Cargo.toml", cargo, &tests, &benches);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("rust/tests/ghost.rs"), "{}", v[0].message);
        assert!(v[0].message.contains("[[test]]"), "{}", v[0].message);
    }

    #[test]
    fn l4_flags_wall_clock_outside_allowlist() {
        let src = "fn f() {\n    let t0 = std::time::Instant::now();\n}\n";
        let v = lint_wall_clock("rust/src/coordinator/trainer.rs", &clean(src));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 2);
        assert!(v[0].message.contains("Instant::now"), "{}", v[0].message);
        assert!(lint_wall_clock("rust/src/trace/mod.rs", &clean(src)).is_empty());
    }

    #[test]
    fn l5_flags_unreachable_numeric_field() {
        let metrics = "pub struct StepRecord {\n    pub loss: f64,\n    pub step: u64,\n\
                       \x20   pub label: String,\n}\n";
        let report = "pub const STEP_METRICS: &[StepMetric] = &[\n\
                      \x20   StepMetric { name: \"loss\", get: |s| s.loss },\n];\n\
                      pub const STEP_METRICS_EXEMPT: &[&str] = &[];\n";
        let v = lint_step_metrics("m.rs", metrics, "r.rs", report);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("`step`"), "{}", v[0].message);
        // Exempting it silences the lint; a typo'd exemption is itself caught.
        let exempted = report.replace("&[];", "&[\"step\"];");
        assert!(lint_step_metrics("m.rs", metrics, "r.rs", &exempted).is_empty());
        let typo = report.replace("&[];", "&[\"stpe\"];");
        let v = lint_step_metrics("m.rs", metrics, "r.rs", &typo);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].message.contains("`stpe`"), "{}", v[0].message);
    }
}
