//! Exhaustive interleaving explorer for small concurrency models.
//!
//! The dynamic half of the sync-protocol contract (DESIGN.md §15): the L1
//! lint proves lock *discipline* statically, this module proves the
//! *protocols* built on those locks — the `SharedBuffer`
//! push/pop/backpressure dance and the engine-pool's exactly-once
//! seized-slot claim — hold under **every** schedule, not just the ones a
//! stress test happens to hit.
//!
//! A model is a handful of threads, each a straight-line sequence of
//! atomic [`Action`]s over a shared state `S`. An action is *enabled*
//! when its guard passes — a disabled action models a thread blocked on a
//! condvar, and becomes runnable again when another thread changes the
//! state. [`explore`] runs a depth-first search over every interleaving:
//! the invariant is checked after every action, a reachable state where
//! unfinished threads exist but nothing is enabled is reported as a
//! deadlock, and the terminal assertion runs at every leaf. Failures
//! carry the exact schedule (the action trail) that produced them.
//!
//! This mirrors what `loom` does for real `std::sync` types, minus the
//! memory-model modeling — the dependency cannot be vendored offline, so
//! the protocols are lifted into guarded-action models instead, and the
//! type aliases in `util::sync` remain the swap point for running the
//! real structures under loom where it is available (see `rust/ci.sh`,
//! `SPEED_RL_LOOM=1`).

/// One atomic step of a modeled thread. `tag` is the thread's identity
/// parameter (e.g. which producer), passed to both callbacks so one
/// action table can serve several symmetric threads.
pub struct Action<S> {
    pub name: &'static str,
    pub tag: usize,
    /// May this action run in state `S`? A `false` models blocking (a
    /// condvar wait whose predicate fails, a full buffer, ...).
    pub enabled: fn(&S, usize) -> bool,
    pub apply: fn(&mut S, usize),
}

impl<S> Action<S> {
    pub fn new(
        name: &'static str,
        tag: usize,
        enabled: fn(&S, usize) -> bool,
        apply: fn(&mut S, usize),
    ) -> Action<S> {
        Action { name, tag, enabled, apply }
    }

    /// An action that is always runnable.
    pub fn always(name: &'static str, tag: usize, apply: fn(&mut S, usize)) -> Action<S> {
        Action { name, tag, enabled: |_, _| true, apply }
    }
}

/// A modeled thread: a name (for schedule diagnostics) and its program —
/// actions executed in order, one program counter per thread.
pub struct ModelThread<S> {
    pub name: &'static str,
    pub actions: Vec<Action<S>>,
}

/// A complete model: threads, a safety invariant checked after every
/// action, a terminal assertion checked when all threads finished, and a
/// visited-state budget guarding against accidental explosion.
pub struct Model<'a, S> {
    pub threads: &'a [ModelThread<S>],
    /// Checked after every action at every node. `Err` aborts the search
    /// and reports the schedule that reached the bad state.
    pub invariant: fn(&S) -> Result<(), String>,
    /// Checked at every leaf (all program counters at the end).
    pub terminal: fn(&S) -> Result<(), String>,
    /// Abort if the search visits more than this many states.
    pub max_states: u64,
}

/// Search statistics: `schedules` is the number of complete
/// interleavings verified, `states` the number of visited nodes.
#[derive(Debug, Clone, Copy)]
pub struct Exploration {
    pub schedules: u64,
    pub states: u64,
}

/// Exhaustively explore every interleaving of `model` from `init`.
///
/// Returns the search statistics on success. Any invariant violation,
/// deadlock, terminal failure, or budget exhaustion returns `Err` with
/// the offending schedule spelled out as `thread.action` steps.
pub fn explore<S: Clone>(model: &Model<S>, init: S) -> Result<Exploration, String> {
    (model.invariant)(&init).map_err(|e| format!("invariant failed in initial state: {e}"))?;
    let mut pcs = vec![0usize; model.threads.len()];
    let mut trail: Vec<String> = Vec::new();
    let mut stats = Exploration { schedules: 0, states: 0 };
    dfs(model, &init, &mut pcs, &mut trail, &mut stats)?;
    Ok(stats)
}

fn dfs<S: Clone>(
    model: &Model<S>,
    state: &S,
    pcs: &mut [usize],
    trail: &mut Vec<String>,
    stats: &mut Exploration,
) -> Result<(), String> {
    stats.states += 1;
    if stats.states > model.max_states {
        return Err(format!(
            "state budget exceeded ({} states) — model too large or non-terminating",
            model.max_states
        ));
    }
    let mut ran_any = false;
    let mut unfinished = false;
    for (ti, thread) in model.threads.iter().enumerate() {
        let pc = pcs[ti];
        if pc >= thread.actions.len() {
            continue;
        }
        unfinished = true;
        let action = &thread.actions[pc];
        if !(action.enabled)(state, action.tag) {
            continue;
        }
        ran_any = true;
        let mut next = state.clone();
        (action.apply)(&mut next, action.tag);
        pcs[ti] += 1;
        trail.push(format!("{}.{}", thread.name, action.name));
        let checked = (model.invariant)(&next)
            .map_err(|e| fail(trail, "invariant violated", &e))
            .and_then(|()| dfs(model, &next, pcs, trail, stats));
        trail.pop();
        pcs[ti] -= 1;
        checked?;
    }
    if !unfinished {
        stats.schedules += 1;
        (model.terminal)(state).map_err(|e| fail(trail, "terminal assertion failed", &e))?;
    } else if !ran_any {
        return Err(fail(trail, "deadlock", "unfinished threads exist but none is enabled"));
    }
    Ok(())
}

/// Render a failure with the schedule that produced it.
fn fail(trail: &[String], kind: &str, msg: &str) -> String {
    if trail.is_empty() {
        format!("{kind} in initial state: {msg}")
    } else {
        format!("{kind} after schedule [{}]: {msg}", trail.join(" -> "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone)]
    struct Counter {
        n: usize,
    }

    fn bump(s: &mut Counter, _tag: usize) {
        s.n += 1;
    }

    #[test]
    fn two_increments_interleave_fully() {
        // Two threads of two always-enabled steps each: C(4,2) = 6
        // distinct interleavings, all reaching n == 4.
        let threads = [
            ModelThread {
                name: "a",
                actions: vec![Action::always("inc1", 0, bump), Action::always("inc2", 0, bump)],
            },
            ModelThread {
                name: "b",
                actions: vec![Action::always("inc1", 1, bump), Action::always("inc2", 1, bump)],
            },
        ];
        let model = Model {
            threads: &threads,
            invariant: |s: &Counter| if s.n <= 4 { Ok(()) } else { Err("n > 4".into()) },
            terminal: |s: &Counter| {
                if s.n == 4 {
                    Ok(())
                } else {
                    Err(format!("n = {} at leaf", s.n))
                }
            },
            max_states: 10_000,
        };
        let ex = explore(&model, Counter { n: 0 }).expect("clean model");
        assert_eq!(ex.schedules, 6);
        assert!(ex.states > 6);
    }

    #[test]
    fn deadlock_is_detected() {
        // One thread waits for n >= 1; nobody ever bumps n.
        let threads = [ModelThread {
            name: "waiter",
            actions: vec![Action::new("wait", 0, |s: &Counter, _| s.n >= 1, |_, _| {})],
        }];
        let model = Model {
            threads: &threads,
            invariant: |_: &Counter| Ok(()),
            terminal: |_: &Counter| Ok(()),
            max_states: 100,
        };
        let err = explore(&model, Counter { n: 0 }).expect_err("must deadlock");
        assert!(err.contains("deadlock"), "unexpected error: {err}");
    }

    #[test]
    fn invariant_violation_reports_schedule() {
        let threads = [ModelThread {
            name: "t",
            actions: vec![Action::always("bump", 0, bump), Action::always("bump2", 0, bump)],
        }];
        let model = Model {
            threads: &threads,
            invariant: |s: &Counter| if s.n < 2 { Ok(()) } else { Err("n reached 2".into()) },
            terminal: |_: &Counter| Ok(()),
            max_states: 100,
        };
        let err = explore(&model, Counter { n: 0 }).expect_err("invariant must fire");
        assert!(err.contains("invariant violated"), "unexpected error: {err}");
        assert!(err.contains("t.bump -> t.bump2"), "schedule missing from: {err}");
    }

    #[test]
    fn state_budget_is_enforced() {
        let threads = [
            ModelThread {
                name: "a",
                actions: (0..6).map(|_| Action::always("inc", 0, bump)).collect(),
            },
            ModelThread {
                name: "b",
                actions: (0..6).map(|_| Action::always("inc", 1, bump)).collect(),
            },
        ];
        let model = Model {
            threads: &threads,
            invariant: |_: &Counter| Ok(()),
            terminal: |_: &Counter| Ok(()),
            max_states: 10,
        };
        let err = explore(&model, Counter { n: 0 }).expect_err("budget must trip");
        assert!(err.contains("state budget exceeded"), "unexpected error: {err}");
    }
}
