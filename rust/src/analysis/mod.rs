//! `speed-rl lint` — the repo's invariant linter (DESIGN.md §15).
//!
//! The codebase leans on conventions a compiler cannot check: every lock
//! acquisition goes through the poison-recovering wrappers in
//! `util/sync.rs`, multi-lock files respect a declared acquisition order,
//! counter structs round-trip every field through merge/JSON, harness
//! files are registered in the non-autodiscovered `Cargo.toml`, wall
//! clocks stay inside telemetry, and every numeric step metric is either
//! charted or exempted with a reason. Each of those conventions has
//! silently broken a class of tooling when violated — so this module
//! parses the repo's own source tree (via the line-preserving
//! [`scanner`]) and enforces them as hard CI gates ahead of fmt/clippy.
//!
//! The passes themselves ([`lints`]) are pure functions over source text;
//! this module owns the file walking and orchestration. [`model`] is the
//! companion *dynamic* side of the same contract: an exhaustive
//! interleaving explorer that model-checks the sync protocols the L1 lint
//! guards statically.

pub mod lints;
pub mod model;
pub mod scanner;

use std::path::{Path, PathBuf};

use anyhow::Context;

/// One finding: which lint fired, where, and why. `line` is 1-based;
/// 0 means the finding is file-scoped (e.g. a missing declaration).
pub struct Violation {
    pub lint: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Violation {
    pub fn new(lint: &'static str, file: &str, line: usize, message: &str) -> Violation {
        Violation { lint, file: file.to_string(), line, message: message.to_string() }
    }
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.file, self.lint, self.message)
        } else {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.message)
        }
    }
}

/// Result of a full lint run over the repository.
pub struct LintReport {
    /// All findings, sorted by `(file, line)`.
    pub violations: Vec<Violation>,
    /// Number of `.rs` files scanned under `rust/src/`.
    pub files_scanned: usize,
}

/// Run every lint pass against the repository rooted at `root` (the
/// directory holding `Cargo.toml`, `rust/`, and `benches/`).
///
/// * L1 (raw locks + lock order) and L4 (wall clocks) walk every `.rs`
///   file under `rust/src/`.
/// * L2 reads `rust/src/metrics/mod.rs` against the chaos smoke in
///   `rust/ci.sh`.
/// * L3 diffs the `rust/tests/` and `benches/` directory listings against
///   the `path = "..."` entries in `Cargo.toml`.
/// * L5 reads `StepRecord` out of `rust/src/metrics/mod.rs` against the
///   metric tables in `rust/src/metrics/report.rs`.
pub fn run_lints(root: &Path) -> anyhow::Result<LintReport> {
    let src_dir = root.join("rust").join("src");
    let mut files = Vec::new();
    walk_rs(&src_dir, &mut files)
        .with_context(|| format!("walking {}", src_dir.display()))?;
    files.sort();

    let mut violations = Vec::new();
    for path in &files {
        let rel = rel_path(root, path);
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let cs = scanner::clean(&src);
        violations.extend(lints::lint_raw_locks(&rel, &cs));
        if let Some(spec) = lints::LOCK_ORDERS.iter().find(|s| rel.ends_with(s.file_suffix)) {
            violations.extend(lints::lint_lock_order(&rel, &cs, spec));
        }
        violations.extend(lints::lint_wall_clock(&rel, &cs));
    }

    let read_rel = |rel: &str| -> anyhow::Result<String> {
        std::fs::read_to_string(root.join(rel)).with_context(|| format!("reading {rel}"))
    };
    let metrics_src = read_rel("rust/src/metrics/mod.rs")?;
    let report_src = read_rel("rust/src/metrics/report.rs")?;
    let ci_src = read_rel("rust/ci.sh")?;
    let cargo_src = read_rel("Cargo.toml")?;
    violations.extend(lints::lint_counter_schema(
        "rust/src/metrics/mod.rs",
        &metrics_src,
        "rust/ci.sh",
        &ci_src,
    ));
    violations.extend(lints::lint_step_metrics(
        "rust/src/metrics/mod.rs",
        &metrics_src,
        "rust/src/metrics/report.rs",
        &report_src,
    ));
    let test_files = list_rs(&root.join("rust").join("tests"), "rust/tests")?;
    let bench_files = list_rs(&root.join("benches"), "benches")?;
    violations.extend(lints::lint_harness_registration(
        "Cargo.toml",
        &cargo_src,
        &test_files,
        &bench_files,
    ));

    violations.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(LintReport { violations, files_scanned: files.len() })
}

/// Recursively collect `.rs` files under `dir`.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Non-recursive listing of `.rs` files in `dir` as `prefix/name.rs`
/// strings, sorted. A missing directory lists as empty (the lint then has
/// nothing to check rather than erroring).
fn list_rs(dir: &Path, prefix: &str) -> anyhow::Result<Vec<String>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let path = entry?.path();
        if path.is_file() && path.extension().is_some_and(|e| e == "rs") {
            if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
                out.push(format!("{prefix}/{name}"));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// `path` relative to `root`, with forward slashes, as a display string.
fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The linter's own acceptance gate: the repository must be clean.
    /// Every new raw lock, misordered acquisition, dropped counter field,
    /// unregistered harness, stray wall clock, or unchartered metric
    /// fails this test (and the `speed-rl lint` CI gate) with a precise
    /// location.
    #[test]
    fn repo_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run_lints(root).expect("lint run");
        let rendered: Vec<String> =
            report.violations.iter().map(|v| v.to_string()).collect();
        assert!(
            rendered.is_empty(),
            "repository violates its own invariants:\n{}",
            rendered.join("\n")
        );
        assert!(report.files_scanned > 20, "walker found too few files: {}", report.files_scanned);
    }

    #[test]
    fn violations_render_with_and_without_line() {
        let v = Violation::new("L1", "src/x.rs", 7, "msg");
        assert_eq!(v.to_string(), "src/x.rs:7: [L1] msg");
        let v = Violation::new("L2", "src/x.rs", 0, "msg");
        assert_eq!(v.to_string(), "src/x.rs: [L2] msg");
    }
}
