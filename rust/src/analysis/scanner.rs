//! Line-preserving Rust source cleaner for the invariant lints.
//!
//! Deliberately *not* a parser: the L1–L5 passes only need to see code
//! tokens with comments and literal contents out of the way, at their
//! original line numbers. [`clean`] blanks comments and the *contents* of
//! string/char literals with spaces (delimiters and newlines survive, so
//! byte columns and line numbers are stable), and marks every line that
//! sits inside a `#[cfg(test)]` item so lints can restrict themselves to
//! shipping code. Anything this cleaner cannot see (macro-generated locks,
//! cross-function lock nesting) is out of scope by design — DESIGN.md §15
//! records those limits next to the invariants themselves.

/// A cleaned view of one source file. `lines[i]` is source line `i + 1`
/// with comments and literal contents blanked; `in_test[i]` is true when
/// that line belongs to a `#[cfg(test)]` region (the attribute line, the
/// item header, and everything through the item's closing brace).
pub struct CleanSource {
    pub lines: Vec<String>,
    pub in_test: Vec<bool>,
}

impl CleanSource {
    /// Iterate the cleaned lines of shipping (non-test) code as
    /// `(1-based line number, cleaned text)`.
    pub fn shipping_lines(&self) -> impl Iterator<Item = (usize, &str)> {
        self.lines
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.in_test[*i])
            .map(|(i, l)| (i + 1, l.as_str()))
    }
}

/// Clean `src`: blank comments (line and nested block) and the contents of
/// string / raw-string / char literals, preserving structure, then mark
/// `#[cfg(test)]` regions.
pub fn clean(src: &str) -> CleanSource {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if chars[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
        } else if !prev_ident && (c == 'r' || c == 'b') && raw_string_at(&chars, i).is_some() {
            let (quote, hashes) = raw_string_at(&chars, i).expect("checked above");
            for _ in i..=quote {
                out.push(' ');
            }
            out.push('"');
            i = quote + 1;
            // Contents end at `"` followed by exactly `hashes` hashes.
            while i < n {
                if chars[i] == '"' && count_hashes(&chars, i + 1) >= hashes {
                    out.push('"');
                    for _ in 0..hashes {
                        out.push(' ');
                    }
                    i += 1 + hashes;
                    break;
                }
                out.push(if chars[i] == '\n' { '\n' } else { ' ' });
                i += 1;
            }
        } else if c == '"' {
            out.push('"');
            i += 1;
            while i < n {
                match chars[i] {
                    '\\' if i + 1 < n => {
                        out.push_str("  ");
                        i += 2;
                    }
                    '"' => {
                        out.push('"');
                        i += 1;
                        break;
                    }
                    '\n' => {
                        out.push('\n');
                        i += 1;
                    }
                    _ => {
                        out.push(' ');
                        i += 1;
                    }
                }
            }
        } else if c == '\'' {
            // Char literal vs lifetime: `'\...'` and `'x'` are literals,
            // anything else (`'a`, `'static`, loop labels) passes through.
            let is_escape = i + 1 < n && chars[i + 1] == '\\';
            let is_plain = i + 2 < n && chars[i + 1] != '\'' && chars[i + 2] == '\'';
            if is_escape {
                out.push('\'');
                i += 1;
                while i < n {
                    match chars[i] {
                        '\\' if i + 1 < n => {
                            out.push_str("  ");
                            i += 2;
                        }
                        '\'' => {
                            out.push('\'');
                            i += 1;
                            break;
                        }
                        _ => {
                            out.push(' ');
                            i += 1;
                        }
                    }
                }
            } else if is_plain {
                out.push('\'');
                out.push(' ');
                out.push('\'');
                i += 3;
            } else {
                out.push('\'');
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    let lines: Vec<String> = out.lines().map(|l| l.to_string()).collect();
    let in_test = test_regions(&lines);
    CleanSource { lines, in_test }
}

/// If a raw string starts at `i` (`r"`, `r#"`, `br"`, ...), return the
/// index of its opening quote and the number of hashes.
fn raw_string_at(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let hashes = count_hashes(chars, j);
    j += hashes;
    if chars.get(j) == Some(&'"') {
        Some((j, hashes))
    } else {
        None
    }
}

fn count_hashes(chars: &[char], from: usize) -> usize {
    chars[from.min(chars.len())..].iter().take_while(|&&c| c == '#').count()
}

/// Mark the lines covered by `#[cfg(test)]` items: from the attribute
/// through the closing brace of the item it gates (or through the `;` of a
/// braceless item). Runs on cleaned lines, so braces in strings/comments
/// cannot desync the depth tracking.
fn test_regions(lines: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut depth: i32 = 0;
    let mut pending = false;
    let mut region_depth: Option<i32> = None;
    for (li, line) in lines.iter().enumerate() {
        if region_depth.is_some() || pending {
            in_test[li] = true;
        }
        if line.contains("#[cfg(test)]") {
            pending = true;
            in_test[li] = true;
        }
        for ch in line.chars() {
            match ch {
                '{' => {
                    if pending && region_depth.is_none() {
                        region_depth = Some(depth);
                        pending = false;
                        in_test[li] = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some(rd) = region_depth {
                        if depth <= rd {
                            region_depth = None;
                        }
                    }
                }
                ';' if pending && region_depth.is_none() => {
                    pending = false;
                }
                _ => {}
            }
        }
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let a = 1; // x.lock()\nlet b = \".lock()\";\n/* .lock()\n.lock() */ let c = 2;\n";
        let cs = clean(src);
        assert_eq!(cs.lines.len(), 4);
        for l in &cs.lines {
            assert!(!l.contains(".lock()"), "literal survived cleaning: {l}");
        }
        assert!(cs.lines[0].contains("let a = 1;"));
        assert!(cs.lines[1].contains("let b = \""));
        assert!(cs.lines[3].contains("let c = 2;"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let src = "fn f<'a>(x: &'a str) -> char { if x.is_empty() { '{' } else { '\\'' } }\n";
        let cs = clean(src);
        assert!(cs.lines[0].contains("<'a>"), "lifetime mangled: {}", cs.lines[0]);
        assert!(!cs.lines[0].contains("'{'"), "char literal survived: {}", cs.lines[0]);
        // The blanked brace literal must not perturb depth tracking:
        let opens = cs.lines[0].matches('{').count();
        let closes = cs.lines[0].matches('}').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"a \".lock()\" b\"#;\nlet t = 3;\n";
        let cs = clean(src);
        assert!(!cs.lines[0].contains(".lock()"));
        assert!(cs.lines[1].contains("let t = 3;"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn ship() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn ship2() {}\n";
        let cs = clean(src);
        assert_eq!(cs.in_test, vec![false, true, true, true, true, false]);
        let shipping: Vec<usize> = cs.shipping_lines().map(|(n, _)| n).collect();
        assert_eq!(shipping, vec![1, 6]);
    }
}
