//! RL core: advantage estimators, algorithm configurations, and the SNR/Φ
//! theory of paper §3 and Appendices A/B.

pub mod advantage;
pub mod algo;
pub mod theory;
pub mod update;

pub use advantage::AdvantageEstimator;
pub use algo::{AlgoConfig, BaseAlgo};
