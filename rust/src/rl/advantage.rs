//! Advantage estimators over a *group* of N rollouts for one prompt.
//!
//! The compiled `train_step` consumes per-rollout scalar advantages; which
//! estimator produces them is an L3 decision, so all the paper's baselines
//! (RLOO eq. 8, GRPO, REINFORCE w/ batch baseline, REINFORCE++) live here.

/// Which estimator converts group rewards into advantages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdvantageEstimator {
    /// Leave-one-out baseline (paper eq. 8): `A_i = r_i - mean_{j!=i} r_j`.
    Rloo,
    /// Group-normalized: `A_i = (r_i - mean) / (std + eps)` (GRPO).
    Grpo,
    /// Plain REINFORCE with a moving global baseline supplied by the caller.
    Reinforce,
    /// REINFORCE++-style: group mean baseline then *batch-level* whitening
    /// (the whitening pass is applied by [`whiten`] over the whole batch).
    ReinforcePlusPlus,
}

impl AdvantageEstimator {
    pub fn name(&self) -> &'static str {
        match self {
            AdvantageEstimator::Rloo => "rloo",
            AdvantageEstimator::Grpo => "grpo",
            AdvantageEstimator::Reinforce => "reinforce",
            AdvantageEstimator::ReinforcePlusPlus => "reinforce++",
        }
    }

    /// Per-group advantages. `global_baseline` is only used by `Reinforce`.
    pub fn advantages(&self, rewards: &[f32], global_baseline: f32) -> Vec<f32> {
        match self {
            AdvantageEstimator::Rloo => rloo(rewards),
            AdvantageEstimator::Grpo => grpo(rewards),
            AdvantageEstimator::Reinforce => {
                rewards.iter().map(|r| r - global_baseline).collect()
            }
            AdvantageEstimator::ReinforcePlusPlus => {
                let mean = mean(rewards);
                rewards.iter().map(|r| r - mean).collect()
            }
        }
    }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// RLOO (eq. 8): `A_i = r_i - (sum - r_i) / (N - 1)`.
pub fn rloo(rewards: &[f32]) -> Vec<f32> {
    let n = rewards.len();
    if n <= 1 {
        return vec![0.0; n];
    }
    let sum: f32 = rewards.iter().sum();
    rewards
        .iter()
        .map(|&r| r - (sum - r) / (n as f32 - 1.0))
        .collect()
}

/// GRPO group normalization.
pub fn grpo(rewards: &[f32]) -> Vec<f32> {
    let n = rewards.len();
    if n <= 1 {
        return vec![0.0; n];
    }
    let m = mean(rewards);
    let var = rewards.iter().map(|r| (r - m) * (r - m)).sum::<f32>() / n as f32;
    let std = var.sqrt();
    if std < 1e-8 {
        return vec![0.0; n]; // uniform rewards carry no signal (paper eq. 6)
    }
    rewards.iter().map(|r| (r - m) / (std + 1e-6)).collect()
}

/// Equal-prompt weight for a group of `n` rollouts in a batch whose mean
/// group size is `mean_n`.
///
/// With variable per-prompt rollout budgets a large-budget group would
/// otherwise dominate the batch gradient simply by contributing more rows:
/// scaling each rollout's advantage by `mean_n / n` keeps every *prompt's*
/// total gradient weight equal, so extra rollouts reduce that prompt's
/// estimator variance (what they were allocated for) without upweighting
/// it. Uniform group sizes give `mean_n == n` and a weight of exactly 1.0
/// for every group — bit-for-bit the unweighted batch.
pub fn group_size_weight(n: usize, mean_n: f64) -> f32 {
    if n == 0 {
        return 0.0;
    }
    (mean_n / n as f64) as f32
}

/// Batch-level whitening (REINFORCE++ second stage): zero-mean, unit-var.
pub fn whiten(advs: &mut [f32]) {
    let n = advs.len();
    if n <= 1 {
        return;
    }
    let m = advs.iter().sum::<f32>() / n as f32;
    let var = advs.iter().map(|a| (a - m) * (a - m)).sum::<f32>() / n as f32;
    let std = var.sqrt().max(1e-8);
    for a in advs.iter_mut() {
        *a = (*a - m) / std;
    }
}

/// Empirical pass rate of a reward group.
pub fn pass_rate(rewards: &[f32]) -> f64 {
    if rewards.is_empty() {
        return 0.0;
    }
    rewards.iter().filter(|&&r| r > 0.5).count() as f64 / rewards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::prop_assert;

    fn rand_rewards(rng: &mut crate::util::rng::Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| if rng.bool(0.4) { 1.0 } else { 0.0 }).collect()
    }

    #[test]
    fn rloo_matches_direct_formula() {
        let r = [1.0, 0.0, 0.0, 1.0];
        let a = rloo(&r);
        // A_0 = 1 - (0+0+1)/3 = 2/3
        assert!((a[0] - 2.0 / 3.0).abs() < 1e-6);
        assert!((a[1] + 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn rloo_zero_for_uniform_groups() {
        check("rloo-uniform-zero", 50, |rng| {
            let n = rng.range_usize(2, 32);
            let val = if rng.bool(0.5) { 1.0 } else { 0.0 };
            let a = rloo(&vec![val; n]);
            prop_assert!(a.iter().all(|&x| x.abs() < 1e-6), "nonzero adv for uniform rewards");
            Ok(())
        });
    }

    #[test]
    fn rloo_unbiased_mean_zero() {
        // sum of RLOO advantages is N/(N-1) * sum(r - mean) = 0
        check("rloo-sums-zero", 100, |rng| {
            let n = rng.range_usize(2, 24);
            let r = rand_rewards(rng, n);
            let a = rloo(&r);
            let s: f32 = a.iter().sum();
            prop_assert!(s.abs() < 1e-4, "sum {s}");
            Ok(())
        });
    }

    #[test]
    fn rloo_scale_is_n_over_n_minus_1_of_centered() {
        check("rloo-scale", 100, |rng| {
            let n = rng.range_usize(2, 24);
            let r = rand_rewards(rng, n);
            let m: f32 = r.iter().sum::<f32>() / n as f32;
            let a = rloo(&r);
            let k = n as f32 / (n as f32 - 1.0);
            for (ai, ri) in a.iter().zip(&r) {
                prop_assert!((ai - k * (ri - m)).abs() < 1e-5, "mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn grpo_unit_variance() {
        check("grpo-unit-var", 60, |rng| {
            let n = rng.range_usize(4, 32);
            let r = rand_rewards(rng, n);
            let a = grpo(&r);
            let m: f32 = a.iter().sum::<f32>() / n as f32;
            if a.iter().any(|&x| x != 0.0) {
                let var: f32 = a.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / n as f32;
                prop_assert!((var - 1.0).abs() < 0.02, "var {var}");
            }
            Ok(())
        });
    }

    #[test]
    fn group_size_weight_is_identity_for_uniform_groups() {
        for n in [1usize, 2, 8, 24, 384] {
            assert_eq!(group_size_weight(n, n as f64), 1.0, "n={n}");
        }
        assert_eq!(group_size_weight(0, 8.0), 0.0);
    }

    #[test]
    fn group_size_weight_equalizes_total_group_weight() {
        // Two groups of sizes 6 and 2 (mean 4): each prompt's total weight
        // (rows x weight) must come out equal.
        let w_big = group_size_weight(6, 4.0);
        let w_small = group_size_weight(2, 4.0);
        assert!((6.0 * w_big as f64 - 2.0 * w_small as f64).abs() < 1e-6);
        assert!(w_big < 1.0 && w_small > 1.0);
    }

    #[test]
    fn whiten_normalizes() {
        let mut a = vec![3.0, 5.0, 1.0, 7.0, -2.0];
        whiten(&mut a);
        let m: f32 = a.iter().sum::<f32>() / 5.0;
        let var: f32 = a.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / 5.0;
        assert!(m.abs() < 1e-6 && (var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn pass_rate_counts() {
        assert_eq!(pass_rate(&[1.0, 0.0, 1.0, 0.0]), 0.5);
        assert_eq!(pass_rate(&[]), 0.0);
    }

    #[test]
    fn estimator_dispatch() {
        let r = [1.0, 0.0];
        prop_check_dispatch(&r);
    }

    fn prop_check_dispatch(r: &[f32]) {
        assert_eq!(AdvantageEstimator::Rloo.advantages(r, 0.0), rloo(r));
        assert_eq!(AdvantageEstimator::Grpo.advantages(r, 0.0), grpo(r));
        let re = AdvantageEstimator::Reinforce.advantages(r, 0.25);
        assert_eq!(re, vec![0.75, -0.25]);
    }
}
