//! The paper's theory, executable: SNR bounds (Theorem 3.1), the implicit
//! objective Φ (Theorem 4.1), Fact 1's improvement bound, and screening
//! acceptance probabilities. `examples/theory_check.rs` validates the
//! bounds against Monte-Carlo estimates on a tractable policy.

/// Exact Theorem 3.1 upper bound (from the proof's final display):
/// `SNR <= [ 1/(N p (1-p)) + (N-2)(N-3)/(N(N-1)) - 1 ]^{-1}`.
///
/// Returns 0 at p in {0, 1} (the gradient itself vanishes, eq. 6).
pub fn snr_bound_exact(n: usize, p: f64) -> f64 {
    if !(0.0..=1.0).contains(&p) || p == 0.0 || p == 1.0 || n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let denom = 1.0 / (nf * p * (1.0 - p)) + (nf - 2.0) * (nf - 3.0) / (nf * (nf - 1.0)) - 1.0;
    if denom <= 0.0 {
        f64::INFINITY
    } else {
        1.0 / denom
    }
}

/// The simplified headline bound of eq. (11): `SNR <= 4 N p (1-p)`.
pub fn snr_bound_simple(n: usize, p: f64) -> f64 {
    4.0 * n as f64 * p * (1.0 - p)
}

/// Fact 1: expected one-step improvement lower bound
/// `E[J(θ+)] - J(θ) >= 0.5 ||∇J||² (1 - 1/SNR)`.
pub fn fact1_improvement(grad_norm_sq: f64, snr: f64) -> f64 {
    if snr <= 0.0 {
        // SNR -> 0: the bound degenerates to -inf; callers treat this as
        // "no guaranteed progress".
        return f64::NEG_INFINITY;
    }
    0.5 * grad_norm_sq * (1.0 - 1.0 / snr)
}

/// Binomial pmf P(X = k), X ~ Bin(n, p). Direct product; n <= a few hundred.
pub fn binom_pmf(n: usize, k: usize, p: f64) -> f64 {
    if k > n {
        return 0.0;
    }
    if p <= 0.0 {
        return if k == 0 { 1.0 } else { 0.0 };
    }
    if p >= 1.0 {
        return if k == n { 1.0 } else { 0.0 };
    }
    // log-space for stability
    let mut log = 0.0f64;
    for i in 0..k {
        log += ((n - i) as f64).ln() - ((i + 1) as f64).ln();
    }
    log += k as f64 * p.ln() + (n - k) as f64 * (1.0 - p).ln();
    log.exp()
}

/// Probability that SPEED's screening test accepts a prompt with true pass
/// rate `p`: `P( p_low < X/N_init < p_high )`, X ~ Bin(N_init, p).
/// With the paper's default thresholds (0, 1) this is
/// `1 - p^N_init - (1-p)^N_init`.
pub fn acceptance_probability(n_init: usize, p: f64, p_low: f64, p_high: f64) -> f64 {
    let mut acc = 0.0;
    for k in 0..=n_init {
        let rate = k as f64 / n_init as f64;
        if rate > p_low && rate < p_high {
            acc += binom_pmf(n_init, k, p);
        }
    }
    acc.clamp(0.0, 1.0)
}

/// Theorem 4.1's reweighting map Φ (Appendix B closed form, up to the
/// additive constant):
///
/// Φ(p) = p − N_cont/(N (N_init+1)) (p^{N_init+1} − (1−p)^{N_init+1})
///        + N_cont/(N (N−1)(N_init+1)) ((1+N_init p)(1−p)^{N_init}
///                                      − p^{N_init}(N_init(1−p)+1))
pub fn phi(p: f64, n_init: usize, n_cont: usize) -> f64 {
    let p = p.clamp(0.0, 1.0);
    let ni = n_init as f64;
    let nc = n_cont as f64;
    let n = ni + nc;
    let q = 1.0 - p;
    let term1 = nc / (n * (ni + 1.0)) * (p.powi(n_init as i32 + 1) - q.powi(n_init as i32 + 1));
    let term2 = nc / (n * (n - 1.0) * (ni + 1.0))
        * ((1.0 + ni * p) * q.powi(n_init as i32) - p.powi(n_init as i32) * (ni * q + 1.0));
    p - term1 + term2
}

/// dΦ/dp (Appendix B): the weight SPEED-RLOO implicitly puts on a prompt's
/// gradient as a function of its pass rate.
pub fn phi_derivative(p: f64, n_init: usize, n_cont: usize) -> f64 {
    let p = p.clamp(0.0, 1.0);
    let ni = n_init as f64;
    let nc = n_cont as f64;
    let n = ni + nc;
    let q = 1.0 - p;
    let pow = |x: f64, e: i32| x.powi(e);
    1.0 - nc / n * (pow(p, n_init as i32) + pow(q, n_init as i32))
        - ni * nc / (n * (n - 1.0))
            * (p * pow(q, n_init as i32 - 1) + q * pow(p, n_init as i32 - 1))
}

/// Numerically integrate phi_derivative to cross-check the closed form.
#[cfg(test)]
fn phi_numeric(p: f64, n_init: usize, n_cont: usize, steps: usize) -> f64 {
    let mut acc = phi(0.0, n_init, n_cont);
    let h = p / steps as f64;
    for i in 0..steps {
        let x0 = i as f64 * h;
        let x1 = x0 + h;
        acc += 0.5 * h * (phi_derivative(x0, n_init, n_cont) + phi_derivative(x1, n_init, n_cont));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::prop_assert;

    #[test]
    fn snr_bounds_vanish_at_extremes() {
        for n in [4, 8, 24, 64] {
            assert_eq!(snr_bound_exact(n, 0.0), 0.0);
            assert_eq!(snr_bound_exact(n, 1.0), 0.0);
            assert!(snr_bound_exact(n, 1e-6) < 1e-3);
            assert!(snr_bound_exact(n, 1.0 - 1e-6) < 1e-3);
        }
    }

    #[test]
    fn snr_bound_peaks_at_half() {
        let n = 24;
        let mid = snr_bound_exact(n, 0.5);
        for p in [0.05, 0.1, 0.2, 0.35, 0.65, 0.9] {
            assert!(snr_bound_exact(n, p) <= mid + 1e-12, "p={p}");
        }
    }

    #[test]
    fn exact_bound_tighter_than_simple_in_tails() {
        // Theorem 3.1 states the 4Np(1-p) form for p < 1/4 or p > 3/4.
        for n in [8, 24, 64] {
            for p in [0.01, 0.05, 0.1, 0.2, 0.8, 0.9, 0.99] {
                let exact = snr_bound_exact(n, p);
                let simple = snr_bound_simple(n, p);
                assert!(exact <= simple + 1e-9, "n={n} p={p}: {exact} > {simple}");
            }
        }
    }

    #[test]
    fn binom_pmf_sums_to_one() {
        check("binom-normalized", 40, |rng| {
            let n = rng.range_usize(1, 64);
            let p = rng.f64();
            let total: f64 = (0..=n).map(|k| binom_pmf(n, k, p)).sum();
            prop_assert!((total - 1.0).abs() < 1e-9, "sum {total}");
            Ok(())
        });
    }

    #[test]
    fn acceptance_matches_closed_form_for_default_thresholds() {
        check("acceptance-closed-form", 60, |rng| {
            let n_init = rng.range_usize(2, 16);
            let p = rng.f64();
            let got = acceptance_probability(n_init, p, 0.0, 1.0);
            let expect = 1.0 - p.powi(n_init as i32) - (1.0 - p).powi(n_init as i32);
            prop_assert!((got - expect).abs() < 1e-9, "got {got}, closed {expect}");
            Ok(())
        });
    }

    #[test]
    fn acceptance_low_at_extremes_high_at_half() {
        let a0 = acceptance_probability(8, 0.01, 0.0, 1.0);
        let ah = acceptance_probability(8, 0.5, 0.0, 1.0);
        let a1 = acceptance_probability(8, 0.99, 0.0, 1.0);
        assert!(a0 < 0.1 && a1 < 0.1 && ah > 0.99, "{a0} {ah} {a1}");
    }

    #[test]
    fn phi_is_monotone_increasing() {
        // Theorem 4.1: Φ' >= 0 for all valid (N_init, N_cont).
        for (ni, nc) in [(1, 1), (4, 20), (6, 18), (8, 16), (2, 62)] {
            let mut prev = phi(0.0, ni, nc);
            for i in 1..=200 {
                let p = i as f64 / 200.0;
                let cur = phi(p, ni, nc);
                assert!(cur >= prev - 1e-12, "ni={ni} nc={nc} p={p}: {cur} < {prev}");
                prev = cur;
            }
        }
    }

    #[test]
    fn phi_derivative_nonnegative_and_matches_integral() {
        check("phi-deriv", 40, |rng| {
            let ni = rng.range_usize(1, 10);
            let nc = rng.range_usize(1, 30);
            let p = rng.f64();
            let d = phi_derivative(p, ni, nc);
            prop_assert!(d >= -1e-9, "phi' = {d} < 0 at p={p}, ni={ni}, nc={nc}");
            let numeric = phi_numeric(p, ni, nc, 400);
            let closed = phi(p, ni, nc);
            prop_assert!(
                (numeric - closed).abs() < 1e-4,
                "phi mismatch at p={p}: closed {closed}, integral {numeric}"
            );
            Ok(())
        });
    }

    #[test]
    fn phi_maximized_at_one() {
        for (ni, nc) in [(4, 20), (8, 16)] {
            let at_one = phi(1.0, ni, nc);
            for i in 0..100 {
                let p = i as f64 / 100.0;
                assert!(phi(p, ni, nc) <= at_one + 1e-12);
            }
        }
    }

    #[test]
    fn fact1_signs() {
        assert!(fact1_improvement(1.0, 2.0) > 0.0); // SNR > 1 -> progress
        assert!(fact1_improvement(1.0, 0.5) < 0.0); // SNR < 1 -> no guarantee
        assert_eq!(fact1_improvement(1.0, 0.0), f64::NEG_INFINITY);
    }
}
