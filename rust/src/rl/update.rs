//! Train-batch assembly: rollout groups -> the fixed-shape tensors the
//! compiled `train_step` artifact consumes.
//!
//! Row layout contract (must mirror `model.rollout` positions exactly so
//! recomputed logprobs align with behavior logprobs): prompt tokens at
//! `[0, len)`, generated tokens at `[len, len+G)`, PAD tail. The loss mask
//! covers generated tokens up to and including the first EOS.

use anyhow::Result;

use crate::data::verifier::loss_token_count;
use crate::rl::advantage::{group_size_weight, AdvantageEstimator};
use crate::runtime::Tensor;

/// One sampled response for a prompt.
#[derive(Clone, Debug)]
pub struct Rollout {
    pub gen_tokens: Vec<i32>,
    pub gen_logprobs: Vec<f32>,
    pub reward: f32,
}

/// A prompt together with its group of N rollouts (screening + continuation).
#[derive(Clone, Debug)]
pub struct PromptGroup {
    /// Index into the training dataset.
    pub prompt_idx: usize,
    /// The task (the policy tokenizes `task.prompt` when assembling rows).
    pub task: crate::data::tasks::TaskInstance,
    pub rollouts: Vec<Rollout>,
}

impl PromptGroup {
    pub fn rewards(&self) -> Vec<f32> {
        self.rollouts.iter().map(|r| r.reward).collect()
    }

    pub fn pass_rate(&self) -> f64 {
        crate::rl::advantage::pass_rate(&self.rewards())
    }
}

/// Host-side train batch, ready to convert into artifact inputs.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    pub rows: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub old_logprobs: Vec<f32>,
    pub advantages: Vec<f32>,
    /// Rows actually carrying data (the rest are zero padding).
    pub rows_used: usize,
    /// Mean |advantage| over used rows (diagnostic).
    pub mean_abs_adv: f64,
}

impl TrainBatch {
    /// Assemble a fixed-shape batch from prompt groups.
    ///
    /// * `tok` — tokenizer for the prompts.
    /// * `rows`/`seq_len` — the compiled train artifact's shape.
    /// * `estimator` — converts group rewards to advantages.
    /// * `global_baseline` — only used by plain REINFORCE.
    ///
    /// Unused trailing rows are zero-padded (mask 0 ⇒ no gradient).
    ///
    /// Group-size-aware normalization: when group sizes differ (variable
    /// per-prompt rollout budgets), each group's advantages are scaled by
    /// `mean_group_size / group_size` so every prompt carries equal total
    /// gradient weight — see [`group_size_weight`]. Uniform groups get a
    /// weight of exactly 1.0, leaving the batch bit-for-bit unchanged.
    pub fn assemble(
        groups: &[PromptGroup],
        tok: &crate::data::tokenizer::Tokenizer,
        estimator: AdvantageEstimator,
        global_baseline: f32,
        rows: usize,
        seq_len: usize,
    ) -> Result<TrainBatch> {
        let total_rollouts: usize = groups.iter().map(|g| g.rollouts.len()).sum();
        anyhow::ensure!(
            total_rollouts <= rows,
            "batch of {total_rollouts} rollouts exceeds compiled rows {rows}"
        );
        let mean_group = if groups.is_empty() {
            0.0
        } else {
            total_rollouts as f64 / groups.len() as f64
        };
        let mut tokens = vec![0i32; rows * seq_len];
        let mut loss_mask = vec![0f32; rows * seq_len];
        let mut old_logprobs = vec![0f32; rows * seq_len];
        let mut advantages = vec![0f32; rows];
        let mut row = 0usize;
        let mut adv_sum = 0f64;
        for g in groups {
            let weight = group_size_weight(g.rollouts.len(), mean_group);
            let advs: Vec<f32> = estimator
                .advantages(&g.rewards(), global_baseline)
                .into_iter()
                .map(|a| a * weight)
                .collect();
            let prompt_tokens = tok.encode(&g.task.prompt)?;
            let plen = prompt_tokens.len();
            for (r, adv) in g.rollouts.iter().zip(advs) {
                anyhow::ensure!(
                    plen + r.gen_tokens.len() <= seq_len,
                    "row overflow: prompt {plen} + gen {} > seq {seq_len}",
                    r.gen_tokens.len()
                );
                let base = row * seq_len;
                tokens[base..base + plen].copy_from_slice(&prompt_tokens);
                let gbase = base + plen;
                tokens[gbase..gbase + r.gen_tokens.len()].copy_from_slice(&r.gen_tokens);
                let k = loss_token_count(&r.gen_tokens);
                for j in 0..k {
                    loss_mask[gbase + j] = 1.0;
                    old_logprobs[gbase + j] = r.gen_logprobs[j];
                }
                advantages[row] = adv;
                adv_sum += adv.abs() as f64;
                row += 1;
            }
        }
        Ok(TrainBatch {
            rows,
            seq_len,
            tokens,
            loss_mask,
            old_logprobs,
            advantages,
            rows_used: row,
            mean_abs_adv: if row > 0 { adv_sum / row as f64 } else { 0.0 },
        })
    }

    /// Convert to the artifact's data-argument tensors
    /// `(tokens, loss_mask, old_logprobs, advantages)`.
    pub fn tensors(&self) -> (Tensor, Tensor, Tensor, Tensor) {
        (
            Tensor::i32(vec![self.rows, self.seq_len], self.tokens.clone()),
            Tensor::f32(vec![self.rows, self.seq_len], self.loss_mask.clone()),
            Tensor::f32(vec![self.rows, self.seq_len], self.old_logprobs.clone()),
            Tensor::f32(vec![self.rows], self.advantages.clone()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::EOS;
    use crate::util::proptest::check;
    use crate::prop_assert;

    use crate::data::tasks::{TaskFamily, TaskInstance};
    use crate::data::tokenizer::Tokenizer;

    /// `prompt` is a string; "234" encodes to token ids [5, 6, 7].
    fn group(prompt: &str, gens: Vec<(Vec<i32>, f32)>) -> PromptGroup {
        PromptGroup {
            prompt_idx: 0,
            task: TaskInstance {
                family: TaskFamily::Add,
                level: 1,
                prompt: prompt.to_string(),
                answer: 0,
            },
            rollouts: gens
                .into_iter()
                .map(|(g, reward)| Rollout {
                    gen_logprobs: vec![-0.5; g.len()],
                    gen_tokens: g,
                    reward,
                })
                .collect(),
        }
    }

    fn tok() -> Tokenizer {
        Tokenizer::new()
    }

    #[test]
    fn layout_places_gen_after_prompt() {
        let g = group("234", vec![(vec![8, EOS, 9, 9], 1.0), (vec![8, 8, 8, EOS], 0.0)]);
        let b = TrainBatch::assemble(&[g], &tok(), AdvantageEstimator::Rloo, 0.0, 4, 10).unwrap();
        assert_eq!(b.rows_used, 2);
        // row 0: prompt at 0..3, gen at 3..7
        assert_eq!(&b.tokens[0..7], &[5, 6, 7, 8, EOS, 9, 9]);
        // mask covers gen tokens up to + incl EOS only
        assert_eq!(&b.loss_mask[0..10], &[0., 0., 0., 1., 1., 0., 0., 0., 0., 0.]);
        // row 1: no EOS until last -> all 4 gen positions masked
        assert_eq!(&b.loss_mask[10..20], &[0., 0., 0., 1., 1., 1., 1., 0., 0., 0.]);
        // padding rows zeroed
        assert!(b.tokens[20..].iter().all(|&t| t == 0));
        assert_eq!(b.advantages[2], 0.0);
    }

    #[test]
    fn rloo_advantages_in_batch() {
        let g = group("1", vec![(vec![EOS], 1.0), (vec![EOS], 0.0)]);
        let b = TrainBatch::assemble(&[g], &tok(), AdvantageEstimator::Rloo, 0.0, 2, 4).unwrap();
        assert_eq!(b.advantages, vec![1.0, -1.0]);
    }

    #[test]
    fn overflow_rejected() {
        let g = group("11111111", vec![(vec![2; 8], 1.0)]);
        assert!(
            TrainBatch::assemble(&[g.clone()], &tok(), AdvantageEstimator::Rloo, 0.0, 1, 10)
                .is_err()
        );
        assert!(
            TrainBatch::assemble(&[g.clone(), g], &tok(), AdvantageEstimator::Rloo, 0.0, 1, 16)
                .is_err()
        );
    }

    #[test]
    fn mask_only_on_generated_positions() {
        check("trainbatch-mask", 60, |rng| {
            let plen = rng.range_usize(1, 6);
            let glen = rng.range_usize(1, 6);
            let n = rng.range_usize(1, 4);
            let gens: Vec<(Vec<i32>, f32)> = (0..n)
                .map(|_| {
                    let mut g: Vec<i32> = (0..glen).map(|_| rng.range_i64(3, 26) as i32).collect();
                    if rng.bool(0.7) {
                        let pos = rng.range_usize(0, glen - 1);
                        g[pos] = EOS;
                    }
                    (g, if rng.bool(0.5) { 1.0 } else { 0.0 })
                })
                .collect();
            let prompt: String = (0..plen).map(|i| char::from(b'0' + (i % 10) as u8)).collect();
            let g = group(&prompt, gens);
            let rows = n + rng.range_usize(0, 3);
            let seq = plen + glen + rng.range_usize(0, 4);
            let b =
                TrainBatch::assemble(&[g], &tok(), AdvantageEstimator::Grpo, 0.0, rows, seq)
                    .unwrap();
            for r in 0..rows {
                for t in 0..seq {
                    let m = b.loss_mask[r * seq + t];
                    if r >= n || t < plen || t >= plen + glen {
                        prop_assert!(m == 0.0, "mask leaked at ({r},{t})");
                    }
                }
            }
            // every used row has at least one masked token
            for r in 0..n {
                let s: f32 = b.loss_mask[r * seq..(r + 1) * seq].iter().sum();
                prop_assert!(s >= 1.0, "row {r} has empty mask");
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_groups_are_not_reweighted() {
        // Two equal-size groups: the group-size weight is exactly 1.0, so
        // the batch matches a per-group assembly bit for bit (the fixed-
        // allocator equivalence rail at the train-batch layer).
        let g1 = group("1", vec![(vec![EOS], 1.0), (vec![EOS], 0.0)]);
        let g2 = group("2", vec![(vec![EOS], 0.0), (vec![EOS], 1.0)]);
        let b =
            TrainBatch::assemble(&[g1, g2], &tok(), AdvantageEstimator::Rloo, 0.0, 4, 4).unwrap();
        assert_eq!(b.advantages, vec![1.0, -1.0, -1.0, 1.0]);
    }

    #[test]
    fn variable_groups_get_equal_prompt_weight() {
        // Group sizes 6 and 2, mean 4: RLOO advantages scaled by 4/6 and
        // 4/2 so each prompt's total gradient weight is equal.
        let alternating: Vec<(Vec<i32>, f32)> =
            (0..6).map(|i| (vec![EOS], (i % 2) as f32)).collect();
        let g_big = group("1", alternating);
        let g_small = group("2", vec![(vec![EOS], 1.0), (vec![EOS], 0.0)]);
        let b = TrainBatch::assemble(
            &[g_big.clone(), g_small.clone()],
            &tok(),
            AdvantageEstimator::Rloo,
            0.0,
            8,
            4,
        )
        .unwrap();
        let raw_big = AdvantageEstimator::Rloo.advantages(&g_big.rewards(), 0.0);
        let raw_small = AdvantageEstimator::Rloo.advantages(&g_small.rewards(), 0.0);
        for (i, raw) in raw_big.iter().enumerate() {
            assert!((b.advantages[i] - raw * (4.0 / 6.0)).abs() < 1e-6, "row {i}");
        }
        for (i, raw) in raw_small.iter().enumerate() {
            assert!((b.advantages[6 + i] - raw * 2.0).abs() < 1e-6, "row {i}");
        }
        // Equal total weight per prompt: rows x weight is 6 x 2/3 = 2 x 2.
        assert!((6.0 * (4.0 / 6.0) - 2.0 * 2.0f64).abs() < 1e-12);
    }

    #[test]
    fn tensor_shapes() {
        let g = group("1", vec![(vec![EOS], 1.0)]);
        let b = TrainBatch::assemble(&[g], &tok(), AdvantageEstimator::Rloo, 0.0, 3, 5).unwrap();
        let (t, m, o, a) = b.tensors();
        assert_eq!(t.shape(), &[3, 5]);
        assert_eq!(m.shape(), &[3, 5]);
        assert_eq!(o.shape(), &[3, 5]);
        assert_eq!(a.shape(), &[3]);
    }
}
