//! RL algorithm configurations (paper §5.1 baselines).
//!
//! `BaseAlgo` fixes the advantage estimator, the PPO-style clip thresholds
//! the compiled `train_step` receives, and whether DAPO's *dynamic sampling*
//! group filter applies (discard groups with uniform rewards after full
//! inference — the post-hoc cousin of SPEED's pre-hoc screening).

use crate::rl::advantage::{pass_rate, AdvantageEstimator};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaseAlgo {
    Rloo,
    Dapo,
    Grpo,
    Reinforce,
    ReinforcePlusPlus,
}

impl BaseAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            BaseAlgo::Rloo => "rloo",
            BaseAlgo::Dapo => "dapo",
            BaseAlgo::Grpo => "grpo",
            BaseAlgo::Reinforce => "reinforce",
            BaseAlgo::ReinforcePlusPlus => "reinforce++",
        }
    }

    pub fn parse(s: &str) -> Option<BaseAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "rloo" => Some(BaseAlgo::Rloo),
            "dapo" => Some(BaseAlgo::Dapo),
            "grpo" => Some(BaseAlgo::Grpo),
            "reinforce" => Some(BaseAlgo::Reinforce),
            "reinforce++" | "reinforcepp" => Some(BaseAlgo::ReinforcePlusPlus),
            _ => None,
        }
    }
}

/// Full algorithm configuration passed to the trainer.
#[derive(Clone, Copy, Debug)]
pub struct AlgoConfig {
    pub base: BaseAlgo,
    /// PPO clip range; paper's DAPO setting: eps_low=0.2, eps_high=0.28
    /// ("clip-higher"). Non-clipping algorithms use a huge range so the
    /// compiled min(ratio*A, clip(ratio)*A) reduces to REINFORCE.
    pub clip_low: f32,
    pub clip_high: f32,
    pub lr: f64,
    pub weight_decay: f64,
    pub max_grad_norm: f64,
    /// Linear warmup steps for the lr schedule (paper: 10).
    pub warmup_steps: usize,
}

impl AlgoConfig {
    pub fn new(base: BaseAlgo) -> AlgoConfig {
        let (clip_low, clip_high) = match base {
            // Paper §5.1: eps_low = 0.2, eps_high = 0.28 for DAPO variants.
            BaseAlgo::Dapo | BaseAlgo::Grpo => (0.2, 0.28),
            // Effectively unclipped (single update per batch => ratio ~= 1).
            _ => (1e6, 1e6),
        };
        AlgoConfig {
            base,
            clip_low,
            clip_high,
            lr: 1e-6, // paper default; real-policy runs override via config
            weight_decay: 0.1,
            max_grad_norm: 1.0,
            warmup_steps: 10,
        }
    }

    pub fn estimator(&self) -> AdvantageEstimator {
        match self.base {
            BaseAlgo::Rloo => AdvantageEstimator::Rloo,
            // DAPO is built on GRPO-style group normalization.
            BaseAlgo::Dapo | BaseAlgo::Grpo => AdvantageEstimator::Grpo,
            BaseAlgo::Reinforce => AdvantageEstimator::Reinforce,
            BaseAlgo::ReinforcePlusPlus => AdvantageEstimator::ReinforcePlusPlus,
        }
    }

    /// DAPO dynamic sampling: after generating all N responses, drop groups
    /// whose rewards are uniform (pass rate 0 or 1) and resample. Vanilla
    /// RLOO/GRPO/REINFORCE train on everything.
    pub fn filters_uniform_groups(&self) -> bool {
        matches!(self.base, BaseAlgo::Dapo)
    }

    /// Keep this reward group for training?
    pub fn keep_group(&self, rewards: &[f32]) -> bool {
        if !self.filters_uniform_groups() {
            return true;
        }
        let p = pass_rate(rewards);
        p > 0.0 && p < 1.0
    }

    /// Learning rate at optimizer step `t` (linear warmup then constant —
    /// the paper's schedule).
    pub fn lr_at(&self, t: usize) -> f64 {
        if self.warmup_steps == 0 || t >= self.warmup_steps {
            self.lr
        } else {
            self.lr * (t + 1) as f64 / self.warmup_steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for b in [
            BaseAlgo::Rloo,
            BaseAlgo::Dapo,
            BaseAlgo::Grpo,
            BaseAlgo::Reinforce,
            BaseAlgo::ReinforcePlusPlus,
        ] {
            assert_eq!(BaseAlgo::parse(b.name()), Some(b));
        }
        assert_eq!(BaseAlgo::parse("bogus"), None);
    }

    #[test]
    fn dapo_filters_uniform_groups() {
        let dapo = AlgoConfig::new(BaseAlgo::Dapo);
        assert!(!dapo.keep_group(&[0.0, 0.0, 0.0]));
        assert!(!dapo.keep_group(&[1.0, 1.0]));
        assert!(dapo.keep_group(&[1.0, 0.0]));
        let rloo = AlgoConfig::new(BaseAlgo::Rloo);
        assert!(rloo.keep_group(&[0.0, 0.0, 0.0]));
    }

    #[test]
    fn paper_clip_settings() {
        let dapo = AlgoConfig::new(BaseAlgo::Dapo);
        assert_eq!((dapo.clip_low, dapo.clip_high), (0.2, 0.28));
        let rloo = AlgoConfig::new(BaseAlgo::Rloo);
        assert!(rloo.clip_low > 1e3); // unclipped
    }

    #[test]
    fn warmup_schedule() {
        let mut cfg = AlgoConfig::new(BaseAlgo::Rloo);
        cfg.lr = 1.0;
        cfg.warmup_steps = 10;
        assert!((cfg.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((cfg.lr_at(4) - 0.5).abs() < 1e-12);
        assert_eq!(cfg.lr_at(10), 1.0);
        assert_eq!(cfg.lr_at(500), 1.0);
    }
}
