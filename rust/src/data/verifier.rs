//! Binary reward verifier (paper eq. 2): exact integer-answer matching.
//!
//! The model's generation is a token row; a response is *correct* iff the
//! decoded text up to the first EOS, with surrounding spaces stripped,
//! parses as exactly the ground-truth integer. Missing EOS (truncated
//! ramble) is incorrect — the same convention DAPO's overlong filtering
//! penalizes.

use crate::data::tasks::TaskInstance;
use crate::data::tokenizer::{Tokenizer, EOS};

/// Verification outcome (kept richer than the 0/1 reward for diagnostics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VerifyOutcome {
    Correct,
    /// Parsed an integer but the wrong one.
    WrongAnswer,
    /// No EOS within the generation budget.
    Truncated,
    /// Decoded text is not an integer.
    Malformed,
}

impl VerifyOutcome {
    pub fn reward(&self) -> f32 {
        match self {
            VerifyOutcome::Correct => 1.0,
            _ => 0.0,
        }
    }

    pub fn is_correct(&self) -> bool {
        matches!(self, VerifyOutcome::Correct)
    }
}

/// Verify one generated row against the task's ground truth.
pub fn verify(tok: &Tokenizer, task: &TaskInstance, gen_tokens: &[i32]) -> VerifyOutcome {
    if !gen_tokens.contains(&EOS) {
        return VerifyOutcome::Truncated;
    }
    let text = tok.decode(gen_tokens);
    let trimmed = text.trim();
    match trimmed.parse::<i64>() {
        Ok(x) if x == task.answer => VerifyOutcome::Correct,
        Ok(_) => VerifyOutcome::WrongAnswer,
        Err(_) => VerifyOutcome::Malformed,
    }
}

/// Number of tokens that count toward the RL loss: everything up to and
/// including the first EOS (or the full row when truncated).
pub fn loss_token_count(gen_tokens: &[i32]) -> usize {
    match gen_tokens.iter().position(|&t| t == EOS) {
        Some(idx) => idx + 1,
        None => gen_tokens.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskFamily;

    fn task(answer: i64) -> TaskInstance {
        TaskInstance { family: TaskFamily::Add, level: 1, prompt: "1+1=".into(), answer }
    }

    fn toks(tok: &Tokenizer, s: &str, eos: bool) -> Vec<i32> {
        let mut ids = tok.encode(s).unwrap();
        if eos {
            ids.push(EOS);
        }
        ids
    }

    #[test]
    fn correct_answer() {
        let tok = Tokenizer::new();
        assert_eq!(verify(&tok, &task(42), &toks(&tok, "42", true)), VerifyOutcome::Correct);
    }

    #[test]
    fn negative_answer() {
        let tok = Tokenizer::new();
        assert_eq!(verify(&tok, &task(-7), &toks(&tok, "-7", true)), VerifyOutcome::Correct);
    }

    #[test]
    fn wrong_answer() {
        let tok = Tokenizer::new();
        assert_eq!(verify(&tok, &task(42), &toks(&tok, "41", true)), VerifyOutcome::WrongAnswer);
    }

    #[test]
    fn truncated_without_eos() {
        let tok = Tokenizer::new();
        assert_eq!(verify(&tok, &task(42), &toks(&tok, "42", false)), VerifyOutcome::Truncated);
    }

    #[test]
    fn malformed_text() {
        let tok = Tokenizer::new();
        assert_eq!(verify(&tok, &task(42), &toks(&tok, "4+2", true)), VerifyOutcome::Malformed);
        assert_eq!(verify(&tok, &task(42), &toks(&tok, "", true)), VerifyOutcome::Malformed);
    }

    #[test]
    fn spaces_are_tolerated() {
        let tok = Tokenizer::new();
        assert_eq!(verify(&tok, &task(5), &toks(&tok, " 5 ", true)), VerifyOutcome::Correct);
    }

    #[test]
    fn trailing_tokens_after_eos_ignored() {
        let tok = Tokenizer::new();
        let mut ids = toks(&tok, "42", true);
        ids.extend(toks(&tok, "999", false));
        assert_eq!(verify(&tok, &task(42), &ids), VerifyOutcome::Correct);
    }

    #[test]
    fn loss_token_counting() {
        let tok = Tokenizer::new();
        let ids = toks(&tok, "42", true); // 2 digits + EOS
        assert_eq!(loss_token_count(&ids), 3);
        let no_eos = toks(&tok, "4242", false);
        assert_eq!(loss_token_count(&no_eos), 4);
    }
}
