//! Synthetic math-task families with graded difficulty.
//!
//! These are the substitute for the paper's math corpora (DESIGN.md §3):
//! seven families of integer-answer problems whose difficulty is a
//! generator parameter, expressed entirely in the 24-char model vocabulary.
//! Family + level shape the pass-rate spectrum the curriculum operates on —
//! the analogue of GSM8k-vs-AIME spread inside NuminaMath.
//!
//! Prompt grammar (all verifiable by exact integer match):
//!   Add      "37+85="            Sub      "92-187="
//!   Mul      "12*34="            Mod      "977%8="
//!   Chain    "3+41-7+2="         Count    "#7(17477)="  (how many '7's)
//!   Compare  ">(12,7,45)="  max  /  "<(12,7,45)="  min

use crate::util::rng::Rng;

/// Difficulty level, 1 (trivial) ..= 10 (competition tail).
pub type Difficulty = u8;

pub const MAX_LEVEL: Difficulty = 10;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    Add,
    Sub,
    Mul,
    Mod,
    Chain,
    Count,
    Compare,
}

pub const ALL_FAMILIES: [TaskFamily; 7] = [
    TaskFamily::Add,
    TaskFamily::Sub,
    TaskFamily::Mul,
    TaskFamily::Mod,
    TaskFamily::Chain,
    TaskFamily::Count,
    TaskFamily::Compare,
];

impl TaskFamily {
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::Add => "add",
            TaskFamily::Sub => "sub",
            TaskFamily::Mul => "mul",
            TaskFamily::Mod => "mod",
            TaskFamily::Chain => "chain",
            TaskFamily::Count => "count",
            TaskFamily::Compare => "compare",
        }
    }

    /// Inverse of [`index`](Self::index), for checkpoint deserialization.
    pub fn from_index(i: usize) -> Option<TaskFamily> {
        ALL_FAMILIES.get(i).copied()
    }

    /// Stable position in [`ALL_FAMILIES`] (the one-hot feature index).
    pub fn index(&self) -> usize {
        match self {
            TaskFamily::Add => 0,
            TaskFamily::Sub => 1,
            TaskFamily::Mul => 2,
            TaskFamily::Mod => 3,
            TaskFamily::Chain => 4,
            TaskFamily::Count => 5,
            TaskFamily::Compare => 6,
        }
    }
}

/// Length of [`TaskInstance::features`]: bias + family one-hot + level +
/// level² + prompt length.
pub const N_TASK_FEATURES: usize = 1 + ALL_FAMILIES.len() + 2 + 1;

/// One training/eval prompt with its verified ground truth.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskInstance {
    pub family: TaskFamily,
    pub level: Difficulty,
    pub prompt: String,
    pub answer: i64,
}

impl TaskInstance {
    pub fn answer_text(&self) -> String {
        self.answer.to_string()
    }

    /// Stable prompt identity: an FNV-1a hash of family, level, and prompt
    /// text. The same instance re-drawn in a later epoch (or by another
    /// rollout worker) maps to the same key, which is what lets the
    /// difficulty predictor accumulate evidence per prompt across a run.
    pub fn identity(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        };
        eat(self.family.index() as u8);
        eat(self.level);
        for b in self.prompt.bytes() {
            eat(b);
        }
        h
    }

    /// Feature vector for the difficulty predictor's generalizing model:
    /// bias, family one-hot, normalized level, level², prompt length. All
    /// components are in `[0, 1]` so online logistic updates stay tame.
    pub fn features(&self) -> [f64; N_TASK_FEATURES] {
        let mut x = [0.0f64; N_TASK_FEATURES];
        x[0] = 1.0;
        x[1 + self.family.index()] = 1.0;
        let level = self.level as f64 / MAX_LEVEL as f64;
        x[1 + ALL_FAMILIES.len()] = level;
        x[1 + ALL_FAMILIES.len() + 1] = level * level;
        x[1 + ALL_FAMILIES.len() + 2] = (self.prompt.len() as f64 / 24.0).min(1.0);
        x
    }
}

fn rand_with_digits(rng: &mut Rng, digits: u32) -> i64 {
    debug_assert!((1..=9).contains(&digits));
    if digits == 1 {
        rng.range_i64(0, 9)
    } else {
        let lo = 10i64.pow(digits - 1);
        rng.range_i64(lo, lo * 10 - 1)
    }
}

/// Generate one instance of `family` at `level` (deterministic in `rng`).
///
/// `max_prompt_chars` bounds the prompt so it always fits the compiled
/// prompt width; generators degrade their parameters rather than overflow.
pub fn generate(
    rng: &mut Rng,
    family: TaskFamily,
    level: Difficulty,
    max_prompt_chars: usize,
) -> TaskInstance {
    let level = level.clamp(1, MAX_LEVEL);
    let (prompt, answer) = match family {
        TaskFamily::Add => {
            // level -> operand digits 1..=6
            let d = ((level as u32 + 1) / 2).clamp(1, 6);
            let a = rand_with_digits(rng, d);
            let b = rand_with_digits(rng, d);
            (format!("{a}+{b}="), a + b)
        }
        TaskFamily::Sub => {
            let d = ((level as u32 + 1) / 2).clamp(1, 6);
            let a = rand_with_digits(rng, d);
            let b = rand_with_digits(rng, d);
            (format!("{a}-{b}="), a - b)
        }
        TaskFamily::Mul => {
            // second operand grows slower: multiplication is much harder.
            let d1 = ((level as u32 + 1) / 2).clamp(1, 4);
            let d2 = (level as u32 / 3).clamp(1, 3);
            let a = rand_with_digits(rng, d1);
            let b = rand_with_digits(rng, d2);
            (format!("{a}*{b}="), a * b)
        }
        TaskFamily::Mod => {
            let d = ((level as u32 + 2) / 2).clamp(1, 6);
            let a = rand_with_digits(rng, d);
            let m = rng.range_i64(2, 9 + 2 * level as i64);
            (format!("{a}%{m}="), a % m)
        }
        TaskFamily::Chain => {
            // level -> number of ops 1..=5, operand digits 1..=2
            let ops = (1 + level as usize / 2).clamp(1, 5);
            let d = if level > 5 { 2 } else { 1 };
            let mut acc = rand_with_digits(rng, d);
            let mut s = acc.to_string();
            for _ in 0..ops {
                let x = rand_with_digits(rng, d);
                if rng.bool(0.5) {
                    acc += x;
                    s.push('+');
                } else {
                    acc -= x;
                    s.push('-');
                }
                s.push_str(&x.to_string());
            }
            s.push('=');
            (s, acc)
        }
        TaskFamily::Count => {
            // count occurrences of a digit in a digit string
            let len = (2 + 2 * level as usize).min(max_prompt_chars.saturating_sub(6)).max(2);
            let target = rng.range_i64(0, 9);
            let mut s = String::with_capacity(len);
            let mut count = 0i64;
            for _ in 0..len {
                // Bias towards the target digit so counts are non-trivial.
                let c = if rng.bool(0.3) { target } else { rng.range_i64(0, 9) };
                if c == target {
                    count += 1;
                }
                s.push(char::from(b'0' + c as u8));
            }
            (format!("#{target}({s})="), count)
        }
        TaskFamily::Compare => {
            let k = (2 + level as usize / 2).clamp(2, 6);
            let d = if level > 4 { 3 } else { 2 };
            let xs: Vec<i64> = (0..k).map(|_| rand_with_digits(rng, d)).collect();
            let maxop = rng.bool(0.5);
            let op = if maxop { '>' } else { '<' };
            let ans = if maxop {
                *xs.iter().max().unwrap()
            } else {
                *xs.iter().min().unwrap()
            };
            let list = xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
            (format!("{op}({list})="), ans)
        }
    };
    if prompt.len() > max_prompt_chars {
        // Degrade gracefully: retry at a lower level (terminates at level 1,
        // whose prompts are always short).
        return generate(rng, family, level - 1, max_prompt_chars);
    }
    TaskInstance { family, level, prompt, answer }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;
    use crate::util::proptest::check;
    use crate::{prop_assert, prop_assert_eq};

    fn eval_prompt(p: &str) -> Option<i64> {
        // Independent oracle: parse and evaluate the prompt grammar.
        let body = p.strip_suffix('=')?;
        if let Some(rest) = body.strip_prefix('#') {
            let target = rest.chars().next()?;
            let inner = rest[1..].strip_prefix('(')?.strip_suffix(')')?;
            return Some(inner.chars().filter(|&c| c == target).count() as i64);
        }
        if let Some(rest) = body.strip_prefix('>').or_else(|| body.strip_prefix('<')) {
            let inner = rest.strip_prefix('(')?.strip_suffix(')')?;
            let xs: Vec<i64> = inner.split(',').map(|x| x.parse().unwrap()).collect();
            return if body.starts_with('>') {
                xs.into_iter().max()
            } else {
                xs.into_iter().min()
            };
        }
        // arithmetic: a op b op c ... with + - * %
        let mut nums = Vec::new();
        let mut ops = Vec::new();
        let mut cur = String::new();
        for (i, c) in body.chars().enumerate() {
            if c.is_ascii_digit() || (c == '-' && i == 0) {
                cur.push(c);
            } else {
                nums.push(cur.parse::<i64>().ok()?);
                cur.clear();
                ops.push(c);
            }
        }
        nums.push(cur.parse::<i64>().ok()?);
        // single * or % never mixes with + - in our grammar
        let mut acc = nums[0];
        for (op, x) in ops.iter().zip(&nums[1..]) {
            acc = match op {
                '+' => acc + x,
                '-' => acc - x,
                '*' => acc * x,
                '%' => acc % x,
                _ => return None,
            };
        }
        Some(acc)
    }

    #[test]
    fn generated_answers_match_independent_oracle() {
        check("task-answers", 300, |rng| {
            let fam = ALL_FAMILIES[rng.range_usize(0, 6)];
            let level = rng.range_i64(1, 10) as u8;
            let t = generate(rng, fam, level, 24);
            let oracle = eval_prompt(&t.prompt);
            prop_assert!(oracle.is_some(), "unparseable prompt '{}'", t.prompt);
            prop_assert_eq!(oracle.unwrap(), t.answer);
            Ok(())
        });
    }

    #[test]
    fn prompts_fit_width_and_vocab() {
        let tok = Tokenizer::new();
        check("task-prompt-fits", 300, |rng| {
            let fam = ALL_FAMILIES[rng.range_usize(0, 6)];
            let level = rng.range_i64(1, 10) as u8;
            let t = generate(rng, fam, level, 24);
            prop_assert!(t.prompt.len() <= 24, "prompt too long: '{}'", t.prompt);
            prop_assert!(tok.encode(&t.prompt).is_ok(), "OOV char in '{}'", t.prompt);
            // answers must fit a small generation budget too
            prop_assert!(t.answer_text().len() <= 10, "answer too long");
            Ok(())
        });
    }

    #[test]
    fn difficulty_increases_operand_size() {
        let mut rng = Rng::new(0);
        let easy: Vec<_> = (0..200)
            .map(|_| generate(&mut rng, TaskFamily::Add, 1, 24).prompt.len())
            .collect();
        let hard: Vec<_> = (0..200)
            .map(|_| generate(&mut rng, TaskFamily::Add, 9, 24).prompt.len())
            .collect();
        let easy_mean: f64 = easy.iter().sum::<usize>() as f64 / 200.0;
        let hard_mean: f64 = hard.iter().sum::<usize>() as f64 / 200.0;
        assert!(hard_mean > easy_mean + 3.0, "easy {easy_mean}, hard {hard_mean}");
    }

    #[test]
    fn deterministic_generation() {
        let a = generate(&mut Rng::new(7), TaskFamily::Chain, 5, 24);
        let b = generate(&mut Rng::new(7), TaskFamily::Chain, 5, 24);
        assert_eq!(a, b);
    }

    #[test]
    fn identity_stable_and_collision_free_in_practice() {
        // Equal instances hash equal; distinct prompts hash distinct over a
        // realistic sample.
        let a = generate(&mut Rng::new(7), TaskFamily::Chain, 5, 24);
        let b = generate(&mut Rng::new(7), TaskFamily::Chain, 5, 24);
        assert_eq!(a.identity(), b.identity());
        let mut seen = std::collections::HashSet::new();
        let mut rng = Rng::new(8);
        for i in 0..2000 {
            let t = generate(&mut rng, ALL_FAMILIES[i % 7], (i % 10 + 1) as u8, 24);
            seen.insert(t.identity());
        }
        assert!(seen.len() > 1900, "identity collisions: {} unique of 2000", seen.len());
    }

    #[test]
    fn features_are_bounded_and_family_one_hot() {
        check("task-features", 100, |rng| {
            let fam = ALL_FAMILIES[rng.range_usize(0, 6)];
            let level = rng.range_i64(1, 10) as u8;
            let t = generate(rng, fam, level, 24);
            let x = t.features();
            prop_assert!(x.iter().all(|v| (0.0..=1.0).contains(v)), "feature out of range");
            prop_assert!(x[0] == 1.0, "bias");
            let hot: f64 = x[1..8].iter().sum();
            prop_assert!(hot == 1.0 && x[1 + t.family.index()] == 1.0, "one-hot");
            Ok(())
        });
    }
}
