//! Streaming prompt loader: epoch-shuffled, deterministic, infinite.
//!
//! Algorithm 2 line 5 "fetch a batch of prompts from the data loader" —
//! SPEED consumes prompts faster than vanilla RL (screening rejects some),
//! so the loader transparently reshuffles and starts a new epoch when
//! exhausted.

use std::sync::{Arc, Mutex};

use crate::data::dataset::Dataset;
use crate::data::tasks::TaskInstance;
use crate::util::rng::Rng;
use crate::util::sync::plock;

/// Where a curriculum pulls prompts from. Abstracts over the serial case
/// (exclusive loader borrow) and the pipelined case (loader behind a mutex,
/// shared by K rollout workers).
pub trait PromptSource: Send {
    /// Next (dataset index, task) pair.
    fn next_prompt(&mut self) -> (usize, TaskInstance);

    /// Prompts consumed so far (the paper's data-efficiency axis).
    fn consumed(&self) -> usize;
}

/// Serial prompt source: exclusive access to the loader and dataset.
pub struct DatasetSource<'a> {
    pub loader: &'a mut Loader,
    pub dataset: &'a Dataset,
}

impl PromptSource for DatasetSource<'_> {
    fn next_prompt(&mut self) -> (usize, TaskInstance) {
        let idx = self.loader.next_index();
        (idx, self.dataset.instances[idx].clone())
    }

    fn consumed(&self) -> usize {
        self.loader.consumed()
    }
}

/// Shared prompt source for the pipelined coordinator: K workers draw from
/// one loader, so the global prompt order is a single stream (each prompt
/// is handed out exactly once per epoch, never duplicated across workers).
#[derive(Clone)]
pub struct SharedSource {
    pub loader: Arc<Mutex<Loader>>,
    pub dataset: Arc<Dataset>,
}

impl PromptSource for SharedSource {
    fn next_prompt(&mut self) -> (usize, TaskInstance) {
        let idx = plock(&self.loader).next_index();
        (idx, self.dataset.instances[idx].clone())
    }

    fn consumed(&self) -> usize {
        plock(&self.loader).consumed()
    }
}

pub struct Loader {
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Rng,
}

/// Everything a warm-resume checkpoint needs to rebuild a [`Loader`]
/// mid-epoch: the current shuffle, the cursor into it, and the shuffler's
/// RNG state (so the *next* epoch's shuffle also matches an uninterrupted
/// run).
#[derive(Clone, Debug)]
pub struct LoaderState {
    pub order: Vec<usize>,
    pub cursor: usize,
    pub epoch: usize,
    pub rng: [u64; 4],
}

impl Loader {
    pub fn new(dataset_len: usize, seed: u64) -> Loader {
        assert!(dataset_len > 0, "empty dataset");
        let mut rng = Rng::new(seed ^ 0x10ad_10ad);
        let mut order: Vec<usize> = (0..dataset_len).collect();
        rng.shuffle(&mut order);
        Loader { order, cursor: 0, epoch: 0, rng }
    }

    /// Next instance index (reshuffles on epoch end).
    pub fn next_index(&mut self) -> usize {
        if self.cursor >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let idx = self.order[self.cursor];
        self.cursor += 1;
        idx
    }

    /// Fetch `n` indices.
    pub fn next_batch(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.next_index()).collect()
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Prompts consumed so far (the paper's "data efficiency" axis: SPEED
    /// consumes more prompts per step but trains on fewer).
    pub fn consumed(&self) -> usize {
        self.epoch * self.order.len() + self.cursor
    }

    /// Snapshot for a warm-resume checkpoint.
    pub fn state(&self) -> LoaderState {
        LoaderState {
            order: self.order.clone(),
            cursor: self.cursor,
            epoch: self.epoch,
            rng: self.rng.state(),
        }
    }

    /// Rebuild a loader from a [`state`](Self::state) snapshot. The order
    /// must be a permutation of the same dataset the run is resuming on;
    /// the caller (the checkpoint loader) verifies the dataset fingerprint
    /// before calling this.
    pub fn from_state(state: &LoaderState) -> Loader {
        Loader {
            order: state.order.clone(),
            cursor: state.cursor.min(state.order.len()),
            epoch: state.epoch,
            rng: Rng::from_state(state.rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn covers_all_indices_each_epoch() {
        let mut loader = Loader::new(10, 3);
        let first: HashSet<usize> = loader.next_batch(10).into_iter().collect();
        assert_eq!(first.len(), 10);
        let second: HashSet<usize> = loader.next_batch(10).into_iter().collect();
        assert_eq!(second.len(), 10);
        assert_eq!(loader.epoch(), 1);
    }

    #[test]
    fn deterministic() {
        let mut a = Loader::new(50, 9);
        let mut b = Loader::new(50, 9);
        assert_eq!(a.next_batch(75), b.next_batch(75));
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Loader::new(40, 13);
        a.next_batch(55); // mid-second-epoch
        let mut b = Loader::from_state(&a.state());
        assert_eq!(b.consumed(), a.consumed());
        // identical draws across the next epoch boundary too
        assert_eq!(a.next_batch(60), b.next_batch(60));
        assert_eq!(a.epoch(), b.epoch());
    }

    #[test]
    fn epochs_reshuffle() {
        let mut loader = Loader::new(32, 1);
        let e0 = loader.next_batch(32);
        let e1 = loader.next_batch(32);
        assert_ne!(e0, e1); // astronomically unlikely to be equal
        assert_eq!(loader.consumed(), 64);
    }
}
