//! Data substrate: tokenizer, synthetic math-task families, dataset
//! mixtures (the NuminaMath / DAPO-17k / DeepScaleR analogues), verifier,
//! and the streaming loader. See DESIGN.md §3 for the substitution argument.

pub mod dataset;
pub mod loader;
pub mod tasks;
pub mod tokenizer;
pub mod verifier;

pub use dataset::{Dataset, DatasetKind, EvalBenchmark};
pub use loader::{DatasetSource, Loader, PromptSource, SharedSource};
pub use tasks::{Difficulty, TaskFamily, TaskInstance};
pub use tokenizer::Tokenizer;
pub use verifier::{verify, VerifyOutcome};
