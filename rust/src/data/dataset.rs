//! Dataset mixtures — the synthetic analogues of the paper's training
//! corpora and evaluation benchmarks (DESIGN.md §3).
//!
//! Each training mixture is defined by a difficulty profile (weights over
//! generator levels 1..=10) and a family mix; each evaluation benchmark is a
//! held-out set at a difficulty band, sized like the paper's
//! (DAPO-1k=1000, MATH500=500, AMC2023=40, AIME=30).

use crate::data::tasks::{self, TaskFamily, TaskInstance, ALL_FAMILIES, MAX_LEVEL};
use crate::util::rng::Rng;

/// Training mixtures (paper: NuminaMath / DAPO-17k / DeepScaleR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 220k-scale, GSM8k-to-competition spread (easy-skewed).
    SynthNumina,
    /// 16k-scale, medium-hard with a large unsolvable-for-base-model mass.
    SynthDapo17k,
    /// 40k-scale, competition-heavy (AIME/AMC-derived in the paper).
    SynthDeepScale,
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::SynthNumina => "synth-numina",
            DatasetKind::SynthDapo17k => "synth-dapo17k",
            DatasetKind::SynthDeepScale => "synth-deepscale",
        }
    }

    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s {
            "synth-numina" | "numina" => Some(DatasetKind::SynthNumina),
            "synth-dapo17k" | "dapo17k" => Some(DatasetKind::SynthDapo17k),
            "synth-deepscale" | "deepscale" => Some(DatasetKind::SynthDeepScale),
            _ => None,
        }
    }

    /// Default training-set size (scaled-down analogue of the paper's).
    pub fn default_size(&self) -> usize {
        match self {
            DatasetKind::SynthNumina => 220_000,
            DatasetKind::SynthDapo17k => 16_000,
            DatasetKind::SynthDeepScale => 40_000,
        }
    }

    /// Difficulty profile: unnormalized weights for levels 1..=10.
    pub fn level_weights(&self) -> [f64; 10] {
        match self {
            DatasetKind::SynthNumina => [10.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0],
            DatasetKind::SynthDapo17k => [1.0, 2.0, 3.0, 5.0, 7.0, 8.0, 8.0, 7.0, 6.0, 5.0],
            DatasetKind::SynthDeepScale => [0.0, 1.0, 1.0, 2.0, 4.0, 6.0, 8.0, 9.0, 9.0, 8.0],
        }
    }
}

/// Evaluation benchmarks (paper: DAPO-1k / MATH500 / AMC2023 / AIME).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalBenchmark {
    Dapo1k,
    Math500,
    Amc2023,
    Aime,
}

pub const ALL_BENCHMARKS: [EvalBenchmark; 4] = [
    EvalBenchmark::Dapo1k,
    EvalBenchmark::Math500,
    EvalBenchmark::Amc2023,
    EvalBenchmark::Aime,
];

impl EvalBenchmark {
    pub fn name(&self) -> &'static str {
        match self {
            EvalBenchmark::Dapo1k => "dapo1k",
            EvalBenchmark::Math500 => "math500",
            EvalBenchmark::Amc2023 => "amc2023",
            EvalBenchmark::Aime => "aime",
        }
    }

    pub fn parse(s: &str) -> Option<EvalBenchmark> {
        match s {
            "dapo1k" => Some(EvalBenchmark::Dapo1k),
            "math500" => Some(EvalBenchmark::Math500),
            "amc2023" => Some(EvalBenchmark::Amc2023),
            "aime" => Some(EvalBenchmark::Aime),
            _ => None,
        }
    }

    pub fn size(&self) -> usize {
        match self {
            EvalBenchmark::Dapo1k => 1000,
            EvalBenchmark::Math500 => 500,
            EvalBenchmark::Amc2023 => 40,
            EvalBenchmark::Aime => 30,
        }
    }

    /// Difficulty band (inclusive level range).
    pub fn level_band(&self) -> (u8, u8) {
        match self {
            EvalBenchmark::Dapo1k => (3, 10), // held-out slice of dapo17k
            EvalBenchmark::Math500 => (2, 6),
            EvalBenchmark::Amc2023 => (5, 8),
            EvalBenchmark::Aime => (7, 10),
        }
    }
}

/// A materialized set of task instances.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub instances: Vec<TaskInstance>,
}

fn sample_level(rng: &mut Rng, weights: &[f64; 10]) -> u8 {
    let total: f64 = weights.iter().sum();
    let mut x = rng.f64() * total;
    for (i, w) in weights.iter().enumerate() {
        x -= w;
        if x <= 0.0 {
            return (i + 1) as u8;
        }
    }
    MAX_LEVEL
}

fn sample_family(rng: &mut Rng) -> TaskFamily {
    ALL_FAMILIES[rng.range_usize(0, ALL_FAMILIES.len() - 1)]
}

impl Dataset {
    /// Generate a training mixture. Deterministic in `seed`.
    pub fn training(kind: DatasetKind, size: usize, seed: u64, max_prompt_chars: usize) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x5a5a_0000);
        let weights = kind.level_weights();
        let instances = (0..size)
            .map(|_| {
                let fam = sample_family(&mut rng);
                let lvl = sample_level(&mut rng, &weights);
                tasks::generate(&mut rng, fam, lvl, max_prompt_chars)
            })
            .collect();
        Dataset { name: kind.name().to_string(), instances }
    }

    /// Generate an evaluation benchmark. Seeds are offset from the training
    /// stream so benchmarks are held out.
    pub fn benchmark(bench: EvalBenchmark, seed: u64, max_prompt_chars: usize) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xeeee_1111 ^ (bench.size() as u64) << 17);
        let (lo, hi) = bench.level_band();
        let instances = (0..bench.size())
            .map(|_| {
                let fam = sample_family(&mut rng);
                let lvl = rng.range_i64(lo as i64, hi as i64) as u8;
                tasks::generate(&mut rng, fam, lvl, max_prompt_chars)
            })
            .collect();
        Dataset { name: bench.name().to_string(), instances }
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Mean difficulty level (diagnostics / DESIGN.md calibration table).
    pub fn mean_level(&self) -> f64 {
        if self.instances.is_empty() {
            return 0.0;
        }
        self.instances.iter().map(|t| t.level as f64).sum::<f64>() / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_sets_deterministic_and_sized() {
        let a = Dataset::training(DatasetKind::SynthDapo17k, 500, 42, 24);
        let b = Dataset::training(DatasetKind::SynthDapo17k, 500, 42, 24);
        assert_eq!(a.instances, b.instances);
        assert_eq!(a.len(), 500);
    }

    #[test]
    fn difficulty_profiles_ordered() {
        let numina = Dataset::training(DatasetKind::SynthNumina, 4000, 1, 24).mean_level();
        let dapo = Dataset::training(DatasetKind::SynthDapo17k, 4000, 1, 24).mean_level();
        let deep = Dataset::training(DatasetKind::SynthDeepScale, 4000, 1, 24).mean_level();
        assert!(numina < dapo && dapo < deep, "{numina} {dapo} {deep}");
    }

    #[test]
    fn benchmarks_sized_like_paper() {
        for b in ALL_BENCHMARKS {
            let d = Dataset::benchmark(b, 0, 24);
            assert_eq!(d.len(), b.size());
            let (lo, hi) = b.level_band();
            assert!(d.instances.iter().all(|t| (lo..=hi).contains(&t.level) || t.level < lo),
                "levels out of band for {}", b.name());
        }
    }

    #[test]
    fn benchmark_bands_ordered_by_difficulty() {
        let m = Dataset::benchmark(EvalBenchmark::Math500, 0, 24).mean_level();
        let a = Dataset::benchmark(EvalBenchmark::Amc2023, 0, 24).mean_level();
        let i = Dataset::benchmark(EvalBenchmark::Aime, 0, 24).mean_level();
        assert!(m < a && a < i, "{m} {a} {i}");
    }

    #[test]
    fn seeds_change_content() {
        let a = Dataset::training(DatasetKind::SynthNumina, 100, 1, 24);
        let b = Dataset::training(DatasetKind::SynthNumina, 100, 2, 24);
        assert_ne!(a.instances, b.instances);
    }
}
