//! Char-level tokenizer. The vocabulary is the *compile-time contract* with
//! `python/compile/model.py` (`VOCAB`): ids are baked into the AOT
//! artifacts, so this table must match exactly — the runtime cross-checks
//! it against `manifest.json` at startup.

use anyhow::{bail, Result};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

/// Printable characters at ids 3..=26 (same order as python).
pub const CHARS: &str = "0123456789+-*/%=()<>, #?";

/// Vocabulary padded to 32 for MXU lane alignment (ids 27..31 unused).
pub const VOCAB_SIZE: usize = 32;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// char -> id for the printable range.
    map: [i32; 128],
    /// id -> char.
    chars: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let mut map = [-1i32; 128];
        let chars: Vec<char> = CHARS.chars().collect();
        for (i, c) in chars.iter().enumerate() {
            map[*c as usize] = (i + 3) as i32;
        }
        Tokenizer { map, chars }
    }

    /// Cross-check against the manifest's vocab list (defense against a
    /// stale artifact directory).
    pub fn validate_against(&self, vocab: &[String]) -> Result<()> {
        if vocab.len() < 3 + self.chars.len() {
            bail!("manifest vocab too short: {}", vocab.len());
        }
        for (i, expect) in ["<pad>", "<bos>", "<eos>"].iter().enumerate() {
            if vocab[i] != *expect {
                bail!("vocab[{i}] is '{}', expected '{expect}'", vocab[i]);
            }
        }
        for (i, c) in self.chars.iter().enumerate() {
            let got = &vocab[i + 3];
            if got.chars().next() != Some(*c) || got.len() != c.len_utf8() {
                bail!("vocab[{}] is '{}', expected '{}'", i + 3, got, c);
            }
        }
        Ok(())
    }

    /// Encode a prompt string (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                let id = if (c as usize) < 128 { self.map[c as usize] } else { -1 };
                if id < 0 {
                    bail!("character '{c}' not in vocabulary");
                }
                Ok(id)
            })
            .collect()
    }

    /// Decode ids to a string; PAD/BOS are dropped, EOS stops decoding,
    /// out-of-range ids render as '?'.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            match id {
                PAD | BOS => continue,
                EOS => break,
                i if (3..3 + self.chars.len() as i32).contains(&i) => {
                    s.push(self.chars[(i - 3) as usize]);
                }
                _ => s.push('?'),
            }
        }
        s
    }

    /// Encode into a fixed-width row: returns (tokens, len). Errors if the
    /// prompt does not fit.
    pub fn encode_padded(&self, text: &str, width: usize) -> Result<(Vec<i32>, usize)> {
        let ids = self.encode(text)?;
        if ids.len() > width {
            bail!("prompt '{text}' ({} tokens) exceeds width {width}", ids.len());
        }
        let len = ids.len();
        let mut row = vec![PAD; width];
        row[..len].copy_from_slice(&ids);
        Ok((row, len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tok = Tokenizer::new();
        let s = "37+85=142";
        let ids = tok.encode(s).unwrap();
        assert_eq!(tok.decode(&ids), s);
    }

    #[test]
    fn special_chars_covered() {
        let tok = Tokenizer::new();
        for c in CHARS.chars() {
            assert!(tok.encode(&c.to_string()).is_ok(), "char {c}");
        }
    }

    #[test]
    fn rejects_oov() {
        let tok = Tokenizer::new();
        assert!(tok.encode("abc").is_err());
        assert!(tok.encode("x=1").is_err());
    }

    #[test]
    fn eos_stops_decode() {
        let tok = Tokenizer::new();
        let mut ids = tok.encode("12").unwrap();
        ids.push(EOS);
        ids.extend(tok.encode("99").unwrap());
        assert_eq!(tok.decode(&ids), "12");
    }

    #[test]
    fn padded_encode() {
        let tok = Tokenizer::new();
        let (row, len) = tok.encode_padded("7+8=", 10).unwrap();
        assert_eq!(len, 4);
        assert_eq!(row.len(), 10);
        assert_eq!(&row[4..], &[PAD; 6]);
        assert!(tok.encode_padded("123456789012", 5).is_err());
    }

    #[test]
    fn ids_match_python_vocab_layout() {
        let tok = Tokenizer::new();
        // '0' is id 3, '9' is 12, '+' 13, '=' 18 — mirrors model.py VOCAB.
        assert_eq!(tok.encode("0").unwrap(), vec![3]);
        assert_eq!(tok.encode("9").unwrap(), vec![12]);
        assert_eq!(tok.encode("+").unwrap(), vec![13]);
        assert_eq!(tok.encode("=").unwrap(), vec![18]);
    }
}
