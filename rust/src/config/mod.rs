//! Typed run configuration + JSON presets (`configs/*.json`).
//!
//! A `RunConfig` fully determines one training run: substrate (sim/real),
//! model scale, dataset, curriculum, base RL algorithm, SPEED split
//! (N_init/N_cont), batch sizes and stop conditions. Paper setups are
//! available as named presets (see [`RunConfig::paper_preset`]).

use anyhow::{bail, Context, Result};

use crate::coordinator::alloc::AllocKind;
use crate::coordinator::curriculum::CurriculumKind;
use crate::data::dataset::DatasetKind;
use crate::policy::service::{BatchingMode, ServiceConfig};
use crate::rl::algo::BaseAlgo;
use crate::util::json::Json;

/// Which policy substrate executes the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Substrate {
    /// IRT simulator at paper scale (default for benches).
    Sim,
    /// AOT transformer through PJRT (the E2E examples).
    Real,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub label: String,
    pub substrate: Substrate,
    /// "sim-1.5b" / "sim-7b" for Sim; artifacts dir preset for Real.
    pub model: String,
    pub dataset: DatasetKind,
    pub dataset_size: usize,
    pub curriculum: CurriculumKind,
    pub algo: BaseAlgo,
    /// SPEED split. Non-SPEED curricula use n_init + n_cont rollouts; with
    /// adaptive allocation `n_cont` is the *reference* budget (it sets the
    /// per-step rollout target `batch_size * (n_init + n_cont)`).
    pub n_init: usize,
    pub n_cont: usize,
    /// Continuation-budget allocator: `fixed` spends exactly `n_cont` per
    /// qualified prompt (the paper's Algorithm 2, bit-for-bit the
    /// pre-refactor behaviour); `adaptive` sizes each prompt's budget from
    /// its posterior reward variance within `[n_cont_min, n_cont_max]`.
    pub alloc: AllocKind,
    /// Adaptive-allocation floor (0 = auto: `max(1, n_cont / 2)`).
    pub n_cont_min: usize,
    /// Adaptive-allocation ceiling (0 = auto: `2 * n_cont`).
    pub n_cont_max: usize,
    /// Screening thresholds (paper default 0/1 strict).
    pub p_low: f64,
    pub p_high: f64,
    pub batch_size: usize,
    pub temperature: f32,
    pub lr: f64,
    pub eval_every: usize,
    pub max_steps: usize,
    pub max_seconds: f64,
    pub seed: u64,
    /// VarianceMax pool factor.
    pub pool_factor: usize,
    /// Rollout workers K for the pipelined coordinator.
    pub workers: usize,
    /// Overlap inference with updates (sim substrate only; off = the
    /// serial reference trainer).
    pub pipeline: bool,
    /// Sampling-buffer capacity in groups. 0 = auto: unbounded for the
    /// serial SPEED buffer (the reference semantics), `4 * batch_size` for
    /// the pipelined shared buffer (backpressure bounds staleness).
    pub buffer_cap: usize,
    /// predictive-speed: skip screening when the predicted rejection
    /// probability reaches this threshold (1.0 = never skip, reproducing
    /// the plain `speed` batch stream exactly).
    pub skip_confidence: f64,
    /// predictive-speed: per-rollout discount of the difficulty posterior
    /// (effective sample size `1/(1-discount)`).
    pub predictor_discount: f64,
    /// predictive-speed: probability of screening a confidently-skipped
    /// prompt anyway (keeps skip decisions falsifiable).
    pub explore_rate: f64,
    /// Route inference through the shared coalescing service (one engine
    /// behind a submission queue; DESIGN.md §8). With `pipeline` on, all K
    /// workers submit to it; with `pipeline` off, the serial loop delegates
    /// through it with one producer (the bit-for-bit equivalence rail).
    pub service: bool,
    /// Service dispatch discipline (`--batching`; DESIGN.md §14):
    /// `deadline` is the legacy micro-batch coalescer below, `slots` is
    /// slot-level continuous batching (admission per submission, no gather
    /// window — the coalesce knobs don't apply and overrides are rejected).
    pub batching: BatchingMode,
    /// Service micro-batch deadline: wait at most this long (real ms) for
    /// more submissions before executing a call.
    pub coalesce_wait_ms: u64,
    /// Service fill waterline: dispatch immediately once queued rows reach
    /// this fraction of engine capacity.
    pub fill_waterline: f64,
    /// Scale the service's micro-batch deadline with the observed
    /// inter-submission gap (EWMA) instead of the fixed `coalesce_wait_ms`
    /// constant (which then only bounds the adaptive deadline).
    pub coalesce_adaptive: bool,
    /// Data-parallel engine replicas behind the shared service (the
    /// `--engines` flag; DESIGN.md §11). 1 = the single-engine service,
    /// bit-for-bit identical to the pre-pool scheduler. Ignored unless
    /// `service` is on.
    pub engines: usize,
    /// Write a Chrome trace-event JSON timeline of the run to this path
    /// (`--trace`; DESIGN.md §12). `None` = tracing off. Zero-perturbation:
    /// a traced run's `RunRecord` is bit-for-bit identical to an untraced
    /// one, and the knob is excluded from the checkpoint fingerprint.
    pub trace: Option<String>,
    /// Scripted fault-injection plan for the engine pool (`--fault-plan`;
    /// DESIGN.md §13). `None` = no chaos harness; `Some("none")` arms the
    /// recovery machinery with an empty script — which must reproduce the
    /// plain run byte for byte. Execution-topology class: excluded from
    /// the checkpoint fingerprint like `trace`/`workers`.
    pub fault_plan: Option<String>,
    /// Execute watchdog for the fault-tolerant pool (`--exec-timeout-ms`):
    /// a replica whose call runs longer than this is quarantined and its
    /// plans redispatched. 0 = no watchdog.
    pub exec_timeout_ms: u64,
    /// Pre-fork one spare engine per active replica and activate spares
    /// into quarantined replicas' places (`--respawn`).
    pub respawn: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        // One source of truth for the scheduler knobs: the service's own
        // defaults (tests/benches building PipelineConfig directly use
        // those too, so CLI- and literal-built runs cannot silently drift).
        let service_cfg = ServiceConfig::default();
        RunConfig {
            label: "run".into(),
            substrate: Substrate::Sim,
            model: "sim-7b".into(),
            dataset: DatasetKind::SynthDapo17k,
            dataset_size: 16_000,
            curriculum: CurriculumKind::Speed,
            algo: BaseAlgo::Rloo,
            n_init: 4,
            n_cont: 20,
            alloc: AllocKind::Fixed,
            n_cont_min: 0,
            n_cont_max: 0,
            p_low: 0.0,
            p_high: 1.0,
            batch_size: 16,
            temperature: 1.0,
            lr: 1e-6,
            eval_every: 10,
            max_steps: 400,
            max_seconds: f64::INFINITY,
            seed: 0,
            pool_factor: 4,
            workers: 1,
            pipeline: false,
            buffer_cap: 0,
            skip_confidence: 0.9,
            predictor_discount: 0.97,
            explore_rate: 0.05,
            service: false,
            batching: service_cfg.batching,
            coalesce_wait_ms: service_cfg.coalesce_wait_ms,
            fill_waterline: service_cfg.fill_waterline,
            coalesce_adaptive: service_cfg.adaptive,
            engines: 1,
            trace: None,
            fault_plan: None,
            exec_timeout_ms: 0,
            respawn: false,
        }
    }
}

impl RunConfig {
    /// Total rollouts per trained prompt (paper: 24). With adaptive
    /// allocation this is the *reference* total (the rollout batch target);
    /// realized groups span `n_init + [n_cont_min, n_cont_max]`.
    pub fn n_total(&self) -> usize {
        self.n_init + self.n_cont
    }

    /// The resolved continuation-budget bounds `(n_cont_min, n_cont_max)`:
    /// degenerate `(n_cont, n_cont)` for the fixed allocator, the explicit
    /// knobs for adaptive with `0` = auto (`max(1, n_cont/2)` and
    /// `2 * n_cont` — a symmetric band around the reference budget).
    pub fn alloc_bounds(&self) -> (usize, usize) {
        match self.alloc {
            AllocKind::Fixed => (self.n_cont, self.n_cont),
            AllocKind::Adaptive => {
                let min =
                    if self.n_cont_min == 0 { (self.n_cont / 2).max(1) } else { self.n_cont_min };
                let max = if self.n_cont_max == 0 { 2 * self.n_cont } else { self.n_cont_max };
                (min, max)
            }
        }
    }

    /// Largest possible group under the resolved budget bounds — what
    /// capacity checks must admit.
    pub fn max_group_rollouts(&self) -> usize {
        self.n_init + self.alloc_bounds().1
    }

    /// Screening/predictor invariants, checked at load time and by the run
    /// drivers — a degenerate band or a zero rollout split would otherwise
    /// silently reject (or accept) every prompt.
    pub fn validate(&self) -> Result<()> {
        if self.n_init < 1 {
            bail!("n_init must be >= 1 (got {})", self.n_init);
        }
        if self.n_cont < 1 {
            bail!("n_cont must be >= 1 (got {})", self.n_cont);
        }
        if !(self.p_low >= 0.0 && self.p_low < self.p_high && self.p_high <= 1.0) {
            bail!(
                "screening band must satisfy 0.0 <= p_low < p_high <= 1.0 (got p_low {}, p_high {})",
                self.p_low,
                self.p_high
            );
        }
        // For curricula that actually screen with the rule, the band must
        // contain at least one achievable realized rate k/n_init, or every
        // prompt is rejected and batch collection spins forever (e.g.
        // n_init = 1 under the strict default band: rates {0, 1} are both
        // outside (0, 1)).
        let screens = matches!(
            self.curriculum,
            CurriculumKind::Speed | CurriculumKind::SpeedNaive | CurriculumKind::PredictiveSpeed
        );
        if screens {
            let achievable = (0..=self.n_init).any(|k| {
                let rate = k as f64 / self.n_init as f64;
                rate > self.p_low && rate < self.p_high
            });
            if !achievable {
                bail!(
                    "screening band ({}, {}) contains no achievable pass rate at n_init {} — \
                     every prompt would be rejected and no batch could ever fill (raise n_init \
                     or widen the band)",
                    self.p_low,
                    self.p_high,
                    self.n_init
                );
            }
        }
        if self.batch_size < 1 {
            bail!("batch_size must be >= 1 (got {})", self.batch_size);
        }
        // Budget-band knobs silently doing nothing would misrepresent the
        // run (the config JSON would record a band no allocator enforces).
        if self.alloc == AllocKind::Fixed && (self.n_cont_min != 0 || self.n_cont_max != 0) {
            bail!(
                "n_cont_min/n_cont_max (got {}/{}) only apply to alloc=adaptive — the fixed \
                 allocator always spends exactly n_cont",
                self.n_cont_min,
                self.n_cont_max
            );
        }
        // Same hazard one level up: only the SPEED-family curricula consult
        // the allocator at all (they are the ones with a continuation
        // phase), so adaptive allocation on any other curriculum would run
        // uniform while the config claims otherwise.
        let allocates =
            matches!(self.curriculum, CurriculumKind::Speed | CurriculumKind::PredictiveSpeed);
        if self.alloc == AllocKind::Adaptive && !allocates {
            bail!(
                "alloc=adaptive requires a budget-allocating curriculum (speed or \
                 predictive-speed); '{}' spends uniform rollouts per prompt",
                self.curriculum.name()
            );
        }
        let (alloc_min, alloc_max) = self.alloc_bounds();
        if alloc_min > alloc_max {
            bail!(
                "n_cont_min must be <= n_cont_max (got {} > {}); 0 = auto",
                alloc_min,
                alloc_max
            );
        }
        // A single maximum-budget group must fit the per-step rollout
        // target, or the batch take could never complete.
        if self.max_group_rollouts() > self.batch_size * self.n_total() {
            bail!(
                "a maximum-budget group ({} rollouts = n_init {} + n_cont_max {}) exceeds the \
                 rollout batch target {} (batch_size x (n_init + n_cont)) — lower n_cont_max or \
                 raise batch_size/n_cont",
                self.max_group_rollouts(),
                self.n_init,
                alloc_max,
                self.batch_size * self.n_total()
            );
        }
        if !(self.skip_confidence > 0.0 && self.skip_confidence <= 1.0) {
            bail!(
                "skip_confidence must be in (0.0, 1.0] (got {}); 1.0 disables skipping",
                self.skip_confidence
            );
        }
        if !(self.predictor_discount > 0.0 && self.predictor_discount <= 1.0) {
            bail!(
                "predictor_discount must be in (0.0, 1.0] (got {})",
                self.predictor_discount
            );
        }
        if !(0.0..=1.0).contains(&self.explore_rate) {
            bail!("explore_rate must be in [0.0, 1.0] (got {})", self.explore_rate);
        }
        if !(self.fill_waterline > 0.0 && self.fill_waterline <= 1.0) {
            bail!(
                "fill_waterline must be in (0.0, 1.0] (got {}); 1.0 dispatches only full calls \
                 (the coalesce_wait_ms deadline still bounds waiting)",
                self.fill_waterline
            );
        }
        // Slots mode has no gather window, so a coalesce-knob override
        // would silently do nothing while the config JSON records it as
        // live — the same hazard as the alloc-band knobs above.
        if self.batching == BatchingMode::Slots {
            let defaults = ServiceConfig::default();
            if self.coalesce_wait_ms != defaults.coalesce_wait_ms
                || self.fill_waterline != defaults.fill_waterline
                || self.coalesce_adaptive != defaults.adaptive
            {
                bail!(
                    "--batching slots admits each submission the moment it arrives and has no \
                     coalesce deadline; drop the coalesce-wait-ms/fill-waterline/\
                     coalesce-adaptive overrides or use a deadline mode (valid batching \
                     modes: {})",
                    BatchingMode::NAMES.join(", ")
                );
            }
        }
        if !(1..=crate::metrics::MAX_POOL).contains(&self.engines) {
            bail!(
                "engines must be in 1..={} (got {}); the per-replica counters are \
                 fixed-size arrays",
                crate::metrics::MAX_POOL,
                self.engines
            );
        }
        if let Some(spec) = &self.fault_plan {
            let plan = crate::policy::fault::FaultPlan::parse(spec).context("fault_plan")?;
            if let Some(r) = plan.max_replica() {
                if r >= self.engines {
                    bail!(
                        "fault plan names replica {r} but only {} engine(s) are configured",
                        self.engines
                    );
                }
            }
        }
        Ok(())
    }

    /// A paper experimental setup by name, e.g. "7b-deepscale-speed-rloo".
    /// Grammar: `<model>-<dataset>-<curriculum>-<algo>`.
    pub fn paper_preset(name: &str) -> Result<RunConfig> {
        let parts: Vec<&str> = name.split('-').collect();
        if parts.len() != 4 {
            bail!("preset '{name}' must be <model>-<dataset>-<curriculum>-<algo>");
        }
        let mut cfg = RunConfig::default();
        cfg.label = name.to_string();
        cfg.model = match parts[0] {
            "1.5b" | "15b" => "sim-1.5b".into(),
            "7b" => "sim-7b".into(),
            other => bail!("unknown model '{other}'"),
        };
        cfg.dataset = DatasetKind::parse(parts[1]).context("dataset")?;
        cfg.dataset_size = cfg.dataset.default_size().min(40_000);
        cfg.curriculum = CurriculumKind::parse_or_err(parts[2])?;
        cfg.algo = BaseAlgo::parse(parts[3]).context("algo")?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("label", Json::str(self.label.clone())),
            (
                "substrate",
                Json::str(match self.substrate {
                    Substrate::Sim => "sim",
                    Substrate::Real => "real",
                }),
            ),
            ("model", Json::str(self.model.clone())),
            ("dataset", Json::str(self.dataset.name())),
            ("dataset_size", Json::num(self.dataset_size as f64)),
            ("curriculum", Json::str(self.curriculum.name())),
            ("algo", Json::str(self.algo.name())),
            ("n_init", Json::num(self.n_init as f64)),
            ("n_cont", Json::num(self.n_cont as f64)),
            ("alloc", Json::str(self.alloc.name())),
            ("n_cont_min", Json::num(self.n_cont_min as f64)),
            ("n_cont_max", Json::num(self.n_cont_max as f64)),
            ("p_low", Json::num(self.p_low)),
            ("p_high", Json::num(self.p_high)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("temperature", Json::num(self.temperature as f64)),
            ("lr", Json::num(self.lr)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("max_steps", Json::num(self.max_steps as f64)),
            ("max_seconds", Json::num(self.max_seconds)),
            ("seed", Json::num(self.seed as f64)),
            ("pool_factor", Json::num(self.pool_factor as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("pipeline", Json::Bool(self.pipeline)),
            ("buffer_cap", Json::num(self.buffer_cap as f64)),
            ("skip_confidence", Json::num(self.skip_confidence)),
            ("predictor_discount", Json::num(self.predictor_discount)),
            ("explore_rate", Json::num(self.explore_rate)),
            ("service", Json::Bool(self.service)),
            ("coalesce_wait_ms", Json::num(self.coalesce_wait_ms as f64)),
            ("fill_waterline", Json::num(self.fill_waterline)),
            ("coalesce_adaptive", Json::Bool(self.coalesce_adaptive)),
            ("engines", Json::num(self.engines as f64)),
        ];
        // Only emitted when set: untraced configs stay byte-identical to
        // the pre-trace format (the resume-smoke full-byte diff).
        if let Some(path) = &self.trace {
            fields.push(("trace", Json::str(path.clone())));
        }
        // Same emit-only-when-set rule for the batching mode: deadline
        // (the default) keeps the pre-slots byte layout.
        if self.batching != BatchingMode::Deadline {
            fields.push(("batching", Json::str(self.batching.name().to_string())));
        }
        // Same emit-only-when-set rule for the fault-tolerance knobs:
        // a run without the chaos harness keeps the pre-§13 byte layout.
        if let Some(plan) = &self.fault_plan {
            fields.push(("fault_plan", Json::str(plan.clone())));
        }
        if self.exec_timeout_ms > 0 {
            fields.push(("exec_timeout_ms", Json::num(self.exec_timeout_ms as f64)));
        }
        if self.respawn {
            fields.push(("respawn", Json::Bool(true)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let get_str = |k: &str| j.get(k).and_then(|x| x.as_str());
        let get_num = |k: &str| j.get(k).and_then(|x| x.as_f64());
        if let Some(v) = get_str("label") {
            cfg.label = v.to_string();
        }
        if let Some(v) = get_str("substrate") {
            cfg.substrate = match v {
                "sim" => Substrate::Sim,
                "real" => Substrate::Real,
                other => bail!("unknown substrate '{other}'"),
            };
        }
        if let Some(v) = get_str("model") {
            cfg.model = v.to_string();
        }
        if let Some(v) = get_str("dataset") {
            cfg.dataset = DatasetKind::parse(v).with_context(|| format!("dataset '{v}'"))?;
        }
        if let Some(v) = get_str("curriculum") {
            cfg.curriculum = CurriculumKind::parse_or_err(v)?;
        }
        if let Some(v) = get_str("algo") {
            cfg.algo = BaseAlgo::parse(v).with_context(|| format!("algo '{v}'"))?;
        }
        if let Some(v) = get_str("alloc") {
            cfg.alloc = AllocKind::parse_or_err(v)?;
        }
        macro_rules! num_field {
            ($key:literal, $field:ident, $ty:ty) => {
                if let Some(v) = get_num($key) {
                    cfg.$field = v as $ty;
                }
            };
        }
        num_field!("dataset_size", dataset_size, usize);
        num_field!("n_init", n_init, usize);
        num_field!("n_cont", n_cont, usize);
        num_field!("n_cont_min", n_cont_min, usize);
        num_field!("n_cont_max", n_cont_max, usize);
        num_field!("p_low", p_low, f64);
        num_field!("p_high", p_high, f64);
        num_field!("batch_size", batch_size, usize);
        num_field!("temperature", temperature, f32);
        num_field!("lr", lr, f64);
        num_field!("eval_every", eval_every, usize);
        num_field!("max_steps", max_steps, usize);
        num_field!("max_seconds", max_seconds, f64);
        num_field!("seed", seed, u64);
        num_field!("pool_factor", pool_factor, usize);
        num_field!("workers", workers, usize);
        num_field!("buffer_cap", buffer_cap, usize);
        num_field!("skip_confidence", skip_confidence, f64);
        num_field!("predictor_discount", predictor_discount, f64);
        num_field!("explore_rate", explore_rate, f64);
        num_field!("coalesce_wait_ms", coalesce_wait_ms, u64);
        num_field!("fill_waterline", fill_waterline, f64);
        num_field!("engines", engines, usize);
        if let Some(v) = j.get("pipeline").and_then(|x| x.as_bool()) {
            cfg.pipeline = v;
        }
        if let Some(v) = j.get("service").and_then(|x| x.as_bool()) {
            cfg.service = v;
        }
        if let Some(v) = j.get("coalesce_adaptive").and_then(|x| x.as_bool()) {
            cfg.coalesce_adaptive = v;
        }
        if let Some(v) = get_str("batching") {
            cfg.batching = BatchingMode::parse_or_err(v)?;
        }
        if let Some(v) = get_str("trace") {
            cfg.trace = Some(v.to_string());
        }
        if let Some(v) = get_str("fault_plan") {
            cfg.fault_plan = Some(v.to_string());
        }
        num_field!("exec_timeout_ms", exec_timeout_ms, u64);
        if let Some(v) = j.get("respawn").and_then(|x| x.as_bool()) {
            cfg.respawn = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        Self::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.label = "x".into();
        cfg.n_init = 4;
        cfg.max_seconds = 100.0;
        cfg.workers = 4;
        cfg.pipeline = true;
        cfg.buffer_cap = 48;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.label, "x");
        assert_eq!(back.n_init, 4);
        assert_eq!(back.n_total(), 4 + cfg.n_cont);
        assert_eq!(back.max_seconds, 100.0);
        assert_eq!(back.curriculum, cfg.curriculum);
        assert_eq!(back.workers, 4);
        assert!(back.pipeline);
        assert_eq!(back.buffer_cap, 48);
    }

    #[test]
    fn default_config_with_infinite_max_seconds_roundtrips() {
        // The default run has no time cap (max_seconds = infinity); its
        // JSON must still parse back — the writer emits the "Infinity"
        // literal the parser accepts, not Rust's "inf". A resumed run
        // loads the config file the original run saved, so an
        // unparseable default would block every resume of an uncapped run.
        let cfg = RunConfig::default();
        assert!(cfg.max_seconds.is_infinite());
        let text = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.max_seconds.is_infinite());
        assert_eq!(back.curriculum, cfg.curriculum);
    }

    #[test]
    fn validation_rejects_degenerate_configs() {
        let ok = RunConfig::default();
        assert!(ok.validate().is_ok());
        let mut bad = RunConfig::default();
        bad.n_init = 0;
        assert!(bad.validate().unwrap_err().to_string().contains("n_init"));
        let mut bad = RunConfig::default();
        bad.n_cont = 0;
        assert!(bad.validate().unwrap_err().to_string().contains("n_cont"));
        // Inverted and out-of-range bands carry the full invariant in the
        // error text.
        let mut bad = RunConfig::default();
        bad.p_low = 0.8;
        bad.p_high = 0.2;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("p_low < p_high"), "unhelpful error: {msg}");
        let mut bad = RunConfig::default();
        bad.p_high = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = RunConfig::default();
        bad.p_low = -0.1;
        assert!(bad.validate().is_err());
        // Equal thresholds are degenerate too (nothing can qualify).
        let mut bad = RunConfig::default();
        bad.p_low = 0.5;
        bad.p_high = 0.5;
        assert!(bad.validate().is_err());
        let mut bad = RunConfig::default();
        bad.skip_confidence = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = RunConfig::default();
        bad.predictor_discount = 1.2;
        assert!(bad.validate().is_err());
        let mut bad = RunConfig::default();
        bad.explore_rate = -0.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validation_rejects_unachievable_screening_bands() {
        // n_init = 1 under the strict default band: realized rates are 0 or
        // 1, both rejected — screening curricula could never fill a batch.
        let mut bad = RunConfig::default();
        bad.curriculum = CurriculumKind::Speed;
        bad.n_init = 1;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("no achievable pass rate"), "unhelpful error: {msg}");
        // One more screening rollout makes the band achievable again.
        let mut ok = RunConfig::default();
        ok.curriculum = CurriculumKind::Speed;
        ok.n_init = 2; // k = 1 -> rate 0.5 sits inside (0, 1)
        assert!(ok.validate().is_ok());
        // Non-screening curricula ignore the band: n_init = 1 stays valid.
        let mut uniform = RunConfig::default();
        uniform.curriculum = CurriculumKind::Uniform;
        uniform.n_init = 1;
        assert!(uniform.validate().is_ok());
    }

    #[test]
    fn from_json_validates_at_load_time() {
        let mut cfg = RunConfig::default();
        cfg.p_low = 0.9;
        cfg.p_high = 0.1;
        let err = RunConfig::from_json(&cfg.to_json()).unwrap_err().to_string();
        assert!(err.contains("p_low"), "load must surface the invariant: {err}");
    }

    #[test]
    fn predictor_knobs_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.skip_confidence = 0.75;
        cfg.predictor_discount = 0.99;
        cfg.explore_rate = 0.1;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.skip_confidence, 0.75);
        assert_eq!(back.predictor_discount, 0.99);
        assert_eq!(back.explore_rate, 0.1);
    }

    #[test]
    fn service_knobs_roundtrip_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.service = true;
        cfg.coalesce_wait_ms = 7;
        cfg.fill_waterline = 0.5;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.service);
        assert_eq!(back.coalesce_wait_ms, 7);
        assert_eq!(back.fill_waterline, 0.5);
        // default stays off
        assert!(!RunConfig::default().service);
        let mut bad = RunConfig::default();
        bad.fill_waterline = 0.0;
        assert!(bad.validate().unwrap_err().to_string().contains("fill_waterline"));
        let mut bad = RunConfig::default();
        bad.fill_waterline = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn unknown_curriculum_error_lists_valid_names() {
        let mut j = RunConfig::default().to_json();
        // Overwrite via parse of a patched string (Json is append-only
        // here, so round-trip through text).
        let text = j.to_string_pretty().replace("\"speed\"", "\"bogus-curriculum\"");
        j = Json::parse(&text).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("bogus-curriculum"), "{err}");
        assert!(err.contains("predictive-speed") && err.contains("uniform"), "{err}");
    }

    #[test]
    fn alloc_knobs_roundtrip_resolve_and_validate() {
        // Fixed (the default): degenerate bounds at n_cont, whatever the
        // min/max knobs say.
        let cfg = RunConfig::default();
        assert_eq!(cfg.alloc, AllocKind::Fixed);
        assert_eq!(cfg.alloc_bounds(), (cfg.n_cont, cfg.n_cont));
        assert_eq!(cfg.max_group_rollouts(), cfg.n_total());
        // Adaptive auto bounds: symmetric band around the reference budget.
        let mut cfg = RunConfig::default();
        cfg.alloc = AllocKind::Adaptive;
        assert_eq!(cfg.alloc_bounds(), (10, 40));
        assert_eq!(cfg.max_group_rollouts(), 44);
        assert!(cfg.validate().is_ok());
        // Explicit bounds round-trip through JSON.
        cfg.n_cont_min = 8;
        cfg.n_cont_max = 32;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.alloc, AllocKind::Adaptive);
        assert_eq!(back.alloc_bounds(), (8, 32));
        // Inverted bounds are rejected with the invariant in the message.
        let mut bad = RunConfig::default();
        bad.alloc = AllocKind::Adaptive;
        bad.n_cont_min = 32;
        bad.n_cont_max = 8;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("n_cont_min"), "unhelpful error: {msg}");
        // Band knobs under the fixed allocator would be silently ignored —
        // rejected instead, so the recorded config never lies.
        let mut bad = RunConfig::default();
        bad.n_cont_min = 8;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("alloc=adaptive"), "unhelpful error: {msg}");
        // Adaptive allocation on a curriculum with no continuation phase
        // would likewise run uniform while the config claims a band.
        let mut bad = RunConfig::default();
        bad.curriculum = CurriculumKind::Uniform;
        bad.alloc = AllocKind::Adaptive;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("uniform"), "unhelpful error: {msg}");
        let mut ok = RunConfig::default();
        ok.curriculum = CurriculumKind::PredictiveSpeed;
        ok.alloc = AllocKind::Adaptive;
        assert!(ok.validate().is_ok());
        // A max-budget group that cannot fit one rollout batch target is
        // rejected (batch_size 1: n_init + 2*n_cont > n_init + n_cont).
        let mut bad = RunConfig::default();
        bad.alloc = AllocKind::Adaptive;
        bad.batch_size = 1;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("rollout batch target"), "unhelpful error: {msg}");
    }

    #[test]
    fn engines_roundtrips_defaults_to_one_and_validates_bounds() {
        assert_eq!(RunConfig::default().engines, 1);
        let mut cfg = RunConfig::default();
        cfg.service = true;
        cfg.engines = 4;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.engines, 4);
        // A pre-pool record without the field parses as E=1.
        let text = cfg.to_json().to_string_pretty().replace(",\n  \"engines\": 4", "");
        assert!(!text.contains("engines"), "field not stripped: {text}");
        let old = RunConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(old.engines, 1);
        let mut bad = RunConfig::default();
        bad.engines = 0;
        assert!(bad.validate().unwrap_err().to_string().contains("engines"));
        let mut bad = RunConfig::default();
        bad.engines = crate::metrics::MAX_POOL + 1;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn trace_knob_roundtrips_and_is_omitted_when_off() {
        // Off by default, and the field is absent from the JSON so
        // untraced configs keep the pre-trace byte layout.
        let cfg = RunConfig::default();
        assert!(cfg.trace.is_none());
        assert!(!cfg.to_json().to_string_pretty().contains("\"trace\""));
        let mut cfg = RunConfig::default();
        cfg.trace = Some("out/trace.json".into());
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.trace.as_deref(), Some("out/trace.json"));
    }

    #[test]
    fn fault_knobs_roundtrip_and_are_omitted_when_off() {
        // Off by default, and absent from the JSON so non-chaos configs
        // keep the pre-fault-tolerance byte layout.
        let cfg = RunConfig::default();
        assert!(cfg.fault_plan.is_none());
        assert_eq!(cfg.exec_timeout_ms, 0);
        assert!(!cfg.respawn);
        let text = cfg.to_json().to_string_pretty();
        assert!(!text.contains("fault_plan"), "{text}");
        assert!(!text.contains("exec_timeout_ms"), "{text}");
        assert!(!text.contains("respawn"), "{text}");
        let mut cfg = RunConfig::default();
        cfg.engines = 3;
        cfg.fault_plan = Some("err@0:2,stall@1:3:400,die@2:4".into());
        cfg.exec_timeout_ms = 50;
        cfg.respawn = true;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.fault_plan.as_deref(), Some("err@0:2,stall@1:3:400,die@2:4"));
        assert_eq!(back.exec_timeout_ms, 50);
        assert!(back.respawn);
    }

    #[test]
    fn fault_plan_is_validated_at_load_time() {
        // A malformed spec is rejected with the grammar in the message.
        let mut bad = RunConfig::default();
        bad.fault_plan = Some("explode@0:0".into());
        let msg = format!("{:#}", bad.validate().unwrap_err());
        assert!(msg.contains("kind@replica:call"), "no grammar in: {msg}");
        // A plan naming a replica beyond the configured pool is rejected.
        let mut bad = RunConfig::default();
        bad.engines = 2;
        bad.fault_plan = Some("err@2:0".into());
        let msg = format!("{:#}", bad.validate().unwrap_err());
        assert!(msg.contains("replica 2"), "{msg}");
        assert!(msg.contains("2 engine"), "{msg}");
        // "none" arms the machinery with an empty script — always valid.
        let mut ok = RunConfig::default();
        ok.fault_plan = Some("none".into());
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn coalesce_adaptive_roundtrips_and_defaults_off() {
        assert!(!RunConfig::default().coalesce_adaptive);
        let mut cfg = RunConfig::default();
        cfg.coalesce_adaptive = true;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.coalesce_adaptive);
    }

    #[test]
    fn batching_roundtrips_and_is_omitted_for_deadline() {
        // Deadline is the default and absent from the JSON, so pre-slots
        // configs keep their byte layout (the resume-smoke full-byte diff).
        let cfg = RunConfig::default();
        assert_eq!(cfg.batching, BatchingMode::Deadline);
        assert!(!cfg.to_json().to_string_pretty().contains("batching"));
        let mut cfg = RunConfig::default();
        cfg.batching = BatchingMode::Slots;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.batching, BatchingMode::Slots);
        // An unknown mode in a config file lists the valid modes.
        let mut bad = RunConfig::default().to_json();
        if let Json::Obj(fields) = &mut bad {
            fields.insert("batching".to_string(), Json::str("bogus"));
        }
        let msg = format!("{:#}", RunConfig::from_json(&bad).unwrap_err());
        assert!(msg.contains("deadline, slots"), "{msg}");
    }

    #[test]
    fn slots_mode_rejects_coalesce_knob_overrides() {
        // A coalesce override under slots mode would silently do nothing
        // — reject it at validate() time, listing the valid modes.
        for mutate in [
            (|c: &mut RunConfig| c.coalesce_wait_ms = 10) as fn(&mut RunConfig),
            |c: &mut RunConfig| c.fill_waterline = 1.0,
            |c: &mut RunConfig| c.coalesce_adaptive = true,
        ] {
            let mut bad = RunConfig::default();
            bad.batching = BatchingMode::Slots;
            mutate(&mut bad);
            let msg = format!("{:#}", bad.validate().unwrap_err());
            assert!(msg.contains("--batching slots"), "{msg}");
            assert!(msg.contains("deadline, slots"), "modes not listed: {msg}");
        }
        // The pure slots config (all coalesce knobs at defaults) is valid,
        // and the deadline default still accepts its own knob overrides.
        let mut ok = RunConfig::default();
        ok.batching = BatchingMode::Slots;
        assert!(ok.validate().is_ok());
        let mut ok = RunConfig::default();
        ok.coalesce_wait_ms = 10;
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn paper_presets_parse() {
        let cfg = RunConfig::paper_preset("7b-deepscale-speed-rloo").unwrap();
        assert_eq!(cfg.model, "sim-7b");
        assert_eq!(cfg.dataset, DatasetKind::SynthDeepScale);
        assert_eq!(cfg.curriculum, CurriculumKind::Speed);
        assert_eq!(cfg.algo, BaseAlgo::Rloo);
        let cfg = RunConfig::paper_preset("1.5b-numina-uniform-dapo").unwrap();
        assert_eq!(cfg.model, "sim-1.5b");
        assert_eq!(cfg.algo, BaseAlgo::Dapo);
        assert!(RunConfig::paper_preset("bad").is_err());
        assert!(RunConfig::paper_preset("7b-nope-speed-rloo").is_err());
    }
}
