//! Trace spine: per-thread bounded event buffers with a global collector
//! that exports Chrome trace-event JSON (DESIGN.md §12).
//!
//! Zero-perturbation contract. Tracing is compiled in but branch-cheap
//! when off: every instrumentation site starts with one relaxed atomic
//! load and touches nothing else. When on, it never reads RNG state and
//! never changes scheduling order — each thread appends to its *own* ring
//! behind a mutex no other thread contends until the final drain — and
//! memory is bounded by a fixed per-thread capacity with a
//! `dropped_events` counter instead of an unbounded Vec. The CI rail in
//! `tests/trace_sim.rs` (and the `ci.sh` trace smoke) holds a traced
//! run's `RunRecord` bit for bit equal to an untraced one on serial,
//! pipelined, and pooled topologies.
//!
//! Timestamps share the wall-clock epoch with `util::logging`, so trace
//! spans and leveled log lines are directly comparable.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::util::json::Json;
use crate::util::logging;
use crate::util::sync::plock;

/// Per-thread ring capacity in events. Beyond it new events are dropped
/// and counted — the buffer never grows past the cap.
pub const RING_CAP: usize = 65_536;

/// Event kinds in the Chrome trace-event model: complete spans (`"X"`)
/// and instants (`"i"`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Span,
    Instant,
}

/// One recorded event. `&'static str` names keep recording allocation-free.
#[derive(Clone, Copy, Debug)]
struct Event {
    name: &'static str,
    cat: &'static str,
    kind: Kind,
    /// Microseconds since the shared logging/trace epoch.
    ts_us: u64,
    dur_us: u64,
    arg: i64,
}

/// A thread's bounded event buffer. Only the owning thread pushes; the
/// collector locks it once at drain time.
struct Ring {
    label: String,
    events: Vec<Event>,
    cap: usize,
    dropped: u64,
}

impl Ring {
    fn new(label: String, cap: usize) -> Ring {
        // Grow lazily toward the cap instead of reserving the full buffer
        // up front for every short-lived thread.
        Ring { label, events: Vec::new(), cap, dropped: 0 }
    }

    fn push(&mut self, ev: Event) {
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Bumped on every enable/finish so stale thread-local handles from a
/// previous collection re-register instead of writing into drained rings.
static GENERATION: AtomicU64 = AtomicU64::new(0);
static REGISTRY: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
/// Cached copy of the logging epoch: `OnceLock::get` is one atomic load,
/// vs. the mutex `logging::epoch()` takes (fine per call, not per event).
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static LOCAL: RefCell<Option<(u64, Arc<Mutex<Ring>>)>> = const { RefCell::new(None) };
}

/// Whether the collector is recording. One relaxed load — the fast path
/// every instrumentation site takes when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start collecting. Anchors the trace to the shared logging epoch.
pub fn enable() {
    let _ = EPOCH.set(logging::epoch());
    GENERATION.fetch_add(1, Ordering::SeqCst);
    plock(&REGISTRY).clear();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop collecting and drain every thread's ring. Returns `None` when
/// tracing was not enabled. Threads may keep calling the record API
/// concurrently; events landing after the swap are simply dropped with
/// their rings.
pub fn finish() -> Option<TraceData> {
    if !ENABLED.swap(false, Ordering::SeqCst) {
        return None;
    }
    GENERATION.fetch_add(1, Ordering::SeqCst);
    let rings: Vec<Arc<Mutex<Ring>>> = std::mem::take(&mut *plock(&REGISTRY));
    let mut threads = Vec::new();
    let mut dropped_events = 0u64;
    for ring in rings {
        let mut g = plock(&ring);
        dropped_events += g.dropped;
        threads.push(ThreadTrace {
            label: std::mem::take(&mut g.label),
            dropped: g.dropped,
            events: std::mem::take(&mut g.events),
        });
    }
    // Registration order races across threads; sort for a deterministic
    // export layout (duplicate labels keep distinct tids).
    threads.sort_by(|a, b| a.label.cmp(&b.label));
    Some(TraceData { threads, dropped_events })
}

/// Run `f` on the calling thread's ring, registering one (keyed to the
/// current collection generation) on first use.
fn with_ring(f: impl FnOnce(&mut Ring)) {
    let generation = GENERATION.load(Ordering::Relaxed);
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        let stale = match slot.as_ref() {
            Some((g, _)) => *g != generation,
            None => true,
        };
        if stale {
            let cur = std::thread::current();
            let label = match cur.name() {
                Some(name) => name.to_string(),
                None => format!("{:?}", cur.id()),
            };
            let ring = Arc::new(Mutex::new(Ring::new(label, RING_CAP)));
            plock(&REGISTRY).push(Arc::clone(&ring));
            *slot = Some((generation, ring));
        }
        if let Some((_, ring)) = slot.as_ref() {
            f(&mut plock(ring));
        }
    });
}

/// Name the calling thread's timeline row (unnamed pool workers would
/// otherwise show up as opaque thread ids). No-op when tracing is off.
pub fn set_thread_label(label: &str) {
    if !enabled() {
        return;
    }
    with_ring(|r| r.label = label.to_string());
}

fn ts_us(t: Instant) -> u64 {
    let epoch = EPOCH.get().copied().unwrap_or(t);
    t.duration_since(epoch).as_micros() as u64
}

/// Span opener: a timestamp when recording, `None` (and no clock read)
/// when off. Pair with [`span`].
#[inline]
pub fn start() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Close a span opened by [`start`]. `arg` is a site-defined small
/// integer (replica index, batch rows, deadline-fired flag, ...).
pub fn span(name: &'static str, cat: &'static str, start: Option<Instant>, arg: i64) {
    let Some(t0) = start else { return };
    record(name, cat, Kind::Span, t0, Instant::now(), arg);
}

/// Record a span from an `Instant` the instrumented code already owns
/// (no extra clock read on the start side, one on the end side).
pub fn span_from(name: &'static str, cat: &'static str, t0: Instant, arg: i64) {
    if !enabled() {
        return;
    }
    record(name, cat, Kind::Span, t0, Instant::now(), arg);
}

/// Record a span between two `Instant`s the instrumented code already
/// owns (no clock reads at all — for sites that measure durations
/// unconditionally, e.g. the always-on latency histograms).
pub fn span_between(name: &'static str, cat: &'static str, t0: Instant, t1: Instant, arg: i64) {
    if !enabled() {
        return;
    }
    record(name, cat, Kind::Span, t0, t1, arg);
}

/// Record a point event.
pub fn instant(name: &'static str, cat: &'static str, arg: i64) {
    if !enabled() {
        return;
    }
    let now = Instant::now();
    record(name, cat, Kind::Instant, now, now, arg);
}

fn record(name: &'static str, cat: &'static str, kind: Kind, t0: Instant, t1: Instant, arg: i64) {
    let ev = Event {
        name,
        cat,
        kind,
        ts_us: ts_us(t0),
        dur_us: t1.saturating_duration_since(t0).as_micros() as u64,
        arg,
    };
    with_ring(|r| r.push(ev));
}

/// One drained per-thread timeline.
pub struct ThreadTrace {
    pub label: String,
    pub dropped: u64,
    events: Vec<Event>,
}

/// Everything [`finish`] collected, ready for export.
pub struct TraceData {
    threads: Vec<ThreadTrace>,
    pub dropped_events: u64,
}

impl TraceData {
    pub fn thread_count(&self) -> usize {
        self.threads.len()
    }

    pub fn event_count(&self) -> usize {
        self.threads.iter().map(|t| t.events.len()).sum()
    }

    /// Chrome trace-event JSON (the object form, loadable by Perfetto and
    /// `chrome://tracing`): `"X"` complete spans and `"i"` instants, one
    /// `tid` per thread with a `thread_name` metadata record, timestamps
    /// in microseconds since the shared epoch.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        for (idx, t) in self.threads.iter().enumerate() {
            let tid = (idx + 1) as f64;
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid)),
                ("args", Json::obj(vec![("name", Json::str(t.label.clone()))])),
            ]));
            for ev in &t.events {
                let mut fields = vec![
                    ("name", Json::str(ev.name)),
                    ("cat", Json::str(ev.cat)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(tid)),
                    ("ts", Json::num(ev.ts_us as f64)),
                    ("args", Json::obj(vec![("arg", Json::num(ev.arg as f64))])),
                ];
                match ev.kind {
                    Kind::Span => {
                        fields.push(("ph", Json::str("X")));
                        fields.push(("dur", Json::num(ev.dur_us as f64)));
                    }
                    Kind::Instant => {
                        fields.push(("ph", Json::str("i")));
                        fields.push(("s", Json::str("t")));
                    }
                }
                events.push(Json::obj(fields));
            }
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("dropped_events", Json::num(self.dropped_events as f64)),
                    ("tool", Json::str("speed-rl")),
                ]),
            ),
            ("traceEvents", Json::arr(events)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Log-bucketed latency histograms
// ---------------------------------------------------------------------------

/// Bucket count shared by the always-on `ServiceCounters` histograms and
/// the analyzer.
pub const HIST_BUCKETS: usize = 8;

/// Upper bucket edges in seconds: 10µs, 100µs, 1ms, 10ms, 100ms, 1s, 10s,
/// +inf (overflow).
const HIST_UPPER_S: [f64; HIST_BUCKETS] = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, f64::INFINITY];

/// Index of the log bucket holding a latency observation.
pub fn latency_bucket(seconds: f64) -> usize {
    HIST_UPPER_S.iter().position(|&ub| seconds < ub).unwrap_or(HIST_BUCKETS - 1)
}

/// Upper-bound quantile estimate over a log-bucketed histogram: the upper
/// edge of the bucket holding the q-quantile observation. The overflow
/// bucket reports the last finite edge (the estimate saturates rather
/// than inventing a value). Empty histograms report 0.
pub fn hist_quantile(hist: &[u64], q: f64) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let rank = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (i, &count) in hist.iter().enumerate() {
        seen += count;
        if seen >= rank {
            let ub = HIST_UPPER_S[i.min(HIST_BUCKETS - 1)];
            return if ub.is_finite() { ub } else { HIST_UPPER_S[HIST_BUCKETS - 2] };
        }
    }
    HIST_UPPER_S[HIST_BUCKETS - 2]
}

// ---------------------------------------------------------------------------
// Analyzer (`speed-rl trace summarize`)
// ---------------------------------------------------------------------------

/// Aggregate stats for one span name across the whole trace.
pub struct PhaseSummary {
    pub name: String,
    pub count: u64,
    pub total_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// What `summarize_chrome` extracts from a Chrome trace JSON document.
pub struct TraceSummary {
    /// Per-span-name breakdown, descending by total wall-clock.
    pub phases: Vec<PhaseSummary>,
    /// Instant-event counts by name.
    pub instants: Vec<(String, u64)>,
    pub threads: usize,
    pub events: u64,
    pub dropped_events: u64,
    /// First event start to last event end, in seconds.
    pub wall_s: f64,
}

/// Summarize a parsed Chrome trace-event document: per-phase wall-clock
/// totals and exact p50/p95/p99 over each span name's durations.
pub fn summarize_chrome(doc: &Json) -> Result<TraceSummary> {
    let Some(events) = doc.get("traceEvents").and_then(|e| e.as_arr()) else {
        bail!("not a Chrome trace document: missing 'traceEvents' array");
    };
    use std::collections::{BTreeMap, BTreeSet};
    let mut durs: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut instants: BTreeMap<String, u64> = BTreeMap::new();
    let mut threads: BTreeSet<i64> = BTreeSet::new();
    let mut min_ts = f64::INFINITY;
    let mut max_end = f64::NEG_INFINITY;
    let mut count = 0u64;
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        if ph == "M" {
            continue;
        }
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("?");
        let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0);
        count += 1;
        if let Some(tid) = ev.get("tid").and_then(|t| t.as_f64()) {
            threads.insert(tid as i64);
        }
        match ph {
            "X" => {
                let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
                min_ts = min_ts.min(ts);
                max_end = max_end.max(ts + dur);
                durs.entry(name.to_string()).or_default().push(dur);
            }
            "i" | "I" => {
                min_ts = min_ts.min(ts);
                max_end = max_end.max(ts);
                *instants.entry(name.to_string()).or_default() += 1;
            }
            _ => {}
        }
    }
    let mut phases: Vec<PhaseSummary> = durs
        .into_iter()
        .map(|(name, mut d)| {
            d.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let total_us: f64 = d.iter().sum();
            let q = |p: f64| d[((d.len() - 1) as f64 * p).round() as usize] / 1e6;
            PhaseSummary {
                count: d.len() as u64,
                total_s: total_us / 1e6,
                p50_s: q(0.50),
                p95_s: q(0.95),
                p99_s: q(0.99),
                name,
            }
        })
        .collect();
    phases.sort_by(|a, b| {
        b.total_s.partial_cmp(&a.total_s).unwrap_or(std::cmp::Ordering::Equal)
    });
    let dropped_events = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|d| d.as_u64_lossy())
        .unwrap_or(0);
    let wall_s =
        if max_end > min_ts && min_ts.is_finite() { (max_end - min_ts) / 1e6 } else { 0.0 };
    Ok(TraceSummary {
        phases,
        instants: instants.into_iter().collect(),
        threads: threads.len(),
        events: count,
        dropped_events,
        wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_buckets_cover_the_log_range() {
        assert_eq!(latency_bucket(0.0), 0);
        assert_eq!(latency_bucket(5e-6), 0);
        assert_eq!(latency_bucket(5e-5), 1);
        assert_eq!(latency_bucket(5e-4), 2);
        assert_eq!(latency_bucket(5e-3), 3);
        assert_eq!(latency_bucket(5e-2), 4);
        assert_eq!(latency_bucket(0.5), 5);
        assert_eq!(latency_bucket(5.0), 6);
        assert_eq!(latency_bucket(50.0), 7);
        assert_eq!(latency_bucket(f64::INFINITY), 7);
    }

    #[test]
    fn hist_quantile_reports_bucket_upper_edges() {
        let mut hist = [0u64; HIST_BUCKETS];
        assert_eq!(hist_quantile(&hist, 0.95), 0.0);
        // 90 observations in the 1ms bucket, 10 in the 100ms bucket: the
        // p50 sits in the former, the p95 in the latter.
        hist[2] = 90;
        hist[4] = 10;
        assert_eq!(hist_quantile(&hist, 0.50), 1e-3);
        assert_eq!(hist_quantile(&hist, 0.95), 1e-1);
        // The overflow bucket saturates at the last finite edge.
        let mut over = [0u64; HIST_BUCKETS];
        over[7] = 5;
        assert_eq!(hist_quantile(&over, 0.5), 10.0);
    }

    #[test]
    fn ring_drops_beyond_cap_and_counts() {
        let ev = Event { name: "x", cat: "t", kind: Kind::Instant, ts_us: 0, dur_us: 0, arg: 0 };
        let mut ring = Ring::new("t".into(), 2);
        ring.push(ev);
        ring.push(ev);
        ring.push(ev);
        assert_eq!(ring.events.len(), 2);
        assert_eq!(ring.dropped, 1);
    }

    #[test]
    fn collector_roundtrip_exports_chrome_json_and_summarizes() {
        // The one test touching the process-global collector state (other
        // lib tests never enable tracing, so there is nothing to race).
        assert!(!enabled());
        assert!(start().is_none());
        assert!(finish().is_none(), "finish without enable must be a no-op");

        enable();
        set_thread_label("unit-test-thread");
        let t0 = start();
        assert!(t0.is_some());
        span("unit-span", "test", t0, 7);
        span_from("unit-span", "test", Instant::now(), 0);
        instant("unit-instant", "test", 3);
        let helper = std::thread::Builder::new()
            .name("unit-helper".into())
            .spawn(|| instant("helper-instant", "test", 1))
            .unwrap();
        helper.join().unwrap();

        let data = finish().expect("collector was enabled");
        assert!(!enabled());
        assert_eq!(data.thread_count(), 2);
        assert_eq!(data.event_count(), 4);
        assert_eq!(data.dropped_events, 0);

        let doc = data.to_chrome_json();
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 4 events + 2 thread_name metadata records.
        assert_eq!(events.len(), 6);
        let names: Vec<&str> =
            events.iter().filter_map(|e| e.get("name").and_then(|n| n.as_str())).collect();
        assert!(names.contains(&"unit-span"));
        assert!(names.contains(&"helper-instant"));
        let meta: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .collect();
        let labels: Vec<&str> = meta
            .iter()
            .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()))
            .collect();
        assert_eq!(labels, vec!["unit-helper", "unit-test-thread"], "sorted by label");

        let summary = summarize_chrome(&back).unwrap();
        assert_eq!(summary.threads, 2);
        assert_eq!(summary.events, 4);
        assert_eq!(summary.dropped_events, 0);
        let phase = summary.phases.iter().find(|p| p.name == "unit-span").unwrap();
        assert_eq!(phase.count, 2);
        assert!(phase.total_s >= 0.0 && phase.p99_s >= phase.p50_s);
        let inst: u64 =
            summary.instants.iter().filter(|(n, _)| n.ends_with("instant")).map(|(_, c)| c).sum();
        assert_eq!(inst, 2);

        // After finish, recording is off again: no events accumulate.
        span_from("late", "test", Instant::now(), 0);
        assert!(finish().is_none());

        // Not a trace document -> a helpful error.
        let err = summarize_chrome(&Json::obj(vec![])).unwrap_err().to_string();
        assert!(err.contains("traceEvents"), "{err}");
    }
}
