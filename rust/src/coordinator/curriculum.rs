//! Curriculum strategies: how training batches are collected from the
//! inference engine.
//!
//! * [`Uniform`]     — vanilla RL: every sampled prompt gets all N rollouts
//!                     and is trained on (RLOO / GRPO / REINFORCE baselines).
//! * [`DapoFilter`]  — DAPO's dynamic sampling: full inference first, then
//!                     discard uniform-reward groups and resample until the
//!                     batch is full (post-hoc filtering — pays full
//!                     inference for rejected prompts).
//! * [`Speed`]       — the paper's Algorithm 2: screening with `N_init`
//!                     rollouts, continuation only for qualified prompts,
//!                     sampling buffer + pre-fetch batcher.
//! * [`PredictiveSpeed`] — SPEED with a learned pre-screen: the difficulty
//!                     predictor skips confidently-uninformative prompts
//!                     before any rollout is spent
//!                     ([`crate::coordinator::predictive`]).
//! * [`VarianceMax`] — Foster & Foerster (2025): full inference on a pool,
//!                     train on the top-B by reward variance.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::alloc::Allocator;
use crate::coordinator::batcher::{plan_call, Purpose};
use crate::coordinator::buffer::SamplingBuffer;
use crate::coordinator::predictive::PredictiveSpeed;
use crate::coordinator::screening::ScreeningRule;
use crate::data::loader::PromptSource;
use crate::data::tasks::TaskInstance;
use crate::metrics::InferenceCounters;
use crate::policy::{GenRequest, RolloutEngine};
use crate::predictor::{Predictor, PredictorConfig};
use crate::rl::update::PromptGroup;
use crate::util::json::Json;

/// Strategy selector (CLI / config name).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CurriculumKind {
    Uniform,
    DapoFilter,
    Speed,
    /// Algorithm 1 without §4.3's pre-fetching/buffering (ablation).
    SpeedNaive,
    /// SPEED behind the learned difficulty pre-screen.
    PredictiveSpeed,
    VarianceMax,
}

impl CurriculumKind {
    /// Every valid kind, in CLI-listing order.
    pub const ALL: [CurriculumKind; 6] = [
        CurriculumKind::Uniform,
        CurriculumKind::DapoFilter,
        CurriculumKind::Speed,
        CurriculumKind::SpeedNaive,
        CurriculumKind::PredictiveSpeed,
        CurriculumKind::VarianceMax,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CurriculumKind::Uniform => "uniform",
            CurriculumKind::DapoFilter => "dapo-filter",
            CurriculumKind::Speed => "speed",
            CurriculumKind::SpeedNaive => "speed-naive",
            CurriculumKind::PredictiveSpeed => "predictive-speed",
            CurriculumKind::VarianceMax => "variance-max",
        }
    }

    pub fn parse(s: &str) -> Option<CurriculumKind> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" | "vanilla" => Some(CurriculumKind::Uniform),
            "dapo-filter" | "dapo" => Some(CurriculumKind::DapoFilter),
            "speed" => Some(CurriculumKind::Speed),
            "speed-naive" | "naive" => Some(CurriculumKind::SpeedNaive),
            "predictive-speed" | "predictive" => Some(CurriculumKind::PredictiveSpeed),
            "variance-max" | "varmax" => Some(CurriculumKind::VarianceMax),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) with an error that lists every valid name —
    /// what the CLI and config loader surface for a typo'd `--curriculum`.
    pub fn parse_or_err(s: &str) -> Result<CurriculumKind> {
        CurriculumKind::parse(s).ok_or_else(|| {
            let names: Vec<&str> = CurriculumKind::ALL.iter().map(|k| k.name()).collect();
            anyhow!("unknown curriculum '{s}' (valid: {})", names.join(", "))
        })
    }
}

/// Everything a curriculum needs to drive one batch collection. Holds only
/// the *inference* half of the policy, so the same curricula run unchanged
/// inside the serial trainer and inside pipelined rollout workers.
pub struct StepContext<'a> {
    pub engine: &'a mut dyn RolloutEngine,
    pub prompts: &'a mut dyn PromptSource,
    pub train_step: usize,
    pub temperature: f32,
    pub counters: &'a mut InferenceCounters,
}

impl<'a> StepContext<'a> {
    pub(crate) fn next_prompt(&mut self) -> (usize, TaskInstance) {
        self.prompts.next_prompt()
    }

    /// Execute one batched generation call and account for it.
    pub(crate) fn run_call(&mut self, requests: &[GenRequest]) -> Result<crate::policy::GenResult> {
        let res = self.engine.generate(requests, self.temperature)?;
        self.counters.calls += 1;
        self.counters.rows_used += res.rows_used as u64;
        self.counters.rows_capacity += self.engine.rollout_capacity() as u64;
        self.counters.cost_s += res.cost_s;
        self.counters.rollouts += res.groups.iter().map(|g| g.len() as u64).sum::<u64>();
        Ok(res)
    }
}

/// A curriculum collects complete training batches of `B` prompt groups.
pub trait Curriculum {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>>;

    fn kind(&self) -> CurriculumKind;

    /// Groups waiting in internal buffers (SPEED's sampling buffer).
    fn buffered(&self) -> usize {
        0
    }

    /// Mean steps-in-buffer over groups consumed so far (SPEED only).
    fn mean_staleness(&self) -> f64 {
        0.0
    }

    /// Resume-critical internal state for a warm-resume checkpoint
    /// (sampling-buffer contents, pending continuations, exploration RNG).
    /// `None` = stateless curriculum (Uniform/DAPO/VarianceMax hold
    /// nothing between batches). Called only between batch collections
    /// with all observation deltas flushed (the quiesce protocol), never
    /// mid-call.
    fn state_json(&self) -> Option<Json> {
        None
    }

    /// Restore state written by [`state_json`](Curriculum::state_json).
    /// The checkpoint loader verifies the curriculum kind via the config
    /// fingerprint before calling this, so a default no-op is safe for
    /// stateless kinds.
    fn restore_state_json(&mut self, _state: &Json) -> Result<()> {
        Ok(())
    }
}

/// Everything needed to build a curriculum instance — cheap to `Clone`, so
/// pipelined rollout workers can each construct their own inside the worker
/// thread (the `predictor` handle is an `Arc`: all instances built from one
/// spec share a single difficulty store).
#[derive(Clone, Debug)]
pub struct CurriculumSpec {
    pub kind: CurriculumKind,
    pub rule: ScreeningRule,
    /// Per-prompt continuation-budget allocator (SPEED-family kinds only;
    /// [`Allocator::fixed`] reproduces the uniform-`n_cont` semantics).
    pub alloc: Allocator,
    /// VarianceMax pool factor.
    pub pool_factor: usize,
    /// SPEED sampling-buffer capacity (groups; `usize::MAX` = unbounded).
    pub buffer_cap: usize,
    /// Shared difficulty predictor; required by `PredictiveSpeed` (a fresh
    /// private one is created if absent), ignored by every other kind.
    pub predictor: Option<Arc<Predictor>>,
}

impl CurriculumSpec {
    /// A spec with the pre-refactor defaults: fixed allocation at the
    /// rule's `n_cont`, no shared predictor.
    pub fn fixed(kind: CurriculumKind, rule: ScreeningRule) -> CurriculumSpec {
        CurriculumSpec {
            kind,
            rule,
            alloc: Allocator::fixed(rule),
            pool_factor: 4,
            buffer_cap: usize::MAX,
            predictor: None,
        }
    }

    pub fn build(&self) -> Box<dyn Curriculum> {
        if self.kind == CurriculumKind::PredictiveSpeed {
            let predictor = self.predictor.clone().unwrap_or_else(|| {
                Arc::new(Predictor::new(self.rule, PredictorConfig::default()))
            });
            return Box::new(
                PredictiveSpeed::new(self.rule, predictor)
                    .with_buffer_cap(self.buffer_cap)
                    .with_allocator(self.alloc.clone()),
            );
        }
        if self.kind == CurriculumKind::Speed {
            return Box::new(
                Speed::new(self.rule)
                    .with_buffer_cap(self.buffer_cap)
                    .with_allocator(self.alloc.clone()),
            );
        }
        make_configured(self.kind, self.rule, self.pool_factor, self.buffer_cap)
    }
}

/// Construct a strategy with an unbounded SPEED buffer. `rule` supplies
/// (N_init, N_cont) — non-SPEED strategies use `rule.n_total()` rollouts
/// per prompt.
pub fn make(kind: CurriculumKind, rule: ScreeningRule, pool_factor: usize) -> Box<dyn Curriculum> {
    make_configured(kind, rule, pool_factor, usize::MAX)
}

/// [`make`] with an explicit SPEED sampling-buffer capacity. A
/// `PredictiveSpeed` built this way owns a private default predictor; runs
/// that share the store across workers go through [`CurriculumSpec`].
pub fn make_configured(
    kind: CurriculumKind,
    rule: ScreeningRule,
    pool_factor: usize,
    buffer_cap: usize,
) -> Box<dyn Curriculum> {
    match kind {
        CurriculumKind::Uniform => Box::new(Uniform { n_total: rule.n_total() }),
        CurriculumKind::DapoFilter => Box::new(DapoFilter { n_total: rule.n_total() }),
        CurriculumKind::Speed => Box::new(Speed::new(rule).with_buffer_cap(buffer_cap)),
        CurriculumKind::SpeedNaive => {
            Box::new(crate::coordinator::naive::SpeedNaive::new(rule))
        }
        CurriculumKind::PredictiveSpeed => Box::new(
            PredictiveSpeed::new(rule, Arc::new(Predictor::new(rule, PredictorConfig::default())))
                .with_buffer_cap(buffer_cap),
        ),
        CurriculumKind::VarianceMax => {
            Box::new(VarianceMax { n_total: rule.n_total(), pool_factor })
        }
    }
}

// ---------------------------------------------------------------------------
// Uniform (vanilla)
// ---------------------------------------------------------------------------

/// Vanilla RL: sample B prompts, N rollouts each, train on all of them.
pub struct Uniform {
    pub n_total: usize,
}

/// Generate full-N groups for `prompts`, splitting across as many calls as
/// capacity requires. Shared by Uniform / DapoFilter / VarianceMax.
fn full_inference(
    ctx: &mut StepContext<'_>,
    prompts: Vec<(usize, TaskInstance)>,
    n_total: usize,
) -> Result<Vec<PromptGroup>> {
    let capacity = ctx.engine.rollout_capacity();
    assert!(n_total <= capacity, "N={n_total} exceeds inference call capacity {capacity}");
    let per_call = capacity / n_total;
    let mut groups = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(per_call) {
        let requests: Vec<GenRequest> = chunk
            .iter()
            .map(|(idx, task)| GenRequest {
                prompt_idx: *idx,
                task: task.clone(),
                n_samples: n_total,
            })
            .collect();
        let res = ctx.run_call(&requests)?;
        for (req, rollouts) in requests.into_iter().zip(res.groups) {
            groups.push(PromptGroup { prompt_idx: req.prompt_idx, task: req.task, rollouts });
        }
    }
    Ok(groups)
}

impl Curriculum for Uniform {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>> {
        let prompts: Vec<_> = (0..batch_size).map(|_| ctx.next_prompt()).collect();
        full_inference(ctx, prompts, self.n_total)
    }

    fn kind(&self) -> CurriculumKind {
        CurriculumKind::Uniform
    }
}

// ---------------------------------------------------------------------------
// DAPO dynamic sampling
// ---------------------------------------------------------------------------

/// DAPO: full inference, then discard groups whose rewards are uniform
/// (pass rate exactly 0 or 1) and keep sampling until B survive.
pub struct DapoFilter {
    pub n_total: usize,
}

impl Curriculum for DapoFilter {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>> {
        let mut kept: Vec<PromptGroup> = Vec::with_capacity(batch_size);
        // Safety valve: stop resampling after many waves (e.g. a dataset the
        // model fully saturates) and train on whatever survived.
        let max_waves = 64;
        for _wave in 0..max_waves {
            let need = batch_size - kept.len();
            if need == 0 {
                break;
            }
            let prompts: Vec<_> = (0..need).map(|_| ctx.next_prompt()).collect();
            let groups = full_inference(ctx, prompts, self.n_total)?;
            for g in groups {
                ctx.counters.prompts_screened += 1;
                let p = g.pass_rate();
                if p > 0.0 && p < 1.0 {
                    ctx.counters.prompts_accepted += 1;
                    kept.push(g);
                }
            }
        }
        Ok(kept)
    }

    fn kind(&self) -> CurriculumKind {
        CurriculumKind::DapoFilter
    }
}

// ---------------------------------------------------------------------------
// SPEED (Algorithm 2)
// ---------------------------------------------------------------------------

/// The paper's method: two-phase inference with pre-fetching and a sampling
/// buffer.
///
/// KEEP IN SYNC with [`crate::coordinator::predictive::PredictiveSpeed`],
/// which mirrors this loop (plus a pre-screen gate); changes here must be
/// mirrored there or the `skip_confidence = 1.0` equivalence rail breaks.
pub struct Speed {
    pub rule: ScreeningRule,
    /// Per-prompt continuation-budget allocator (fixed by default).
    pub alloc: Allocator,
    pending: std::collections::VecDeque<crate::coordinator::batcher::PendingContinuation>,
    buffer: SamplingBuffer,
    /// Cap on (buffer + pending) in units of training batches before
    /// screening pauses; bounds off-policy staleness.
    pub backlog_batches: usize,
    /// Deferred posterior observations from a self-feeding allocator,
    /// merged into the shared store once per inference call (empty for the
    /// fixed allocator).
    alloc_delta: crate::predictor::ObservationDelta,
}

impl Speed {
    pub fn new(rule: ScreeningRule) -> Speed {
        Speed {
            rule,
            alloc: Allocator::fixed(rule),
            pending: std::collections::VecDeque::new(),
            buffer: SamplingBuffer::new(),
            backlog_batches: 4,
            alloc_delta: crate::predictor::ObservationDelta::default(),
        }
    }

    /// Bound the sampling buffer (oldest-first eviction past `cap` groups).
    pub fn with_buffer_cap(mut self, cap: usize) -> Speed {
        self.buffer = SamplingBuffer::new().with_max_len(cap);
        self
    }

    /// Choose continuation budgets with `alloc` instead of the fixed rule.
    pub fn with_allocator(mut self, alloc: Allocator) -> Speed {
        self.alloc = alloc;
        self
    }

    pub fn mean_staleness(&self) -> f64 {
        self.buffer.mean_staleness()
    }
}

impl Curriculum for Speed {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>> {
        // Batch accounting is in ROLLOUTS, not groups: per-prompt budgets
        // make group sizes heterogeneous, and what the compiled train step
        // consumes is rows. With the fixed allocator every group is exactly
        // `n_total` rollouts, so the target reduces to `batch_size` groups —
        // the pre-refactor semantics, bit for bit.
        let target_rows = batch_size * self.rule.n_total();
        loop {
            if let Some(batch) = self.buffer.take_rollouts(target_rows, ctx.train_step) {
                return Ok(batch);
            }
            // Algorithm 2 lines 4-14: one unified inference call mixing the
            // continuation phase of qualified prompts with the screening
            // phase of the next prompt wave.
            //
            // The backlog throttle is in ROLLOUT units, matching the batch
            // target: counting groups would let many small-budget groups
            // pause screening while the buffer still cannot fill one batch
            // (an empty-plan abort). When screening pauses the backlog
            // holds >= backlog_batches * target_rows, so with pending
            // drained the buffer alone always completes a batch. With the
            // fixed allocator every group is n_total rows and this reduces
            // to the old group-count condition exactly.
            let backlog_rows = self.buffer.rollout_rows()
                + crate::coordinator::batcher::pending_rows(&self.pending, self.rule.n_init);
            let screening_on = backlog_rows < self.backlog_batches * target_rows;
            let capacity = ctx.engine.rollout_capacity();
            let pending = &mut self.pending;
            let rule = self.rule;
            // The supply closure pulls straight from the prompt source.
            let prompts = &mut *ctx.prompts;
            let plan = plan_call(
                pending,
                || prompts.next_prompt(),
                &rule,
                capacity,
                if screening_on { usize::MAX } else { 0 },
            );
            anyhow::ensure!(
                !plan.requests.is_empty(),
                "SPEED planned an empty call (capacity {capacity}, N_init {}, N_cont {})",
                self.rule.n_init,
                self.rule.n_cont
            );
            let res = ctx.run_call(&plan.requests)?;

            let mut cont_iter = plan.continuations.into_iter();
            for ((req, purpose), rollouts) in
                plan.requests.into_iter().zip(plan.purposes).zip(res.groups)
            {
                match purpose {
                    Purpose::Screen => {
                        ctx.counters.prompts_screened += 1;
                        let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
                        if self.rule.qualified(&rewards) {
                            ctx.counters.prompts_accepted += 1;
                            let allocation =
                                self.alloc.allocate(&req.task, &rewards, &mut self.alloc_delta);
                            ctx.counters.record_allocation(allocation.budget.n_cont);
                            self.pending.push_back(
                                crate::coordinator::batcher::PendingContinuation {
                                    prompt_idx: req.prompt_idx,
                                    task: req.task,
                                    screening: rollouts,
                                    born_step: ctx.train_step,
                                    n_cont: allocation.budget.n_cont,
                                    forecast_var: allocation.forecast_var,
                                },
                            );
                        }
                        // Unqualified prompts are dropped here: their would-be
                        // N_cont continuation rollouts are the compute SPEED
                        // saves relative to full inference.
                    }
                    Purpose::Continue => {
                        let pend = cont_iter.next().expect("continuation bookkeeping");
                        let mut all = pend.screening;
                        all.extend(rollouts);
                        debug_assert_eq!(all.len(), self.rule.n_init + pend.n_cont);
                        let group = PromptGroup {
                            prompt_idx: req.prompt_idx,
                            task: req.task,
                            rollouts: all,
                        };
                        ctx.counters.record_alloc_outcome(pend.forecast_var, group.pass_rate());
                        self.buffer.push(group, pend.born_step);
                    }
                }
            }
            // One sharded-store merge per call for a self-feeding adaptive
            // allocator (no-op under the fixed allocator), so the budgets
            // pricing the next wave see this call's screening outcomes.
            self.alloc.flush(&mut self.alloc_delta);
        }
    }

    fn kind(&self) -> CurriculumKind {
        CurriculumKind::Speed
    }

    fn buffered(&self) -> usize {
        self.buffer.len()
    }

    fn mean_staleness(&self) -> f64 {
        self.buffer.mean_staleness()
    }

    fn state_json(&self) -> Option<Json> {
        // The quiesce protocol guarantees no unflushed observations at
        // snapshot time: `collect_batch` flushes the allocator delta at the
        // end of every inference call, so between batches it is empty.
        debug_assert!(
            self.alloc_delta.is_empty(),
            "SPEED snapshot with unflushed allocator observations"
        );
        Some(Json::obj(vec![
            ("buffer", crate::checkpoint::buffer_state_to_json(&self.buffer.state())),
            (
                "pending",
                Json::arr(self.pending.iter().map(crate::checkpoint::pending_to_json)),
            ),
        ]))
    }

    fn restore_state_json(&mut self, state: &Json) -> Result<()> {
        if let Some(b) = state.get("buffer") {
            self.buffer.restore(crate::checkpoint::buffer_state_from_json(b)?);
        }
        self.pending = state
            .get("pending")
            .and_then(|x| x.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(crate::checkpoint::pending_from_json)
            .collect::<Result<_>>()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Variance-max baseline (Foster & Foerster 2025)
// ---------------------------------------------------------------------------

/// Full inference on `pool_factor * B` prompts; train on the top-B by
/// reward variance p(1-p).
pub struct VarianceMax {
    pub n_total: usize,
    pub pool_factor: usize,
}

impl Curriculum for VarianceMax {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>> {
        let pool_size = batch_size * self.pool_factor.max(1);
        let prompts: Vec<_> = (0..pool_size).map(|_| ctx.next_prompt()).collect();
        let mut groups = full_inference(ctx, prompts, self.n_total)?;
        ctx.counters.prompts_screened += groups.len() as u64;
        groups.sort_by(|a, b| {
            let va = a.pass_rate() * (1.0 - a.pass_rate());
            let vb = b.pass_rate() * (1.0 - b.pass_rate());
            vb.partial_cmp(&va).unwrap()
        });
        groups.truncate(batch_size);
        ctx.counters.prompts_accepted += groups.len() as u64;
        Ok(groups)
    }

    fn kind(&self) -> CurriculumKind {
        CurriculumKind::VarianceMax
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_all_kinds() {
        for kind in CurriculumKind::ALL {
            assert_eq!(CurriculumKind::parse(kind.name()), Some(kind));
            assert_eq!(CurriculumKind::parse_or_err(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn parse_error_lists_every_valid_name() {
        let err = CurriculumKind::parse_or_err("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"));
        for kind in CurriculumKind::ALL {
            assert!(err.contains(kind.name()), "error must list '{}': {err}", kind.name());
        }
    }

    #[test]
    fn spec_builds_every_kind() {
        for kind in CurriculumKind::ALL {
            let rule = ScreeningRule::new(4, 8);
            let spec = CurriculumSpec {
                kind,
                rule,
                alloc: Allocator::fixed(rule),
                pool_factor: 2,
                buffer_cap: usize::MAX,
                predictor: None,
            };
            assert_eq!(spec.build().kind(), kind);
            assert_eq!(CurriculumSpec::fixed(kind, rule).build().kind(), kind);
        }
    }

    #[test]
    fn spec_carries_the_allocator_into_speed() {
        let rule = ScreeningRule::new(4, 8);
        let mut spec = CurriculumSpec::fixed(CurriculumKind::Speed, rule);
        spec.alloc = Allocator::adaptive(rule, 2, 16, None, false);
        // Build succeeds and the curriculum reports its kind; allocation
        // behaviour itself is covered by the alloc/batcher tests and the
        // integration rails in rust/tests/alloc_sim.rs.
        assert_eq!(spec.build().kind(), CurriculumKind::Speed);
    }
}
