//! The `predictive-speed` curriculum: SPEED's Algorithm 2 with a learned
//! pre-screen in front of the screening phase.
//!
//! Identical to [`crate::coordinator::curriculum::Speed`] — unified
//! continuation + screening calls through the pre-fetch batcher, sampling
//! buffer, backlog throttle — except that every candidate prompt is first
//! priced by the shared [`Predictor`]. When the posterior predictive puts
//! `skip_confidence` mass on screening *rejecting* the prompt, the
//! `N_init` screening rollouts are not spent at all: the prompt is dropped
//! before inference, the saved rows are counted, and the loop pulls the
//! next candidate. Confident skips are re-measured with probability
//! `explore_rate` (plus an unconditional safety valve after a long skip
//! run), and every realized screening outcome is scored against the
//! forecast that gated it (Brier + skip-decision confusion counts in
//! [`crate::metrics::InferenceCounters`]).
//!
//! With `skip_confidence = 1.0` the predictor never fires and this
//! curriculum reproduces `Speed`'s batch stream exactly (the equivalence
//! rail asserted in `rust/tests/predictor_sim.rs`).
//!
//! KEEP IN SYNC with [`Speed::collect_batch`]: the loop below deliberately
//! mirrors the reference implementation line for line (backlog throttle,
//! plan/route structure, continuation merge) rather than threading predictor
//! hooks through `Speed` — the reference path stays hook-free, at the price
//! that a change to either loop must be mirrored in the other or the
//! `skip_confidence = 1.0` equivalence rail breaks (the test above catches
//! divergence).
//!
//! [`Speed::collect_batch`]: crate::coordinator::curriculum::Speed

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::alloc::Allocator;
use crate::coordinator::batcher::{plan_call, PendingContinuation, Purpose};
use crate::coordinator::buffer::SamplingBuffer;
use crate::coordinator::curriculum::{Curriculum, CurriculumKind, StepContext};
use crate::coordinator::screening::ScreeningRule;
use crate::predictor::{Decision, ObservationDelta, Prediction, Predictor};
use crate::rl::update::PromptGroup;
use crate::util::rng::Rng;

/// Safety valve: after this many *consecutive* skips within one prompt
/// request, the next candidate is screened unconditionally, so a
/// miscalibrated predictor (or a dataset the model has fully saturated)
/// cannot stall the supply loop.
const MAX_CONSECUTIVE_SKIPS: usize = 512;

/// A forecast issued when a screening request entered the call plan; popped
/// in request order when the rollouts come back and scored against the
/// realized accept/reject decision.
struct Ticket {
    prediction: Prediction,
}

pub struct PredictiveSpeed {
    pub rule: ScreeningRule,
    /// Per-prompt continuation-budget allocator (fixed by default). The
    /// adaptive allocator here prices from the same shared posterior the
    /// pre-screen uses — the curriculum already observes every outcome, so
    /// the allocator must NOT feed the store itself.
    pub alloc: Allocator,
    predictor: Arc<Predictor>,
    pending: VecDeque<PendingContinuation>,
    buffer: SamplingBuffer,
    /// Cap on (buffer + pending) in units of training batches before
    /// screening pauses; bounds off-policy staleness (as in `Speed`).
    pub backlog_batches: usize,
    /// Exploration stream; consumed only when the skip rule fires, so with
    /// skipping disabled the curriculum is RNG-silent.
    rng: Rng,
    /// Worker-local pending posterior observations, merged into the shared
    /// store once per inference call instead of per observed group (the
    /// sharded lock is taken at most once per shard per flush — mirrors the
    /// `AtomicCounters` merge; ROADMAP item).
    delta: ObservationDelta,
}

impl PredictiveSpeed {
    pub fn new(rule: ScreeningRule, predictor: Arc<Predictor>) -> PredictiveSpeed {
        let rng = Rng::new(predictor.instance_seed() ^ 0x9d1c_7a5e_55ed_5e1f);
        PredictiveSpeed {
            rule,
            alloc: Allocator::fixed(rule),
            predictor,
            pending: VecDeque::new(),
            buffer: SamplingBuffer::new(),
            backlog_batches: 4,
            rng,
            delta: ObservationDelta::default(),
        }
    }

    /// Bound the sampling buffer (oldest-first eviction past `cap` groups).
    pub fn with_buffer_cap(mut self, cap: usize) -> PredictiveSpeed {
        self.buffer = SamplingBuffer::new().with_max_len(cap);
        self
    }

    /// Choose continuation budgets with `alloc` instead of the fixed rule.
    pub fn with_allocator(mut self, alloc: Allocator) -> PredictiveSpeed {
        self.alloc = alloc;
        self
    }

    /// The shared difficulty predictor (one per run; all workers' instances
    /// observe into it).
    pub fn predictor(&self) -> &Arc<Predictor> {
        &self.predictor
    }
}

impl Curriculum for PredictiveSpeed {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>> {
        // Rollout-target batch accounting, mirroring `Speed` (with the
        // fixed allocator this is exactly `batch_size` groups).
        let target_rows = batch_size * self.rule.n_total();
        loop {
            if let Some(batch) = self.buffer.take_rollouts(target_rows, ctx.train_step) {
                return Ok(batch);
            }
            // Rollout-unit backlog throttle, mirroring `Speed` (see the
            // comment there; group counts would mis-throttle under
            // variable budgets).
            let backlog_rows = self.buffer.rollout_rows()
                + crate::coordinator::batcher::pending_rows(&self.pending, self.rule.n_init);
            let screening_on = backlog_rows < self.backlog_batches * target_rows;
            let capacity = ctx.engine.rollout_capacity();
            let rule = self.rule;
            let n_init = rule.n_init as u64;
            let mut tickets: VecDeque<Ticket> = VecDeque::new();
            let plan = {
                let pending = &mut self.pending;
                let predictor = &self.predictor;
                let rng = &mut self.rng;
                let prompts = &mut *ctx.prompts;
                let counters = &mut *ctx.counters;
                let tickets = &mut tickets;
                plan_call(
                    pending,
                    // The pre-screen: pull candidates until one is worth
                    // spending N_init rollouts on.
                    || {
                        let mut skip_run = 0usize;
                        loop {
                            let (idx, task) = prompts.next_prompt();
                            let decision = predictor.decide(&task, rng);
                            let prediction = match decision {
                                Decision::Skip(_) if skip_run < MAX_CONSECUTIVE_SKIPS => {
                                    skip_run += 1;
                                    counters.prompts_skipped += 1;
                                    counters.rollouts_saved += n_init;
                                    continue;
                                }
                                // Safety valve: forced re-measure.
                                Decision::Skip(p) | Decision::Explore(p) => {
                                    counters.prompts_explored += 1;
                                    p
                                }
                                Decision::Screen(p) => p,
                            };
                            tickets.push_back(Ticket { prediction });
                            return (idx, task);
                        }
                    },
                    &rule,
                    capacity,
                    if screening_on { usize::MAX } else { 0 },
                )
            };
            anyhow::ensure!(
                !plan.requests.is_empty(),
                "predictive-speed planned an empty call (capacity {capacity}, N_init {}, N_cont {})",
                self.rule.n_init,
                self.rule.n_cont
            );
            let res = ctx.run_call(&plan.requests)?;

            let mut cont_iter = plan.continuations.into_iter();
            for ((req, purpose), rollouts) in
                plan.requests.into_iter().zip(plan.purposes).zip(res.groups)
            {
                match purpose {
                    Purpose::Screen => {
                        ctx.counters.prompts_screened += 1;
                        let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
                        let accepted = self.rule.qualified(&rewards);
                        // Score the forecast that let this prompt through:
                        // Brier on the acceptance probability, and the
                        // skip-decision confusion counts (positive class =
                        // "the skip rule would have fired").
                        let ticket = tickets.pop_front().expect("one ticket per screening row");
                        let err =
                            ticket.prediction.accept_prob - if accepted { 1.0 } else { 0.0 };
                        ctx.counters.brier_sum += err * err;
                        ctx.counters.brier_n += 1;
                        match (ticket.prediction.would_skip, !accepted) {
                            (true, true) => ctx.counters.pred_tp += 1,
                            (true, false) => ctx.counters.pred_fp += 1,
                            (false, true) => ctx.counters.pred_fn += 1,
                            (false, false) => ctx.counters.pred_tn += 1,
                        }
                        self.predictor.observe_screening_deferred(
                            &req.task,
                            &rewards,
                            &mut self.delta,
                        );
                        if accepted {
                            ctx.counters.prompts_accepted += 1;
                            // The allocator shares this curriculum's
                            // predictor and never feeds it (the screening
                            // observation above already covers it), so the
                            // delta it receives stays untouched.
                            let allocation =
                                self.alloc.allocate(&req.task, &rewards, &mut self.delta);
                            ctx.counters.record_allocation(allocation.budget.n_cont);
                            self.pending.push_back(PendingContinuation {
                                prompt_idx: req.prompt_idx,
                                task: req.task,
                                screening: rollouts,
                                born_step: ctx.train_step,
                                n_cont: allocation.budget.n_cont,
                                forecast_var: allocation.forecast_var,
                            });
                        }
                    }
                    Purpose::Continue => {
                        let pend = cont_iter.next().expect("continuation bookkeeping");
                        let cont_rewards: Vec<f32> =
                            rollouts.iter().map(|r| r.reward).collect();
                        // Continuation rows (and with them the whole
                        // training group) feed the posterior too.
                        self.predictor.observe_rollouts_deferred(
                            &req.task,
                            &cont_rewards,
                            &mut self.delta,
                        );
                        let mut all = pend.screening;
                        all.extend(rollouts);
                        debug_assert_eq!(all.len(), self.rule.n_init + pend.n_cont);
                        let group = PromptGroup {
                            prompt_idx: req.prompt_idx,
                            task: req.task,
                            rollouts: all,
                        };
                        ctx.counters.record_alloc_outcome(pend.forecast_var, group.pass_rate());
                        self.buffer.push(group, pend.born_step);
                    }
                }
            }
            // One sharded-store merge per call, before the next plan, so
            // the decisions pricing the next wave see this call's
            // observations — exactly when the immediate path made them
            // visible (observations always landed between result
            // processing and the next plan; predictions never happen
            // mid-processing).
            self.predictor.flush(&mut self.delta);
        }
    }

    fn kind(&self) -> CurriculumKind {
        CurriculumKind::PredictiveSpeed
    }

    fn buffered(&self) -> usize {
        self.buffer.len()
    }

    fn mean_staleness(&self) -> f64 {
        self.buffer.mean_staleness()
    }

    fn state_json(&self) -> Option<crate::util::json::Json> {
        use crate::util::json::Json;
        // Quiesce protocol: `collect_batch` flushes the observation delta
        // at the end of every inference call, so between batches (the only
        // legal snapshot point) nothing is pending.
        debug_assert!(
            self.delta.is_empty(),
            "predictive-speed snapshot with unflushed observations"
        );
        Some(Json::obj(vec![
            ("buffer", crate::checkpoint::buffer_state_to_json(&self.buffer.state())),
            (
                "pending",
                Json::arr(self.pending.iter().map(crate::checkpoint::pending_to_json)),
            ),
            ("rng", crate::checkpoint::rng_state_to_json(self.rng.state())),
        ]))
    }

    fn restore_state_json(&mut self, state: &crate::util::json::Json) -> Result<()> {
        if let Some(b) = state.get("buffer") {
            self.buffer.restore(crate::checkpoint::buffer_state_from_json(b)?);
        }
        self.pending = state
            .get("pending")
            .and_then(|x| x.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(crate::checkpoint::pending_from_json)
            .collect::<Result<_>>()?;
        if let Some(rng_state) = state.get("rng") {
            self.rng = Rng::from_state(crate::checkpoint::rng_state_from_json(rng_state)?);
        }
        Ok(())
    }
}
