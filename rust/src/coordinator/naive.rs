//! The *naive* two-phase variant (paper §4.3's strawman): screening and
//! continuation issued as **separate** inference calls, no pre-fetching, no
//! sampling buffer. Paper's point: this realizes little wall-clock gain
//! because each half-empty call still pays the engine overhead — the
//! pre-fetch batcher is what converts screening into actual speedup.
//! Kept as a first-class ablation (`--curriculum speed-naive`).

use anyhow::Result;

use crate::coordinator::curriculum::{Curriculum, CurriculumKind, StepContext};
use crate::coordinator::screening::ScreeningRule;
use crate::policy::GenRequest;
use crate::rl::update::PromptGroup;

pub struct SpeedNaive {
    pub rule: ScreeningRule,
}

impl SpeedNaive {
    pub fn new(rule: ScreeningRule) -> SpeedNaive {
        SpeedNaive { rule }
    }
}

impl Curriculum for SpeedNaive {
    fn collect_batch(
        &mut self,
        ctx: &mut StepContext<'_>,
        batch_size: usize,
    ) -> Result<Vec<PromptGroup>> {
        let capacity = ctx.engine.rollout_capacity();
        let mut qualified: Vec<(GenRequest, Vec<crate::rl::update::Rollout>)> = Vec::new();

        // Phase 1: screening calls until enough prompts qualify.
        while qualified.len() < batch_size {
            let per_call = capacity / self.rule.n_init;
            let requests: Vec<GenRequest> = (0..per_call)
                .map(|_| {
                    let (idx, task) = ctx.next_prompt();
                    GenRequest { prompt_idx: idx, task, n_samples: self.rule.n_init }
                })
                .collect();
            let res = ctx.run_call(&requests)?;
            for (req, rollouts) in requests.into_iter().zip(res.groups) {
                ctx.counters.prompts_screened += 1;
                let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
                if self.rule.qualified(&rewards) {
                    ctx.counters.prompts_accepted += 1;
                    qualified.push((req, rollouts));
                }
            }
        }
        qualified.truncate(batch_size);

        // Phase 2: a separate continuation call per wave of qualified
        // prompts (the second engine invocation the paper's batcher avoids).
        let per_call = capacity / self.rule.n_cont;
        let mut groups = Vec::with_capacity(batch_size);
        for wave in qualified.chunks(per_call) {
            let requests: Vec<GenRequest> = wave
                .iter()
                .map(|(req, _)| GenRequest {
                    prompt_idx: req.prompt_idx,
                    task: req.task.clone(),
                    n_samples: self.rule.n_cont,
                })
                .collect();
            let res = ctx.run_call(&requests)?;
            for ((req, screening), cont) in wave.iter().zip(res.groups) {
                let mut all = screening.clone();
                all.extend(cont);
                groups.push(PromptGroup {
                    prompt_idx: req.prompt_idx,
                    task: req.task.clone(),
                    rollouts: all,
                });
            }
        }
        Ok(groups)
    }

    fn kind(&self) -> CurriculumKind {
        CurriculumKind::SpeedNaive
    }
}
