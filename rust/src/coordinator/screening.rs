//! The screening phase (paper §4.1): a lightweight statistical test on
//! `N_init` rollouts that decides whether a prompt's difficulty is in the
//! informative band before any continuation compute is spent.

use crate::rl::advantage::pass_rate;

/// Pass-rate acceptance test. Paper defaults: `P_low = 0`, `P_high = 1`
/// (strict inequalities — Algorithm 1 line 7: `0 < PASSRATE(x) < 1`).
#[derive(Clone, Copy, Debug)]
pub struct ScreeningRule {
    pub n_init: usize,
    pub n_cont: usize,
    pub p_low: f64,
    pub p_high: f64,
}

impl ScreeningRule {
    /// Paper's default thresholds with the given split.
    pub fn new(n_init: usize, n_cont: usize) -> ScreeningRule {
        ScreeningRule { n_init, n_cont, p_low: 0.0, p_high: 1.0 }
    }

    pub fn with_thresholds(mut self, p_low: f64, p_high: f64) -> ScreeningRule {
        self.p_low = p_low;
        self.p_high = p_high;
        self
    }

    /// Total rollouts per qualified prompt.
    pub fn n_total(&self) -> usize {
        self.n_init + self.n_cont
    }

    /// The screening decision (Algorithm 1 line 7 / Algorithm 2 line 14).
    pub fn qualified(&self, screening_rewards: &[f32]) -> bool {
        debug_assert_eq!(screening_rewards.len(), self.n_init);
        let p = pass_rate(screening_rewards);
        p > self.p_low && p < self.p_high
    }

    /// Probability a prompt with true pass rate `p` survives screening
    /// (used by the simulator and the Fig. 5 analysis).
    pub fn acceptance_probability(&self, p: f64) -> f64 {
        crate::rl::theory::acceptance_probability(self.n_init, p, self.p_low, self.p_high)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::prop_assert;

    #[test]
    fn strict_bounds_default() {
        let rule = ScreeningRule::new(4, 20);
        assert!(!rule.qualified(&[0.0, 0.0, 0.0, 0.0]));
        assert!(!rule.qualified(&[1.0, 1.0, 1.0, 1.0]));
        assert!(rule.qualified(&[1.0, 0.0, 0.0, 0.0]));
        assert!(rule.qualified(&[1.0, 1.0, 1.0, 0.0]));
        assert_eq!(rule.n_total(), 24);
    }

    #[test]
    fn custom_thresholds() {
        // e.g. only the 25%-75% band (strict inequalities at both ends)
        let rule = ScreeningRule::new(8, 16).with_thresholds(0.25, 0.75);
        assert!(!rule.qualified(&[1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])); // 0.25 not > 0.25
        assert!(rule.qualified(&[1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0])); // 0.375
        assert!(!rule.qualified(&[1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0])); // 0.75 not < 0.75
    }

    #[test]
    fn acceptance_probability_boundaries_under_strict_band() {
        // p = 0 and p = 1 are exactly the uninformative extremes: every
        // realized screening slice is uniform, so the default strict band
        // must reject with certainty — for any split.
        for n_init in [1usize, 2, 4, 8, 50] {
            let rule = ScreeningRule::new(n_init, 16);
            assert_eq!(rule.acceptance_probability(0.0), 0.0, "p=0, n_init={n_init}");
            assert_eq!(rule.acceptance_probability(1.0), 0.0, "p=1, n_init={n_init}");
        }
    }

    #[test]
    fn n_init_one_never_qualifies_under_strict_band() {
        // With a single screening rollout the realized pass rate is 0 or 1,
        // both outside the strict (0, 1) band: acceptance is identically 0.
        let rule = ScreeningRule::new(1, 16);
        assert!(!rule.qualified(&[0.0]));
        assert!(!rule.qualified(&[1.0]));
        for p in [0.0, 0.1, 0.5, 0.9, 1.0] {
            assert_eq!(rule.acceptance_probability(p), 0.0, "p={p}");
        }
        // A non-strict band makes n_init = 1 usable again: rates {0, 1}
        // fall inside (-eps, 1+eps)-style wide bands.
        let wide = ScreeningRule::new(1, 16).with_thresholds(-0.5, 1.5);
        assert!(wide.qualified(&[0.0]));
        assert!(wide.qualified(&[1.0]));
        assert!((wide.acceptance_probability(0.5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn acceptance_probability_consistent_with_qualified() {
        // Monte-Carlo frequency of `qualified` must match the closed form.
        check("screening-acceptance-mc", 10, |rng| {
            let n_init = rng.range_usize(3, 8);
            let p = rng.f64();
            let rule = ScreeningRule::new(n_init, 8);
            let trials = 4000;
            let mut hits = 0;
            for _ in 0..trials {
                let rewards: Vec<f32> =
                    (0..n_init).map(|_| if rng.bool(p) { 1.0 } else { 0.0 }).collect();
                if rule.qualified(&rewards) {
                    hits += 1;
                }
            }
            let freq = hits as f64 / trials as f64;
            let expect = rule.acceptance_probability(p);
            prop_assert!(
                (freq - expect).abs() < 0.05,
                "freq {freq} vs closed-form {expect} (p={p}, n_init={n_init})"
            );
            Ok(())
        });
    }
}
