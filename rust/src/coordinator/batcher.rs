//! The pre-fetch batcher (paper §4.3): one fixed-shape inference call packs
//! the *continuation* rows of already-qualified prompts together with the
//! *screening* rows of the next wave of prompts. This is what turns the
//! two-phase scheme into a single engine invocation per cycle instead of
//! two (and is where SPEED's wall-clock win over naive screening comes
//! from).
//!
//! `capacity` is whatever the engine handle advertises: the full compiled
//! row count when a worker owns a private engine, or the *submit quantum*
//! (engine capacity / K) when workers produce requests for the shared
//! coalescing [`InferenceService`] — the service then merges K such plans
//! into one maximally-packed engine call, applying this same
//! continuations-then-screening packing idea across workers.
//!
//! [`InferenceService`]: crate::policy::service::InferenceService

use std::collections::VecDeque;

use crate::coordinator::screening::ScreeningRule;
use crate::data::tasks::TaskInstance;
use crate::policy::GenRequest;

/// Why a request is in the call (drives result routing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Purpose {
    Screen,
    Continue,
}

/// One planned inference call.
#[derive(Debug)]
pub struct CallPlan {
    pub requests: Vec<GenRequest>,
    pub purposes: Vec<Purpose>,
    /// The pending entries consumed by this plan, in the same order as the
    /// `Purpose::Continue` requests (their screening rollouts get merged
    /// with the continuation results).
    pub continuations: Vec<PendingContinuation>,
    pub rows_used: usize,
    pub capacity: usize,
}

impl CallPlan {
    pub fn utilization(&self) -> f64 {
        self.rows_used as f64 / self.capacity as f64
    }

    pub fn n_screen(&self) -> usize {
        self.purposes.iter().filter(|p| **p == Purpose::Screen).count()
    }

    pub fn n_continue(&self) -> usize {
        self.purposes.iter().filter(|p| **p == Purpose::Continue).count()
    }
}

/// A prompt that passed screening and awaits its continuation rollouts.
#[derive(Clone, Debug)]
pub struct PendingContinuation {
    pub prompt_idx: usize,
    pub task: TaskInstance,
    /// Screening rollouts to be merged with the continuation ones.
    pub screening: Vec<crate::rl::update::Rollout>,
    pub born_step: usize,
    /// Continuation rows this prompt was allocated (the per-prompt budget
    /// chosen by [`crate::coordinator::alloc::Allocator`]; the fixed
    /// allocator pins it to the rule's `n_cont`).
    pub n_cont: usize,
    /// Forecast reward variance behind the allocation (scored against the
    /// realized group variance when the continuation completes).
    pub forecast_var: f64,
}

/// Rollout rows the pending queue represents (the `n_init` screening rows
/// each entry already holds plus its allocated continuation budget) — the
/// pending half of the SPEED curricula's rollout-unit backlog throttle.
/// Shared by `Speed` and `PredictiveSpeed` so the two mirrored loops
/// cannot drift on what "backlog" means.
pub fn pending_rows(pending: &VecDeque<PendingContinuation>, n_init: usize) -> usize {
    pending.iter().map(|p| n_init + p.n_cont).sum()
}

/// Pack the next inference call: continuations first (they complete groups
/// and unblock training), then screening rows for fresh prompts from
/// `supply` until the call is full.
///
/// `max_screen` caps how many new prompts are screened in this call (used
/// to stop pulling data when the buffer already overflows the target batch;
/// `usize::MAX` = fill the call).
pub fn plan_call(
    pending: &mut VecDeque<PendingContinuation>,
    mut supply: impl FnMut() -> (usize, TaskInstance),
    rule: &ScreeningRule,
    capacity: usize,
    max_screen: usize,
) -> CallPlan {
    assert!(rule.n_init <= capacity, "N_init exceeds call capacity");
    let mut requests = Vec::new();
    let mut purposes = Vec::new();
    let mut continuations = Vec::new();
    let mut rows = 0usize;

    // Phase A: continuation rows for previously-qualified prompts (FIFO).
    // Budgets vary per prompt, so each pending entry's own `n_cont` drives
    // the packing; the spill stays strictly FIFO — the first entry that
    // does not fit ends the phase, even if a smaller later entry would
    // (reordering would unbound a large-budget prompt's wait).
    while let Some(front) = pending.front() {
        assert!(front.n_cont <= capacity, "allocated N_cont exceeds call capacity");
        if rows + front.n_cont > capacity {
            break;
        }
        let p = pending.pop_front().unwrap();
        requests.push(GenRequest {
            prompt_idx: p.prompt_idx,
            task: p.task.clone(),
            n_samples: p.n_cont,
        });
        purposes.push(Purpose::Continue);
        rows += p.n_cont;
        continuations.push(p);
    }

    // Phase B: screening rows for the next wave of prompts.
    let mut screened = 0usize;
    while rows + rule.n_init <= capacity && screened < max_screen {
        let (prompt_idx, task) = supply();
        requests.push(GenRequest { prompt_idx, task, n_samples: rule.n_init });
        purposes.push(Purpose::Screen);
        rows += rule.n_init;
        screened += 1;
    }

    CallPlan { requests, purposes, continuations, rows_used: rows, capacity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, TaskFamily};
    use crate::rl::update::Rollout;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;
    use crate::prop_assert;

    fn task(rng: &mut Rng) -> TaskInstance {
        generate(rng, TaskFamily::Add, 3, 24)
    }

    fn pend_with_budget(
        rng: &mut Rng,
        idx: usize,
        n_init: usize,
        n_cont: usize,
    ) -> PendingContinuation {
        PendingContinuation {
            prompt_idx: idx,
            task: task(rng),
            screening: vec![
                Rollout { gen_tokens: vec![2], gen_logprobs: vec![-0.2], reward: 1.0 };
                n_init
            ],
            born_step: 0,
            n_cont,
            forecast_var: 0.25,
        }
    }

    fn pend(rng: &mut Rng, idx: usize, rule: &ScreeningRule) -> PendingContinuation {
        // The fixed-budget shape: every pending carries the rule's n_cont.
        pend_with_budget(rng, idx, rule.n_init, rule.n_cont)
    }

    #[test]
    fn continuations_take_priority() {
        let mut rng = Rng::new(0);
        let rule = ScreeningRule::new(4, 12);
        let mut pending: VecDeque<_> = (0..2).map(|i| pend(&mut rng, i, &rule)).collect();
        let mut rng2 = Rng::new(1);
        let mut next = 100usize;
        let plan = plan_call(
            &mut pending,
            || {
                next += 1;
                (next, task(&mut rng2))
            },
            &rule,
            64,
            usize::MAX,
        );
        // 2 continuations (24 rows) + 10 screenings (40 rows) = 64 rows
        assert_eq!(plan.n_continue(), 2);
        assert_eq!(plan.n_screen(), 10);
        assert_eq!(plan.rows_used, 64);
        assert!(pending.is_empty());
        assert_eq!(plan.purposes[0], Purpose::Continue);
    }

    #[test]
    fn oversized_pending_spills_to_next_call() {
        let mut rng = Rng::new(3);
        let rule = ScreeningRule::new(8, 24);
        let mut pending: VecDeque<_> = (0..5).map(|i| pend(&mut rng, i, &rule)).collect();
        let mut rng2 = Rng::new(4);
        let plan = plan_call(&mut pending, || (0, task(&mut rng2)), &rule, 64, usize::MAX);
        // two continuations fit (48 rows), then screening fills 2x8 = 16
        assert_eq!(plan.n_continue(), 2);
        assert_eq!(plan.n_screen(), 2);
        assert_eq!(pending.len(), 3); // spilled
    }

    #[test]
    fn quantum_sized_plans_tile_the_engine_capacity() {
        // Workers submitting to the coalescing service plan against the
        // quantum (engine capacity / K): K such plans must always fit one
        // engine call, whatever mix of continuations/screenings each holds.
        let mut rng = Rng::new(7);
        let rule = ScreeningRule::new(8, 16);
        let (engine_capacity, k) = (384usize, 4usize);
        let quantum = engine_capacity / k;
        let mut total = 0usize;
        for w in 0..k {
            let mut pending: VecDeque<_> = (0..w).map(|i| pend(&mut rng, i, &rule)).collect();
            let mut rng2 = Rng::new(w as u64);
            let plan = plan_call(&mut pending, || (0, task(&mut rng2)), &rule, quantum, usize::MAX);
            assert!(plan.rows_used <= quantum);
            total += plan.rows_used;
        }
        assert!(total <= engine_capacity, "{k} quantum plans overflow the engine call");
    }

    #[test]
    fn max_screen_zero_disables_prefetch() {
        let mut rng = Rng::new(5);
        let rule = ScreeningRule::new(4, 12);
        let mut pending: VecDeque<_> = vec![pend(&mut rng, 0, &rule)].into();
        let mut rng2 = Rng::new(6);
        let plan = plan_call(&mut pending, || (0, task(&mut rng2)), &rule, 64, 0);
        assert_eq!(plan.n_continue(), 1);
        assert_eq!(plan.n_screen(), 0);
        assert_eq!(plan.rows_used, 12);
    }

    #[test]
    fn variable_budgets_pack_and_spill_fifo() {
        let mut rng = Rng::new(21);
        let rule = ScreeningRule::new(4, 16);
        // Budgets 20 + 30 fit a 56-row call with one 4-row screening; the
        // 40-budget third entry spills even though a later 8 would fit.
        let mut pending: VecDeque<_> = [20usize, 30, 40, 8]
            .iter()
            .enumerate()
            .map(|(i, &n_cont)| pend_with_budget(&mut rng, i, 4, n_cont))
            .collect();
        let mut rng2 = Rng::new(22);
        let plan = plan_call(&mut pending, || (0, task(&mut rng2)), &rule, 56, usize::MAX);
        assert_eq!(plan.n_continue(), 2, "FIFO spill must stop at the first misfit");
        assert_eq!(pending.len(), 2);
        assert_eq!(pending.front().unwrap().n_cont, 40);
        assert_eq!(plan.n_screen(), 1); // 20 + 30 + 4 = 54, one screen fits
        assert_eq!(plan.rows_used, 54);
        assert_eq!(plan.requests[0].n_samples, 20);
        assert_eq!(plan.requests[1].n_samples, 30);
    }

    #[test]
    fn variable_budget_packing_invariants() {
        // The satellite property test: heterogeneous budgets never
        // overflow capacity and continuations always precede screenings.
        check("batcher-variable-budgets", 120, |rng| {
            let n_init = rng.range_usize(2, 8);
            let n_cont_max = rng.range_usize(4, 40);
            let capacity = rng.range_usize(n_init.max(n_cont_max), 128);
            let rule = ScreeningRule::new(n_init, n_cont_max);
            let n_pending = rng.range_usize(0, 8);
            let mut seed_rng = Rng::new(rng.next_u64());
            let budgets: Vec<usize> =
                (0..n_pending).map(|_| rng.range_usize(1, n_cont_max)).collect();
            let mut pending: VecDeque<_> = budgets
                .iter()
                .enumerate()
                .map(|(i, &b)| pend_with_budget(&mut seed_rng, i, n_init, b))
                .collect();
            let mut supply_rng = Rng::new(rng.next_u64());
            let before = pending.len();
            let plan =
                plan_call(&mut pending, || (9, task(&mut supply_rng)), &rule, capacity, usize::MAX);
            let rows: usize = plan.requests.iter().map(|r| r.n_samples).sum();
            prop_assert!(rows == plan.rows_used, "row accounting mismatch");
            prop_assert!(plan.rows_used <= capacity, "over capacity");
            // each continuation request carries its pending's own budget
            let mut cont_idx = 0usize;
            for (req, purpose) in plan.requests.iter().zip(&plan.purposes) {
                if *purpose == Purpose::Continue {
                    prop_assert!(
                        req.n_samples == plan.continuations[cont_idx].n_cont,
                        "budget lost in the plan"
                    );
                    cont_idx += 1;
                }
            }
            prop_assert!(cont_idx == plan.continuations.len(), "continuation bookkeeping");
            // FIFO spill: taken continuations are exactly the longest
            // prefix of the original queue that fits
            prop_assert!(plan.n_continue() == before - pending.len(), "pending accounting");
            let mut prefix_rows = 0usize;
            let mut prefix = 0usize;
            for b in &budgets {
                if prefix_rows + b > capacity {
                    break;
                }
                prefix_rows += b;
                prefix += 1;
            }
            prop_assert!(plan.n_continue() == prefix, "spill not FIFO-prefix");
            // all continuations precede all screenings
            let first_screen = plan.purposes.iter().position(|p| *p == Purpose::Screen);
            if let Some(fs) = first_screen {
                prop_assert!(
                    plan.purposes[fs..].iter().all(|p| *p == Purpose::Screen),
                    "interleaved purposes"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn packing_invariants() {
        check("batcher-packing", 80, |rng| {
            let n_init = rng.range_usize(2, 8);
            let n_cont = rng.range_usize(4, 24);
            let capacity = rng.range_usize(n_init.max(n_cont), 96);
            let rule = ScreeningRule::new(n_init, n_cont);
            let n_pending = rng.range_usize(0, 6);
            let mut seed_rng = Rng::new(rng.next_u64());
            let mut pending: VecDeque<_> =
                (0..n_pending).map(|i| pend(&mut seed_rng, i, &rule)).collect();
            let mut supply_rng = Rng::new(rng.next_u64());
            let before = pending.len();
            let plan = plan_call(&mut pending, || (7, task(&mut supply_rng)), &rule, capacity, usize::MAX);
            // rows accounting is exact
            let rows: usize = plan.requests.iter().map(|r| r.n_samples).sum();
            prop_assert!(rows == plan.rows_used, "row accounting mismatch");
            prop_assert!(plan.rows_used <= capacity, "over capacity");
            // no screening row could have been added
            prop_assert!(
                plan.rows_used + n_init > capacity,
                "call left unfilled: {} + {} <= {}",
                plan.rows_used,
                n_init,
                capacity
            );
            // continuations consumed FIFO from the front
            prop_assert!(plan.n_continue() == before - pending.len(), "pending accounting");
            // all continuations precede all screenings
            let first_screen = plan.purposes.iter().position(|p| *p == Purpose::Screen);
            if let Some(fs) = first_screen {
                prop_assert!(
                    plan.purposes[fs..].iter().all(|p| *p == Purpose::Screen),
                    "interleaved purposes"
                );
            }
            Ok(())
        });
    }
}
