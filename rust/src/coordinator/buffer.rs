//! The sampling buffer (paper §4.3, Algorithm 2's `D_buffer`).
//!
//! The number of qualified prompts per inference call fluctuates with the
//! live pass-rate distribution; the buffer absorbs the surplus so every
//! training step sees exactly `B` groups, at the price of a bounded amount
//! of off-policy staleness (tracked per group for diagnostics).

use std::collections::VecDeque;

use crate::rl::update::PromptGroup;

/// A completed group waiting for a training slot.
#[derive(Clone, Debug)]
struct Buffered {
    group: PromptGroup,
    /// Optimizer step at which the group's rollouts were generated.
    born_step: usize,
}

#[derive(Debug, Default)]
pub struct SamplingBuffer {
    q: VecDeque<Buffered>,
    /// Sum over consumed groups of (train_step - born_step); staleness
    /// diagnostic for the off-policy trade-off discussed in §4.3.
    staleness_sum: u64,
    consumed: u64,
}

impl SamplingBuffer {
    pub fn new() -> SamplingBuffer {
        SamplingBuffer::default()
    }

    pub fn push(&mut self, group: PromptGroup, born_step: usize) {
        self.q.push_back(Buffered { group, born_step });
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Pop exactly `b` groups (FIFO: oldest first, bounding staleness).
    /// Returns None when fewer than `b` are buffered — the caller keeps
    /// running inference (Alg. 2 line 4).
    pub fn take_batch(&mut self, b: usize, train_step: usize) -> Option<Vec<PromptGroup>> {
        if self.q.len() < b {
            return None;
        }
        let mut out = Vec::with_capacity(b);
        for _ in 0..b {
            let item = self.q.pop_front().unwrap();
            self.staleness_sum += (train_step.saturating_sub(item.born_step)) as u64;
            self.consumed += 1;
            out.push(item.group);
        }
        Some(out)
    }

    /// Mean steps-in-buffer over all consumed groups.
    pub fn mean_staleness(&self) -> f64 {
        if self.consumed == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.consumed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::update::Rollout;
    use crate::util::proptest::check;
    use crate::{prop_assert, prop_assert_eq};

    fn group(idx: usize) -> PromptGroup {
        PromptGroup {
            prompt_idx: idx,
            task: crate::data::tasks::TaskInstance {
                family: crate::data::tasks::TaskFamily::Add,
                level: 1,
                prompt: "1+1=".into(),
                answer: 2,
            },
            rollouts: vec![Rollout { gen_tokens: vec![2], gen_logprobs: vec![-0.1], reward: 1.0 }],
        }
    }

    #[test]
    fn returns_none_until_full_batch() {
        let mut buf = SamplingBuffer::new();
        buf.push(group(0), 0);
        assert!(buf.take_batch(2, 0).is_none());
        assert_eq!(buf.len(), 1); // nothing consumed by the failed take
        buf.push(group(1), 0);
        let batch = buf.take_batch(2, 1).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(buf.is_empty());
    }

    #[test]
    fn fifo_order_bounds_staleness() {
        let mut buf = SamplingBuffer::new();
        for i in 0..5 {
            buf.push(group(i), i);
        }
        let batch = buf.take_batch(3, 10).unwrap();
        let idxs: Vec<usize> = batch.iter().map(|g| g.prompt_idx).collect();
        assert_eq!(idxs, vec![0, 1, 2]); // oldest first
        assert!((buf.mean_staleness() - (10.0 + 9.0 + 8.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn conservation_property() {
        // pushes == pops + remaining, across random interleavings
        check("buffer-conservation", 50, |rng| {
            let mut buf = SamplingBuffer::new();
            let mut pushed = 0usize;
            let mut popped = 0usize;
            for step in 0..rng.range_usize(5, 40) {
                if rng.bool(0.6) {
                    buf.push(group(pushed), step);
                    pushed += 1;
                }
                if rng.bool(0.4) {
                    let b = rng.range_usize(1, 4);
                    if let Some(batch) = buf.take_batch(b, step) {
                        prop_assert_eq!(batch.len(), b);
                        popped += batch.len();
                    }
                }
            }
            prop_assert!(pushed == popped + buf.len(), "conservation violated");
            Ok(())
        });
    }
}
