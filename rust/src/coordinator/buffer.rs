//! The sampling buffer (paper §4.3, Algorithm 2's `D_buffer`).
//!
//! The number of qualified prompts per inference call fluctuates with the
//! live pass-rate distribution; the buffer absorbs the surplus so every
//! training step sees exactly `B` groups, at the price of a bounded amount
//! of off-policy staleness (tracked per group for diagnostics).
//!
//! Two flavors live here:
//!
//! * [`SamplingBuffer`] — the single-owner deque used by the serial SPEED
//!   curriculum, bounded by `max_len` with oldest-first eviction.
//! * [`SharedBuffer`]   — the `Mutex` + `Condvar` bounded queue between the
//!   pipelined coordinator's K rollout workers (producers) and the learner
//!   (consumer). Backpressure, not eviction: a full buffer blocks workers,
//!   which is what bounds staleness when inference outruns updates.

use std::collections::VecDeque;

use crate::rl::update::PromptGroup;
use crate::util::sync::{plock, pwait, SyncCondvar, SyncMutex};
use crate::warn_log;

/// A completed group waiting for a training slot.
#[derive(Clone, Debug)]
struct Buffered {
    group: PromptGroup,
    /// Optimizer step at which the group's rollouts were generated.
    born_step: usize,
}

/// The longest FIFO prefix of `sizes` (per-group rollout counts) that fits
/// `target_rows`: returns `(take, complete)`. The batch is `complete` when
/// the prefix meets the target exactly, when a queued group overflows it
/// (the batch is as full as FIFO order allows), or when the front group
/// alone exceeds the target — that misfit is taken by itself so the
/// downstream capacity check fails loudly instead of the supply loop
/// spinning forever. Shared by [`SamplingBuffer::take_rollouts`] and
/// [`SharedBuffer::pop_rollouts`] so the serial and pipelined paths can
/// never drift apart on this invariant.
fn rollout_prefix(sizes: impl Iterator<Item = usize>, target_rows: usize) -> (usize, bool) {
    let mut rows = 0usize;
    let mut take = 0usize;
    for n in sizes {
        if rows + n > target_rows {
            // A queued group overflows the remaining headroom: the batch
            // is as full as FIFO order allows (take = 0 is the oversized
            // front, taken alone).
            return (take.max(1), true);
        }
        rows += n;
        take += 1;
    }
    // Queue exhausted under the target: complete only on an exact hit.
    (take, rows == target_rows)
}

#[derive(Debug)]
pub struct SamplingBuffer {
    q: VecDeque<Buffered>,
    /// Capacity in groups; pushing past it evicts the oldest entry.
    max_len: usize,
    /// Sum over consumed groups of (train_step - born_step); staleness
    /// diagnostic for the off-policy trade-off discussed in §4.3.
    staleness_sum: u64,
    consumed: u64,
    evicted: u64,
}

impl Default for SamplingBuffer {
    fn default() -> Self {
        SamplingBuffer {
            q: VecDeque::new(),
            max_len: usize::MAX,
            staleness_sum: 0,
            consumed: 0,
            evicted: 0,
        }
    }
}

impl SamplingBuffer {
    pub fn new() -> SamplingBuffer {
        SamplingBuffer::default()
    }

    /// Bound the buffer to `max_len` groups (oldest-first eviction).
    pub fn with_max_len(mut self, max_len: usize) -> SamplingBuffer {
        self.max_len = max_len.max(1);
        self
    }

    pub fn push(&mut self, group: PromptGroup, born_step: usize) {
        if self.q.len() >= self.max_len {
            // Oldest-first eviction: the stalest group is the least
            // on-policy, so it is the right one to drop.
            let dropped = self.q.pop_front().expect("max_len >= 1");
            self.evicted += 1;
            warn_log!(
                "buffer",
                "evicted prompt {} born at step {} (cap {} reached; {} evictions total)",
                dropped.group.prompt_idx,
                dropped.born_step,
                self.max_len,
                self.evicted
            );
        }
        self.q.push_back(Buffered { group, born_step });
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Groups dropped by the eviction policy so far.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Pop exactly `b` groups (FIFO: oldest first, bounding staleness).
    /// Returns None when fewer than `b` are buffered — the caller keeps
    /// running inference (Alg. 2 line 4).
    ///
    /// Production paths batch by ROLLOUTS
    /// ([`take_rollouts`](Self::take_rollouts)); this group-counted take is
    /// the uniform-budget reference the equivalence tests compare against.
    pub fn take_batch(&mut self, b: usize, train_step: usize) -> Option<Vec<PromptGroup>> {
        if self.q.len() < b {
            return None;
        }
        let mut out = Vec::with_capacity(b);
        for _ in 0..b {
            let item = self.q.pop_front().unwrap();
            self.staleness_sum += (train_step.saturating_sub(item.born_step)) as u64;
            self.consumed += 1;
            out.push(item.group);
        }
        Some(out)
    }

    /// Pop the longest FIFO prefix of groups whose rollouts fit
    /// `target_rows` — the variable-budget batch take: training batches
    /// are accounted in *rollouts* (what the compiled train step actually
    /// consumes), not in groups, since per-prompt budgets make group sizes
    /// heterogeneous. Returns `None` while the whole buffer still fits
    /// under the target (the caller keeps running inference); returns a
    /// batch once the target is met exactly or the next group would
    /// overflow it. With uniform groups of `n` rollouts and a target of
    /// `b * n` this is exactly [`take_batch`](Self::take_batch)`(b)`.
    ///
    /// An oversized front group (alone above the target) is returned by
    /// itself so the downstream capacity check fails loudly instead of the
    /// supply loop spinning forever; run drivers validate budgets against
    /// the train shape so this cannot happen in configured runs.
    pub fn take_rollouts(
        &mut self,
        target_rows: usize,
        train_step: usize,
    ) -> Option<Vec<PromptGroup>> {
        let sizes = self.q.iter().map(|b| b.group.rollouts.len());
        let (take, complete) = rollout_prefix(sizes, target_rows);
        if !complete {
            return None;
        }
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            let item = self.q.pop_front().unwrap();
            self.staleness_sum += (train_step.saturating_sub(item.born_step)) as u64;
            self.consumed += 1;
            out.push(item.group);
        }
        Some(out)
    }

    /// Total rollout rows currently buffered (the rollout-unit backlog the
    /// SPEED curricula throttle screening on).
    pub fn rollout_rows(&self) -> usize {
        self.q.iter().map(|b| b.group.rollouts.len()).sum()
    }

    /// Mean steps-in-buffer over all consumed groups.
    pub fn mean_staleness(&self) -> f64 {
        if self.consumed == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.consumed as f64
        }
    }

    /// Snapshot the buffered groups and staleness accounting for a
    /// warm-resume checkpoint (`max_len` is a construction-time capacity
    /// choice, re-derived from the config on resume, not state).
    pub fn state(&self) -> SamplingBufferState {
        SamplingBufferState {
            entries: self.q.iter().map(|b| (b.group.clone(), b.born_step)).collect(),
            staleness_sum: self.staleness_sum,
            consumed: self.consumed,
            evicted: self.evicted,
        }
    }

    /// Restore contents written by [`state`](Self::state). Entries re-enter
    /// through [`push`](Self::push), so THIS buffer's `max_len` is
    /// enforced — a checkpoint written with a larger (or unbounded) cap
    /// resumed under a smaller one evicts oldest-first down to the bound,
    /// with the evictions counted and logged like any others.
    pub fn restore(&mut self, state: SamplingBufferState) {
        self.q.clear();
        self.staleness_sum = state.staleness_sum;
        self.consumed = state.consumed;
        self.evicted = state.evicted;
        for (group, born_step) in state.entries {
            self.push(group, born_step);
        }
    }
}

/// Serializable contents of a [`SamplingBuffer`] (warm-resume checkpoints):
/// the queued groups with their birth steps plus the cumulative staleness
/// accounting, so `mean_staleness` continues instead of restarting at zero.
#[derive(Clone, Debug, Default)]
pub struct SamplingBufferState {
    pub entries: Vec<(PromptGroup, usize)>,
    pub staleness_sum: u64,
    pub consumed: u64,
    pub evicted: u64,
}

// ---------------------------------------------------------------------------
// SharedBuffer: the producer/consumer queue of the pipelined coordinator
// ---------------------------------------------------------------------------

/// A group in flight between rollout workers and the learner.
#[derive(Clone, Debug)]
struct SharedEntry {
    group: PromptGroup,
    /// Learner step at which the producing worker started collecting.
    born_step: usize,
    /// Parameter version the producing engine served.
    born_version: u64,
}

#[derive(Debug, Default)]
struct SharedState {
    q: VecDeque<SharedEntry>,
    closed: bool,
    pushed: u64,
    popped: u64,
    staleness_sum: u64,
    version_lag_sum: u64,
    /// Optional production cap: once `pushed` reaches it, further pushes
    /// are refused so workers wind down instead of over-producing.
    demand: u64,
}

/// Cumulative [`SharedBuffer`] accounting (conservation + staleness).
#[derive(Clone, Copy, Debug, Default)]
pub struct SharedBufferStats {
    pub pushed: u64,
    pub popped: u64,
    pub len: usize,
    pub mean_staleness: f64,
    pub mean_version_lag: f64,
}

/// Bounded `Mutex`+`Condvar` queue of completed prompt groups: K rollout
/// workers push, the learner pops exactly-`B` batches. A full buffer blocks
/// producers (backpressure bounds off-policy staleness); `close` wakes
/// everyone for shutdown.
///
/// Declared through the [`crate::util::sync`] aliases and lock helpers:
/// this is one of the two protocols modeled exhaustively by
/// `analysis::model` (`rust/tests/loom_sync.rs`), and the aliases are the
/// one-file swap point for a real loom build (DESIGN.md §15).
#[derive(Debug)]
pub struct SharedBuffer {
    state: SyncMutex<SharedState>,
    not_empty: SyncCondvar,
    not_full: SyncCondvar,
    cap: usize,
}

impl SharedBuffer {
    /// `cap` is the capacity in groups (clamped to >= 1).
    pub fn new(cap: usize) -> SharedBuffer {
        SharedBuffer {
            state: SyncMutex::new(SharedState { demand: u64::MAX, ..Default::default() }),
            not_empty: SyncCondvar::new(),
            not_full: SyncCondvar::new(),
            cap: cap.max(1),
        }
    }

    /// Cap total production at `total` groups (e.g. `max_steps * B` when no
    /// early-stop conditions are active) so workers don't run inference the
    /// learner will never consume.
    pub fn set_demand(&self, total: u64) {
        plock(&self.state).demand = total;
    }

    /// Groups still wanted by the learner (`u64::MAX` when uncapped).
    pub fn remaining_demand(&self) -> u64 {
        let g = plock(&self.state);
        g.demand.saturating_sub(g.pushed)
    }

    /// Blocking push; returns false when the buffer is closed or demand is
    /// exhausted (the producer should wind down).
    pub fn push(&self, group: PromptGroup, born_step: usize, born_version: u64) -> bool {
        let mut g = plock(&self.state);
        // Span only when the producer actually blocked: a non-full buffer
        // records nothing (no zero-length event flood).
        let mut t_wait = None;
        while g.q.len() >= self.cap && !g.closed {
            if t_wait.is_none() {
                t_wait = crate::trace::start();
            }
            g = pwait(&self.not_full, g);
        }
        crate::trace::span("buffer-push-wait", "buffer", t_wait, g.q.len() as i64);
        if g.closed || g.pushed >= g.demand {
            return false;
        }
        g.q.push_back(SharedEntry { group, born_step, born_version });
        g.pushed += 1;
        self.not_empty.notify_all();
        true
    }

    /// Blocking pop of exactly `b` groups; `train_step`/`version` are the
    /// learner's current step and weight version (for staleness stats).
    /// Returns None when the buffer is closed with fewer than `b` left.
    ///
    /// Production paths batch by ROLLOUTS
    /// ([`pop_rollouts`](Self::pop_rollouts)); this group-counted pop is
    /// the uniform-budget reference the equivalence tests compare against.
    pub fn pop_batch(
        &self,
        b: usize,
        train_step: usize,
        version: u64,
    ) -> Option<Vec<PromptGroup>> {
        let mut g = plock(&self.state);
        let mut t_wait = None;
        loop {
            if g.q.len() >= b {
                crate::trace::span("buffer-pop-wait", "buffer", t_wait, b as i64);
                let mut out = Vec::with_capacity(b);
                for _ in 0..b {
                    let item = g.q.pop_front().unwrap();
                    g.staleness_sum += train_step.saturating_sub(item.born_step) as u64;
                    g.version_lag_sum += version.saturating_sub(item.born_version);
                    g.popped += 1;
                    out.push(item.group);
                }
                self.not_full.notify_all();
                return Some(out);
            }
            if g.closed {
                return None;
            }
            if t_wait.is_none() {
                t_wait = crate::trace::start();
            }
            g = pwait(&self.not_empty, g);
        }
    }

    /// Blocking pop of the longest FIFO prefix of groups whose rollouts
    /// fit `target_rows` (the variable-budget analogue of
    /// [`pop_batch`](Self::pop_batch) — training batches are accounted in
    /// rollouts, not groups). Blocks until the target is met exactly or a
    /// queued group overflows it; with uniform groups of `n` rollouts and
    /// a target of `b * n` this pops exactly `b` groups. Returns `None`
    /// when the buffer closes before a full batch accumulates. An
    /// oversized front group is popped alone (see
    /// [`SamplingBuffer::take_rollouts`]).
    pub fn pop_rollouts(
        &self,
        target_rows: usize,
        train_step: usize,
        version: u64,
    ) -> Option<Vec<PromptGroup>> {
        let mut g = plock(&self.state);
        let mut t_wait = None;
        loop {
            let sizes = g.q.iter().map(|e| e.group.rollouts.len());
            let (take, complete) = rollout_prefix(sizes, target_rows);
            if complete {
                crate::trace::span("buffer-pop-wait", "buffer", t_wait, take as i64);
                let mut out = Vec::with_capacity(take);
                for _ in 0..take {
                    let item = g.q.pop_front().unwrap();
                    g.staleness_sum += train_step.saturating_sub(item.born_step) as u64;
                    g.version_lag_sum += version.saturating_sub(item.born_version);
                    g.popped += 1;
                    out.push(item.group);
                }
                self.not_full.notify_all();
                return Some(out);
            }
            if g.closed {
                return None;
            }
            if t_wait.is_none() {
                t_wait = crate::trace::start();
            }
            g = pwait(&self.not_empty, g);
        }
    }

    /// Wake all producers and consumers; pending pushes fail, pending pops
    /// drain what fits and then return None.
    pub fn close(&self) {
        plock(&self.state).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        plock(&self.state).closed
    }

    pub fn len(&self) -> usize {
        plock(&self.state).q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mean steps-in-buffer over all popped groups.
    pub fn mean_staleness(&self) -> f64 {
        let g = plock(&self.state);
        if g.popped == 0 {
            0.0
        } else {
            g.staleness_sum as f64 / g.popped as f64
        }
    }

    pub fn stats(&self) -> SharedBufferStats {
        let g = plock(&self.state);
        let denom = g.popped.max(1) as f64;
        SharedBufferStats {
            pushed: g.pushed,
            popped: g.popped,
            len: g.q.len(),
            mean_staleness: if g.popped == 0 { 0.0 } else { g.staleness_sum as f64 / denom },
            mean_version_lag: if g.popped == 0 { 0.0 } else { g.version_lag_sum as f64 / denom },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::update::Rollout;
    use crate::util::proptest::check;
    use crate::{prop_assert, prop_assert_eq};

    fn sized_group(idx: usize, rollouts: usize) -> PromptGroup {
        PromptGroup {
            prompt_idx: idx,
            task: crate::data::tasks::TaskInstance {
                family: crate::data::tasks::TaskFamily::Add,
                level: 1,
                prompt: "1+1=".into(),
                answer: 2,
            },
            rollouts: vec![
                Rollout { gen_tokens: vec![2], gen_logprobs: vec![-0.1], reward: 1.0 };
                rollouts
            ],
        }
    }

    fn group(idx: usize) -> PromptGroup {
        sized_group(idx, 1)
    }

    #[test]
    fn returns_none_until_full_batch() {
        let mut buf = SamplingBuffer::new();
        buf.push(group(0), 0);
        assert!(buf.take_batch(2, 0).is_none());
        assert_eq!(buf.len(), 1); // nothing consumed by the failed take
        buf.push(group(1), 0);
        let batch = buf.take_batch(2, 1).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(buf.is_empty());
    }

    #[test]
    fn fifo_order_bounds_staleness() {
        let mut buf = SamplingBuffer::new();
        for i in 0..5 {
            buf.push(group(i), i);
        }
        let batch = buf.take_batch(3, 10).unwrap();
        let idxs: Vec<usize> = batch.iter().map(|g| g.prompt_idx).collect();
        assert_eq!(idxs, vec![0, 1, 2]); // oldest first
        assert!((buf.mean_staleness() - (10.0 + 9.0 + 8.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn bounded_buffer_evicts_oldest_first() {
        let mut buf = SamplingBuffer::new().with_max_len(3);
        for i in 0..5 {
            buf.push(group(i), i);
        }
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.evicted(), 2);
        let batch = buf.take_batch(3, 5).unwrap();
        let idxs: Vec<usize> = batch.iter().map(|g| g.prompt_idx).collect();
        assert_eq!(idxs, vec![2, 3, 4]); // 0 and 1 were evicted
    }

    #[test]
    fn state_roundtrip_preserves_contents_and_staleness() {
        let mut buf = SamplingBuffer::new();
        for i in 0..6 {
            buf.push(sized_group(i, 4), i);
        }
        buf.take_batch(2, 9).unwrap(); // consume some: staleness accrues
        let mut back = SamplingBuffer::new().with_max_len(32);
        back.restore(buf.state());
        assert_eq!(back.len(), buf.len());
        assert_eq!(back.rollout_rows(), buf.rollout_rows());
        assert_eq!(back.mean_staleness(), buf.mean_staleness());
        // FIFO order survives the round trip
        let a = buf.take_batch(4, 12).unwrap();
        let b = back.take_batch(4, 12).unwrap();
        assert_eq!(
            a.iter().map(|g| g.prompt_idx).collect::<Vec<_>>(),
            b.iter().map(|g| g.prompt_idx).collect::<Vec<_>>()
        );
        assert_eq!(back.mean_staleness(), buf.mean_staleness());
    }

    #[test]
    fn restore_enforces_the_restoring_buffers_capacity() {
        // A checkpoint written unbounded, resumed under a smaller cap:
        // oldest entries are evicted down to the bound and counted.
        let mut big = SamplingBuffer::new();
        for i in 0..6 {
            big.push(sized_group(i, 2), i);
        }
        let mut small = SamplingBuffer::new().with_max_len(4);
        small.restore(big.state());
        assert_eq!(small.len(), 4);
        assert_eq!(small.evicted(), 2);
        let batch = small.take_batch(4, 6).unwrap();
        let idxs: Vec<usize> = batch.iter().map(|g| g.prompt_idx).collect();
        assert_eq!(idxs, vec![2, 3, 4, 5], "oldest entries must be the evicted ones");
    }

    #[test]
    fn conservation_property() {
        // pushes == pops + remaining + evicted, across random interleavings
        // and random capacity bounds
        check("buffer-conservation", 50, |rng| {
            let bounded = rng.bool(0.5);
            let mut buf = if bounded {
                SamplingBuffer::new().with_max_len(rng.range_usize(1, 8))
            } else {
                SamplingBuffer::new()
            };
            let mut pushed = 0usize;
            let mut popped = 0usize;
            for step in 0..rng.range_usize(5, 40) {
                if rng.bool(0.6) {
                    buf.push(group(pushed), step);
                    pushed += 1;
                }
                if rng.bool(0.4) {
                    let b = rng.range_usize(1, 4);
                    if let Some(batch) = buf.take_batch(b, step) {
                        prop_assert_eq!(batch.len(), b);
                        popped += batch.len();
                    }
                }
            }
            prop_assert!(
                pushed == popped + buf.len() + buf.evicted() as usize,
                "conservation violated"
            );
            Ok(())
        });
    }

    #[test]
    fn take_rollouts_matches_take_batch_for_uniform_groups() {
        // Uniform groups of n rollouts + target b*n == take_batch(b): the
        // fixed-allocator equivalence at the buffer layer.
        let mut by_groups = SamplingBuffer::new();
        let mut by_rows = SamplingBuffer::new();
        for i in 0..5 {
            by_groups.push(sized_group(i, 24), i);
            by_rows.push(sized_group(i, 24), i);
        }
        assert!(by_rows.take_rollouts(6 * 24, 5).is_none(), "short buffer must not take");
        assert_eq!(by_rows.len(), 5);
        let a = by_groups.take_batch(3, 7).unwrap();
        let b = by_rows.take_rollouts(3 * 24, 7).unwrap();
        assert_eq!(
            a.iter().map(|g| g.prompt_idx).collect::<Vec<_>>(),
            b.iter().map(|g| g.prompt_idx).collect::<Vec<_>>()
        );
        assert_eq!(by_groups.mean_staleness(), by_rows.mean_staleness());
    }

    #[test]
    fn take_rollouts_fills_up_to_the_target_with_variable_groups() {
        let mut buf = SamplingBuffer::new();
        for (i, n) in [14, 44, 30, 14].iter().enumerate() {
            buf.push(sized_group(i, *n), 0);
        }
        // 14 + 44 + 30 = 88; the next 14 would fit 100? no: 88 + 14 = 102 > 100
        let batch = buf.take_rollouts(100, 1).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.iter().map(|g| g.rollouts.len()).sum::<usize>(), 88);
        assert_eq!(buf.len(), 1);
        // remaining 14 alone under a 100-row target: buffer might grow, so
        // no take yet
        assert!(buf.take_rollouts(100, 1).is_none());
        // an exact-target prefix completes even when it drains the buffer
        buf.push(sized_group(9, 86), 0);
        let batch = buf.take_rollouts(100, 1).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(buf.is_empty());
    }

    #[test]
    fn take_rollouts_surfaces_an_oversized_front_group() {
        // A group larger than the target alone is returned by itself (the
        // downstream capacity check rejects it loudly) instead of wedging
        // the supply loop.
        let mut buf = SamplingBuffer::new();
        buf.push(sized_group(0, 50), 0);
        buf.push(sized_group(1, 10), 0);
        let batch = buf.take_rollouts(48, 1).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rollouts.len(), 50);
        assert_eq!(buf.len(), 1);
    }

    #[test]
    fn shared_buffer_pop_rollouts_takes_variable_prefix() {
        let buf = SharedBuffer::new(8);
        for (i, n) in [24usize, 24, 40, 20].iter().enumerate() {
            assert!(buf.push(sized_group(i, *n), 0, 0));
        }
        // 24 + 24 = 48; the 40-row group overflows a 64-row target
        let batch = buf.pop_rollouts(64, 1, 0).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(buf.len(), 2);
        // uniform case: exact target
        let batch = buf.pop_rollouts(60, 1, 0).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(buf.is_empty());
        buf.close();
        assert!(buf.pop_rollouts(10, 1, 0).is_none());
    }

    #[test]
    fn shared_buffer_pop_rollouts_blocks_until_target() {
        use std::sync::Arc;
        let buf = Arc::new(SharedBuffer::new(8));
        assert!(buf.push(sized_group(0, 24), 0, 0));
        let consumer = {
            let buf = Arc::clone(&buf);
            std::thread::spawn(move || buf.pop_rollouts(48, 0, 0))
        };
        // The consumer needs 48 rows; only 24 are queued. Feed the rest.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(buf.push(sized_group(1, 24), 0, 0));
        let batch = consumer.join().unwrap().expect("batch once target met");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn shared_buffer_push_pop_and_close() {
        let buf = SharedBuffer::new(8);
        assert!(buf.push(group(0), 0, 0));
        assert!(buf.push(group(1), 1, 1));
        let batch = buf.pop_batch(2, 3, 2).unwrap();
        assert_eq!(batch.len(), 2);
        let stats = buf.stats();
        assert_eq!(stats.pushed, 2);
        assert_eq!(stats.popped, 2);
        // staleness (3-0) + (3-1) = 5 over 2 pops; version lag (2-0)+(2-1)
        assert!((stats.mean_staleness - 2.5).abs() < 1e-12);
        assert!((stats.mean_version_lag - 1.5).abs() < 1e-12);
        buf.close();
        assert!(!buf.push(group(2), 0, 0));
        assert!(buf.pop_batch(1, 0, 0).is_none());
    }

    #[test]
    fn shared_buffer_demand_cap_stops_producers() {
        let buf = SharedBuffer::new(8);
        buf.set_demand(2);
        assert!(buf.push(group(0), 0, 0));
        assert!(buf.push(group(1), 0, 0));
        assert_eq!(buf.remaining_demand(), 0);
        assert!(!buf.push(group(2), 0, 0));
        assert_eq!(buf.stats().pushed, 2);
    }

    #[test]
    fn shared_buffer_backpressure_blocks_until_pop() {
        use std::sync::Arc;
        let buf = Arc::new(SharedBuffer::new(1));
        assert!(buf.push(group(0), 0, 0));
        let producer = {
            let buf = Arc::clone(&buf);
            std::thread::spawn(move || buf.push(group(1), 0, 0))
        };
        // The producer must be blocked on the full buffer; free one slot.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let batch = buf.pop_batch(1, 0, 0).unwrap();
        assert_eq!(batch[0].prompt_idx, 0);
        assert!(producer.join().unwrap());
        assert_eq!(buf.len(), 1);
    }
}
