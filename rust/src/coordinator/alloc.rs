//! Per-prompt rollout budgets (CurES-style allocation; PAPERS.md).
//!
//! SPEED's estimator quality per prompt is governed by the reward variance
//! p(1-p) (Theorem 3.1): rollouts spent where the posterior already
//! forecasts a near-uniform outcome buy almost no gradient signal, while
//! high-variance prompts are exactly where extra rollouts sharpen the
//! group baseline. The seed code nevertheless spent a *uniform* `n_cont`
//! on every qualified prompt. This module replaces that scalar contract
//! with a per-prompt [`RolloutBudget`] chosen by an [`Allocator`]:
//!
//! * [`AllocKind::Fixed`]    — every qualified prompt gets `rule.n_cont`
//!   continuation rollouts, reproducing the pre-refactor behaviour bit for
//!   bit (the equivalence rail that makes this refactor safe to land).
//! * [`AllocKind::Adaptive`] — the budget is proportional to the
//!   *posterior* reward variance p̂(1-p̂), where p̂ blends the difficulty
//!   [`Predictor`]'s discounted Beta posterior (when available) with the
//!   just-realized screening outcome, linearly mapped from variance 0
//!   (budget `n_cont_min`) to the maximum 0.25 (budget `n_cont_max`).
//!
//! The forecast variance behind every allocation is kept with the pending
//! continuation and scored against the realized group variance when the
//! group completes (`alloc_calib_*` in
//! [`crate::metrics::InferenceCounters`]) so miscalibrated budgets are
//! visible, not silent.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::screening::ScreeningRule;
use crate::data::tasks::TaskInstance;
use crate::predictor::{ObservationDelta, Predictor};

/// Allocation strategy selector (the `--alloc` CLI knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    /// Uniform `n_cont` per qualified prompt (the paper's Algorithm 2).
    Fixed,
    /// Posterior-variance-proportional budgets in `[n_cont_min, n_cont_max]`.
    Adaptive,
}

impl AllocKind {
    pub const ALL: [AllocKind; 2] = [AllocKind::Fixed, AllocKind::Adaptive];

    pub fn name(&self) -> &'static str {
        match self {
            AllocKind::Fixed => "fixed",
            AllocKind::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<AllocKind> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" | "uniform" => Some(AllocKind::Fixed),
            "adaptive" | "posterior" | "variance" => Some(AllocKind::Adaptive),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) with an error listing every valid name.
    pub fn parse_or_err(s: &str) -> Result<AllocKind> {
        AllocKind::parse(s).ok_or_else(|| {
            let names: Vec<&str> = AllocKind::ALL.iter().map(|k| k.name()).collect();
            anyhow!("unknown allocator '{s}' (valid: {})", names.join(", "))
        })
    }
}

/// One prompt's rollout budget: screening rows it already consumed plus the
/// continuation rows it was allocated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RolloutBudget {
    pub n_init: usize,
    pub n_cont: usize,
}

impl RolloutBudget {
    pub fn n_total(&self) -> usize {
        self.n_init + self.n_cont
    }
}

/// The outcome of one allocation decision.
#[derive(Clone, Copy, Debug)]
pub struct Allocation {
    pub budget: RolloutBudget,
    /// The forecast reward variance p̂(1-p̂) the budget was derived from;
    /// scored against the realized group variance for calibration.
    pub forecast_var: f64,
}

/// Chooses each qualified prompt's continuation budget. Cheap to `Clone`
/// (the predictor handle is an `Arc`), so every pipelined rollout worker's
/// curriculum carries its own copy pricing from the shared posterior store.
#[derive(Clone, Debug)]
pub struct Allocator {
    pub kind: AllocKind,
    pub rule: ScreeningRule,
    pub n_cont_min: usize,
    pub n_cont_max: usize,
    /// Posterior source for `Adaptive`. Absent, the allocator prices from
    /// the screening rewards alone (a uniform Beta(1,1) prior).
    predictor: Option<Arc<Predictor>>,
    /// Fold screening outcomes into the predictor's posterior store from
    /// inside [`allocate`](Self::allocate). On for plain `speed` (nothing
    /// else feeds the store), off for `predictive-speed` (the curriculum
    /// already observes every outcome — feeding twice would double-count).
    feed_posterior: bool,
}

impl Allocator {
    /// The uniform allocator: `rule.n_cont` for every prompt. Reproduces
    /// the pre-refactor rollout stream bit for bit — no RNG draws, no
    /// store access, budgets independent of the screening outcome.
    pub fn fixed(rule: ScreeningRule) -> Allocator {
        Allocator {
            kind: AllocKind::Fixed,
            rule,
            n_cont_min: rule.n_cont,
            n_cont_max: rule.n_cont,
            predictor: None,
            feed_posterior: false,
        }
    }

    /// Posterior-variance-proportional budgets in `[n_cont_min, n_cont_max]`.
    pub fn adaptive(
        rule: ScreeningRule,
        n_cont_min: usize,
        n_cont_max: usize,
        predictor: Option<Arc<Predictor>>,
        feed_posterior: bool,
    ) -> Allocator {
        let n_cont_min = n_cont_min.max(1);
        Allocator {
            kind: AllocKind::Adaptive,
            rule,
            n_cont_min,
            n_cont_max: n_cont_max.max(n_cont_min),
            predictor,
            feed_posterior,
        }
    }

    /// Smallest possible complete group (screening + minimum budget).
    pub fn min_n_total(&self) -> usize {
        self.rule.n_init + self.n_cont_min
    }

    /// Largest possible complete group — what capacity checks must admit.
    pub fn max_n_total(&self) -> usize {
        self.rule.n_init + self.n_cont_max
    }

    /// Choose the continuation budget for a prompt that just passed
    /// screening with `screening_rewards`.
    ///
    /// When this allocator feeds the posterior itself (plain `speed`), the
    /// observation is deferred into `delta` — one sharded-store merge per
    /// inference call via [`flush`](Self::flush), mirroring the
    /// predictive-speed curriculum's batched-observation pattern instead of
    /// taking a shard lock per accepted prompt.
    pub fn allocate(
        &self,
        task: &TaskInstance,
        screening_rewards: &[f32],
        delta: &mut ObservationDelta,
    ) -> Allocation {
        let n = screening_rewards.len();
        let k = screening_rewards.iter().filter(|&&r| r > 0.5).count();
        // Beta posterior over the pass rate: the predictor's discounted
        // per-identity counts (blended with its feature-model prior) when
        // available, else uniform Beta(1,1) — plus the screening outcome.
        let (a0, b0) = match &self.predictor {
            Some(p) => {
                let pred = p.predict(task);
                // Strength grows with the identity's discounted evidence so
                // revisited prompts trust their history over one screen.
                let s = 2.0 + pred.weight.min(16.0);
                (s * pred.mean, s * (1.0 - pred.mean))
            }
            None => (1.0, 1.0),
        };
        if self.feed_posterior {
            delta.push(task.identity(), screening_rewards);
        }
        let a = a0 + k as f64;
        let b = b0 + (n - k) as f64;
        let p_hat = a / (a + b);
        let forecast_var = p_hat * (1.0 - p_hat);
        let n_cont = match self.kind {
            AllocKind::Fixed => self.rule.n_cont,
            AllocKind::Adaptive => {
                // Linear map from forecast variance to budget: v = 0 earns
                // the floor, the maximum v = 0.25 earns the ceiling.
                let span = (self.n_cont_max - self.n_cont_min) as f64;
                let raw = self.n_cont_min as f64 + span * (forecast_var / 0.25);
                (raw.round() as usize).clamp(self.n_cont_min, self.n_cont_max)
            }
        };
        Allocation { budget: RolloutBudget { n_init: self.rule.n_init, n_cont }, forecast_var }
    }

    /// Merge observations deferred by [`allocate`](Self::allocate) into
    /// the posterior store (one sharded-lock pass; call once per inference
    /// call). A no-op for allocators that do not feed the store — the
    /// delta then stays empty, or is owned by the curriculum's own
    /// observation path (predictive-speed).
    pub fn flush(&self, delta: &mut ObservationDelta) {
        if let Some(p) = self.predictor.as_ref().filter(|_| self.feed_posterior) {
            p.flush(delta);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::PredictorConfig;
    use crate::util::rng::Rng;

    fn task(seed: u64) -> TaskInstance {
        let mut rng = Rng::new(seed);
        crate::data::tasks::generate(&mut rng, crate::data::tasks::TaskFamily::Add, 3, 20)
    }

    fn allocate(alloc: &Allocator, task: &TaskInstance, rewards: &[f32]) -> Allocation {
        alloc.allocate(task, rewards, &mut ObservationDelta::default())
    }

    #[test]
    fn parse_covers_all_kinds() {
        for kind in AllocKind::ALL {
            assert_eq!(AllocKind::parse(kind.name()), Some(kind));
            assert_eq!(AllocKind::parse_or_err(kind.name()).unwrap(), kind);
        }
        let err = AllocKind::parse_or_err("bogus").unwrap_err().to_string();
        assert!(err.contains("fixed") && err.contains("adaptive"), "{err}");
    }

    #[test]
    fn fixed_budget_ignores_screening_outcome() {
        let rule = ScreeningRule::new(4, 20);
        let alloc = Allocator::fixed(rule);
        for rewards in [[0.0f32, 0.0, 0.0, 1.0], [1.0, 1.0, 1.0, 0.0], [1.0, 0.0, 1.0, 0.0]] {
            let a = allocate(&alloc, &task(1), &rewards);
            assert_eq!(a.budget.n_cont, 20);
            assert_eq!(a.budget.n_total(), 24);
        }
        assert_eq!(alloc.min_n_total(), 24);
        assert_eq!(alloc.max_n_total(), 24);
    }

    #[test]
    fn adaptive_budget_grows_with_forecast_variance() {
        let rule = ScreeningRule::new(8, 16);
        let alloc = Allocator::adaptive(rule, 4, 32, None, false);
        // Near-extreme screening outcome (1/8) forecasts low variance;
        // balanced (4/8) forecasts the maximum.
        let low = allocate(&alloc, &task(2), &[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let high = allocate(&alloc, &task(2), &[1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(
            high.budget.n_cont > low.budget.n_cont,
            "balanced outcome must earn more rollouts: {} vs {}",
            high.budget.n_cont,
            low.budget.n_cont
        );
        assert!(high.forecast_var > low.forecast_var);
        for a in [low, high] {
            assert!((4..=32).contains(&a.budget.n_cont), "budget out of clamp: {a:?}");
        }
        assert_eq!(alloc.min_n_total(), 12);
        assert_eq!(alloc.max_n_total(), 40);
    }

    #[test]
    fn degenerate_bounds_reduce_adaptive_to_fixed_budgets() {
        let rule = ScreeningRule::new(4, 20);
        let adaptive = Allocator::adaptive(rule, 20, 20, None, false);
        let fixed = Allocator::fixed(rule);
        for rewards in [[1.0f32, 0.0, 0.0, 0.0], [1.0, 1.0, 1.0, 0.0]] {
            let a = allocate(&adaptive, &task(3), &rewards);
            let f = allocate(&fixed, &task(3), &rewards);
            assert_eq!(a.budget, f.budget, "n_cont_min = n_cont_max must pin the budget");
        }
    }

    #[test]
    fn predictor_posterior_steers_the_budget() {
        let rule = ScreeningRule::new(8, 16);
        let predictor = Arc::new(Predictor::new(rule, PredictorConfig::default()));
        let t = task(4);
        // Teach the store a long near-certain history for this identity.
        for _ in 0..6 {
            predictor.observe_rollouts(&t, &[1.0; 8]);
        }
        let informed = Allocator::adaptive(rule, 4, 32, Some(Arc::clone(&predictor)), false);
        let blind = Allocator::adaptive(rule, 4, 32, None, false);
        // Same *balanced* screening outcome: the informed allocator knows
        // the identity is near-trivial and allocates below the blind one.
        let rewards = [1.0f32, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let a = allocate(&informed, &t, &rewards);
        let b = allocate(&blind, &t, &rewards);
        assert!(
            a.budget.n_cont < b.budget.n_cont,
            "history must pull the budget down: informed {} vs blind {}",
            a.budget.n_cont,
            b.budget.n_cont
        );
    }

    #[test]
    fn feed_posterior_defers_observations_until_flush() {
        let rule = ScreeningRule::new(8, 16);
        let predictor = Arc::new(Predictor::new(rule, PredictorConfig::default()));
        let alloc = Allocator::adaptive(rule, 4, 32, Some(Arc::clone(&predictor)), true);
        let mut delta = ObservationDelta::default();
        assert_eq!(predictor.tracked(), 0);
        alloc.allocate(&task(5), &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0], &mut delta);
        // Deferred: the shard lock is not touched per allocation...
        assert_eq!(predictor.tracked(), 0);
        assert!(!delta.is_empty());
        // ...the per-call flush merges it.
        alloc.flush(&mut delta);
        assert!(delta.is_empty());
        assert_eq!(predictor.tracked(), 1, "allocator must feed the shared posterior");
        // And the non-feeding allocator leaves store AND delta untouched.
        let silent = Allocator::adaptive(rule, 4, 32, Some(Arc::clone(&predictor)), false);
        silent.allocate(&task(6), &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0], &mut delta);
        assert!(delta.is_empty());
        silent.flush(&mut delta);
        assert_eq!(predictor.tracked(), 1);
    }
}
