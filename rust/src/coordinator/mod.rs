//! The paper's system contribution (SPEED, §4): online curriculum
//! scheduling of inference and training.
//!
//! * [`screening`]  — the lightweight pass-rate test over `N_init` rollouts
//! * [`alloc`]      — per-prompt continuation budgets: the [`alloc::Allocator`]
//!                    maps the posterior reward variance to each qualified
//!                    prompt's `n_cont` (fixed = the paper's uniform split)
//! * [`buffer`]     — the sampling buffers decoupling qualified-prompt
//!                    supply from the fixed training batch size (Alg. 2):
//!                    the serial bounded deque and the `Mutex`+`Condvar`
//!                    producer/consumer queue
//! * [`batcher`]    — the pre-fetch batcher packing continuation rows of
//!                    batch *t* with screening rows of batch *t+1* into one
//!                    fixed-shape inference call (§4.3)
//! * [`curriculum`] — strategy trait: `Uniform` (vanilla), `DapoFilter`,
//!                    `Speed` (Alg. 2), `VarianceMax` (Foster–Foerster)
//! * [`predictive`] — `PredictiveSpeed`: SPEED behind the learned
//!                    difficulty pre-screen ([`crate::predictor`]) that
//!                    skips confidently-uninformative prompts before any
//!                    rollout is spent
//! * [`trainer`]    — the serial reference loop: inference → verify →
//!                    select → update, with per-phase wall-clock accounting
//! * [`pipeline`]   — the pipelined loop: K rollout workers overlap
//!                    inference with the learner's updates via a bounded
//!                    shared buffer and versioned weight handoff; with the
//!                    `service` knob on, all workers submit through the
//!                    shared coalescing [`crate::policy::service`] instead
//!                    of owning private engines (DESIGN.md §8)

pub mod alloc;
pub mod batcher;
pub mod naive;
pub mod buffer;
pub mod curriculum;
pub mod pipeline;
pub mod predictive;
pub mod screening;
pub mod trainer;

pub use alloc::{AllocKind, Allocator, RolloutBudget};
pub use curriculum::{Curriculum, CurriculumKind, CurriculumSpec};
pub use pipeline::{PipelineConfig, PipelinedTrainer};
pub use screening::ScreeningRule;
pub use trainer::{Trainer, TrainerConfig};
