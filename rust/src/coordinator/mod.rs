//! The paper's system contribution (SPEED, §4): online curriculum
//! scheduling of inference and training.
//!
//! * [`screening`]  — the lightweight pass-rate test over `N_init` rollouts
//! * [`buffer`]     — the sampling buffer decoupling qualified-prompt supply
//!                    from the fixed training batch size (Alg. 2)
//! * [`batcher`]    — the pre-fetch batcher packing continuation rows of
//!                    batch *t* with screening rows of batch *t+1* into one
//!                    fixed-shape inference call (§4.3)
//! * [`curriculum`] — strategy trait: `Uniform` (vanilla), `DapoFilter`,
//!                    `Speed` (Alg. 2), `VarianceMax` (Foster–Foerster)
//! * [`trainer`]    — the outer loop: inference → verify → select → update,
//!                    with per-phase wall-clock accounting

pub mod batcher;
pub mod naive;
pub mod buffer;
pub mod curriculum;
pub mod screening;
pub mod trainer;

pub use curriculum::{Curriculum, CurriculumKind};
pub use screening::ScreeningRule;
pub use trainer::{Trainer, TrainerConfig};
