//! The serial training loop: curriculum → inference → RL update → periodic
//! eval, with the paper's wall-clock accounting (training time = inference +
//! update; validation and checkpointing excluded, §5.1).
//!
//! The pipelined variant that overlaps inference with updates lives in
//! [`crate::coordinator::pipeline`]; this serial loop remains the reference
//! semantics (`workers = 1, pipeline = off` reproduces it bit-for-bit).

use anyhow::Result;

use crate::coordinator::curriculum::{Curriculum, StepContext};
use crate::data::dataset::Dataset;
use crate::data::loader::{DatasetSource, Loader};
use crate::metrics::{EvalRecord, InferenceCounters, RunRecord, StepRecord};
use crate::policy::Policy;
use crate::rl::algo::AlgoConfig;

/// Stop conditions + cadence for one run.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Training batch size B (prompts per update). Paper default: 16.
    pub batch_size: usize,
    /// Sampling temperature for training rollouts.
    pub temperature: f32,
    /// Evaluate every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub max_steps: usize,
    /// Stop when cumulative training time exceeds this (seconds; the
    /// simulator's virtual seconds for SimPolicy runs).
    pub max_seconds: f64,
    /// Stop early when a benchmark hits a target: (benchmark name, target).
    pub stop_at_target: Option<(String, f64)>,
    pub seed: u64,
    /// Label recorded in the run record (e.g. "SPEED-RLOO").
    pub label: String,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            batch_size: 16,
            temperature: 1.0,
            eval_every: 10,
            max_steps: 200,
            max_seconds: f64::INFINITY,
            stop_at_target: None,
            seed: 0,
            label: "run".to_string(),
        }
    }
}

/// One benchmark to track during training.
pub struct EvalSet {
    pub name: String,
    pub tasks: Vec<crate::data::tasks::TaskInstance>,
}

/// Evaluate every benchmark and append the records (shared by the serial
/// and pipelined trainers; eval time is excluded from training time).
pub(crate) fn evaluate_all(
    policy: &mut dyn Policy,
    evals: &[EvalSet],
    step: usize,
    time_s: f64,
    record: &mut RunRecord,
) -> Result<()> {
    for set in evals {
        let res = policy.evaluate(&set.tasks)?;
        record.evals.push(EvalRecord {
            step,
            time_s,
            benchmark: set.name.clone(),
            accuracy: res.accuracy,
        });
    }
    Ok(())
}

/// Per-step skip/exploration rates from two cumulative counter snapshots
/// (ROADMAP item: `StepRecord` carried only cumulative skip counts, so the
/// report could not show the predictor warming up or drifting). Returns
/// `(skip_rate, explore_rate)` over the step's deltas: skipped / candidate
/// prompts drawn, and explored / skip-rule firings; 0 when the
/// denominator is empty.
pub(crate) fn step_rates(prev: &InferenceCounters, cur: &InferenceCounters) -> (f64, f64) {
    let d_skip = cur.prompts_skipped.saturating_sub(prev.prompts_skipped);
    let d_screen = cur.prompts_screened.saturating_sub(prev.prompts_screened);
    let d_explore = cur.prompts_explored.saturating_sub(prev.prompts_explored);
    let candidates = d_skip + d_screen;
    let skip_rate = if candidates == 0 { 0.0 } else { d_skip as f64 / candidates as f64 };
    let fired = d_skip + d_explore;
    let explore_rate = if fired == 0 { 0.0 } else { d_explore as f64 / fired as f64 };
    (skip_rate, explore_rate)
}

/// Continuation rows allocated between two cumulative counter snapshots
/// (the per-step allocated-rows telemetry; shared by both trainers).
pub(crate) fn step_alloc_rows(prev: &InferenceCounters, cur: &InferenceCounters) -> u64 {
    cur.cont_rows_allocated.saturating_sub(prev.cont_rows_allocated)
}

/// True when the most recent eval of `bench` has reached `target` (the
/// early-stop condition of Table 1 runs).
pub(crate) fn target_reached(record: &RunRecord, bench: &str, target: f64) -> bool {
    record
        .evals
        .iter()
        .rev()
        .find(|e| e.benchmark == bench)
        .map(|e| e.accuracy >= target)
        .unwrap_or(false)
}

/// Mutable progress of a serial training run, factored out of
/// [`Trainer::run`] so the checkpoint driver can run in *segments* (run K
/// steps → snapshot everything → run K more). Segmenting is also exactly
/// the resume path — a resumed run is a segment whose state was restored
/// from disk — so periodic saving and warm resume share one code path, and
/// the sim-substrate equivalence rail (segmented ≡ uninterrupted, bit for
/// bit) covers both.
#[derive(Debug)]
pub struct TrainState {
    pub loader: Loader,
    pub counters: InferenceCounters,
    /// Next step to execute (= steps completed so far).
    pub next_step: usize,
    pub inference_s: f64,
    pub update_s: f64,
    pub record: RunRecord,
    /// A stop condition fired (target reached / `max_seconds`): later
    /// segments must not run.
    pub stopped: bool,
}

impl TrainState {
    /// The step-0 state of a fresh run.
    pub fn fresh(dataset_len: usize, seed: u64, label: String) -> TrainState {
        TrainState {
            loader: Loader::new(dataset_len, seed),
            counters: InferenceCounters::default(),
            next_step: 0,
            inference_s: 0.0,
            update_s: 0.0,
            record: RunRecord { label, ..Default::default() },
            stopped: false,
        }
    }

    /// Cumulative training time so far (the paper's axis).
    pub fn time_s(&self) -> f64 {
        self.inference_s + self.update_s
    }
}

pub struct Trainer {
    pub config: TrainerConfig,
    pub algo: AlgoConfig,
}

impl Trainer {
    pub fn new(config: TrainerConfig, algo: AlgoConfig) -> Trainer {
        Trainer { config, algo }
    }

    /// Run the full loop; returns the complete run record.
    pub fn run(
        &self,
        policy: &mut dyn Policy,
        curriculum: &mut dyn Curriculum,
        dataset: &Dataset,
        evals: &[EvalSet],
    ) -> Result<RunRecord> {
        let mut state =
            TrainState::fresh(dataset.len(), self.config.seed, self.config.label.clone());
        self.run_segment(policy, curriculum, dataset, evals, &mut state, self.config.max_steps)?;
        let mut record = state.record;
        record.counters = state.counters;
        Ok(record)
    }

    /// Run steps `state.next_step .. min(until_step, max_steps)`, mutating
    /// `state` in place. Performs the step-0 evaluation only when starting
    /// a genuinely fresh run (a resumed record already contains it). Sets
    /// `state.stopped` when a stop condition fires.
    pub fn run_segment(
        &self,
        policy: &mut dyn Policy,
        curriculum: &mut dyn Curriculum,
        dataset: &Dataset,
        evals: &[EvalSet],
        state: &mut TrainState,
        until_step: usize,
    ) -> Result<()> {
        // Step-0 evaluation so every curve starts at the base model.
        if state.next_step == 0 && state.record.evals.is_empty() {
            let t_eval = crate::trace::start();
            evaluate_all(policy, evals, 0, 0.0, &mut state.record)?;
            crate::trace::span("evaluate", "trainer", t_eval, 0);
        }
        let last = until_step.min(self.config.max_steps);
        while !state.stopped && state.next_step < last {
            let step = state.next_step;
            // ---- collect one batch via the curriculum (inference phase) ----
            let counters_before = state.counters;
            let inf_before = state.counters.cost_s;
            let t_collect = crate::trace::start();
            let groups = {
                let mut source = DatasetSource { loader: &mut state.loader, dataset };
                let mut ctx = StepContext {
                    engine: policy.as_engine(),
                    prompts: &mut source,
                    train_step: step,
                    temperature: self.config.temperature,
                    counters: &mut state.counters,
                };
                curriculum.collect_batch(&mut ctx, self.config.batch_size)?
            };
            crate::trace::span("collect-batch", "trainer", t_collect, step as i64);
            state.inference_s += state.counters.cost_s - inf_before;

            // ---- algorithm-level group filter (DAPO keeps it on too when
            // run through Uniform; harmless for SPEED since screening
            // already removed uniform groups) ----
            let groups: Vec<_> =
                groups.into_iter().filter(|g| self.algo.keep_group(&g.rewards())).collect();

            let train_pass_rate = if groups.is_empty() {
                0.0
            } else {
                groups.iter().map(|g| g.pass_rate()).sum::<f64>() / groups.len() as f64
            };
            // ---- RL update ----
            // (The global REINFORCE baseline is estimator-internal: RLOO /
            // GRPO compute theirs per group, and TrainBatch::assemble takes
            // an explicit one for plain REINFORCE.)
            let mut algo = self.algo;
            algo.lr = self.algo.lr_at(step);
            let t_update = crate::trace::start();
            let tr = policy.train(&groups, &algo)?;
            crate::trace::span("optimizer-update", "trainer", t_update, step as i64);
            state.update_s += tr.cost_s;
            state.next_step = step + 1;

            let time_s = state.inference_s + state.update_s;
            let (step_skip_rate, step_explore_rate) =
                step_rates(&counters_before, &state.counters);
            state.record.steps.push(StepRecord {
                step,
                time_s,
                inference_s: state.inference_s,
                update_s: state.update_s,
                train_pass_rate,
                grad_norm: tr.grad_norm,
                loss: tr.loss,
                clip_frac: tr.clip_frac,
                prompts_consumed: state.loader.consumed(),
                buffer_len: curriculum.buffered(),
                mean_staleness: curriculum.mean_staleness(),
                prompts_skipped: state.counters.prompts_skipped,
                rollouts_saved: state.counters.rollouts_saved,
                predictor_brier: state.counters.predictor_brier(),
                step_skip_rate,
                step_explore_rate,
                // The serial loop has no service in scope; a serviced
                // serial run attaches run-level counters in the driver.
                service_calls: 0,
                service_fill: 0.0,
                service_queue_wait_s: 0.0,
                pool_balance: 0.0,
                service_queue_wait_p95_s: 0.0,
                service_exec_p95_s: 0.0,
                rollouts: state.counters.rollouts,
                step_alloc_rows: step_alloc_rows(&counters_before, &state.counters),
                alloc_calibration: state.counters.alloc_calibration(),
                service_faults: 0,
                service_retries: 0,
                slot_occupancy: 0.0,
            });

            // ---- periodic evaluation (excluded from training time) ----
            if self.config.eval_every > 0 && (step + 1) % self.config.eval_every == 0 {
                let t_eval = crate::trace::start();
                evaluate_all(policy, evals, step + 1, time_s, &mut state.record)?;
                crate::trace::span("evaluate", "trainer", t_eval, (step + 1) as i64);
                if let Some((bench, target)) = &self.config.stop_at_target {
                    if target_reached(&state.record, bench, *target) {
                        crate::info!(
                            "trainer",
                            "{}: target {target} on {bench} reached at step {} ({:.1}s)",
                            self.config.label,
                            step + 1,
                            time_s
                        );
                        state.stopped = true;
                    }
                }
            }
            if time_s >= self.config.max_seconds {
                state.stopped = true;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_rates_use_deltas_not_cumulative_counts() {
        let prev = InferenceCounters {
            prompts_skipped: 100,
            prompts_screened: 100,
            prompts_explored: 10,
            ..Default::default()
        };
        let cur = InferenceCounters {
            prompts_skipped: 103, // +3 skips
            prompts_screened: 106, // +6 screens
            prompts_explored: 11, // +1 explore
            ..Default::default()
        };
        let (skip, explore) = step_rates(&prev, &cur);
        assert!((skip - 3.0 / 9.0).abs() < 1e-12, "skip rate {skip}");
        assert!((explore - 1.0 / 4.0).abs() < 1e-12, "explore rate {explore}");
        // empty step: both denominators zero
        let (skip, explore) = step_rates(&cur, &cur);
        assert_eq!(skip, 0.0);
        assert_eq!(explore, 0.0);
    }
}
