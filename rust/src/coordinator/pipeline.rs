//! The pipelined coordinator: K rollout workers keep the inference engine
//! saturated while the learner consumes exactly-`B` batches from a bounded
//! [`SharedBuffer`] and runs updates concurrently.
//!
//! The serial trainer realizes the paper's premise that training time =
//! inference + update (§5.1) *literally*: the rollout engine idles during
//! every optimizer step. This module overlaps the two phases — the
//! remaining wall-clock cost of an update is only what the buffer cannot
//! hide. Dataflow (DESIGN.md §5):
//!
//! ```text
//!   shared Loader ──> worker 0 ┐  screening + continuation
//!   (Mutex, one    ──> worker 1 ├──────> SharedBuffer ───> learner
//!    prompt stream) ──> worker K ┘   (bounded, Condvar)    (train + eval)
//!            ^                                                 │
//!            └──────── WeightStore (versioned snapshots) <─────┘
//! ```
//!
//! Determinism rail: with `enabled = false` (or `workers = 0`) the run is
//! delegated verbatim to the serial [`Trainer`], so `workers = 1, pipeline
//! = off` reproduces the serial `RunRecord` bit-for-bit. With the pipeline
//! on, rollouts may be produced under a stale parameter version; each
//! buffered group records the version that produced it and the buffer's
//! backpressure (capacity `buffer_cap`) bounds that staleness.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::coordinator::buffer::SharedBuffer;
use crate::coordinator::curriculum::{CurriculumSpec, StepContext};
use crate::coordinator::trainer::{
    evaluate_all, step_alloc_rows, step_rates, target_reached, EvalSet, TrainState, Trainer,
    TrainerConfig,
};
use crate::data::dataset::Dataset;
use crate::data::loader::{Loader, SharedSource};
use crate::metrics::{AtomicCounters, InferenceCounters, RunRecord, ServiceCounters, StepRecord};
use crate::policy::fault::RecoveryConfig;
use crate::policy::service::{InferenceService, ServiceConfig};
use crate::policy::{ForkEngine, Policy, RolloutEngine, WeightSnapshot};
use crate::rl::algo::AlgoConfig;
use crate::util::sync::plock;
use crate::util::threadpool::ThreadPool;

/// Producer/consumer knobs (the `workers` / `pipeline` / `buffer_cap` /
/// `service` fields of [`crate::config::RunConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Rollout workers K (each owns a forked engine, or a service handle).
    pub workers: usize,
    /// Off = delegate to the serial [`Trainer`] (the reference semantics).
    pub enabled: bool,
    /// [`SharedBuffer`] capacity in groups (clamped to >= batch size).
    pub buffer_cap: usize,
    /// Route all workers through ONE coalescing [`InferenceService`]
    /// instead of K private forked engines (DESIGN.md §8).
    pub service: bool,
    /// Scheduler knobs for the service (ignored when `service` is off).
    pub service_cfg: ServiceConfig,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            workers: 1,
            enabled: false,
            buffer_cap: 64,
            service: false,
            service_cfg: ServiceConfig::default(),
        }
    }
}

/// Versioned parameter handoff from the learner to rollout workers: the
/// learner publishes a snapshot after every update, workers poll the
/// version (one atomic load) and install only when behind.
#[derive(Debug)]
pub struct WeightStore {
    snap: Mutex<WeightSnapshot>,
    version: std::sync::atomic::AtomicU64,
}

impl WeightStore {
    pub fn new(snap: WeightSnapshot) -> WeightStore {
        WeightStore {
            version: std::sync::atomic::AtomicU64::new(snap.version),
            snap: Mutex::new(snap),
        }
    }

    pub fn publish(&self, snap: WeightSnapshot) {
        let version = snap.version;
        *plock(&self.snap) = snap;
        self.version.store(version, Ordering::Release);
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    pub fn get(&self) -> WeightSnapshot {
        plock(&self.snap).clone()
    }
}

/// The producer/consumer training loop. Stop conditions mirror
/// [`Trainer`], with one accounting caveat: `time_s` counts all inference
/// *issued* so far — including up to `buffer_cap` prefetched groups the
/// learner has not consumed yet — so `max_seconds` stops are conservative
/// for K > 1 (compute actually spent, the honest cost axis).
pub struct PipelinedTrainer {
    pub config: TrainerConfig,
    pub algo: AlgoConfig,
    pub pipeline: PipelineConfig,
    /// Data-parallel engine replicas behind the shared service (the
    /// `--engines` flag; meaningful only with `pipeline.service` on).
    /// Defaults to 1 — set via [`with_engines`](Self::with_engines).
    engines: usize,
    /// Fault-tolerance knobs + pre-forked spare count for the service
    /// (DESIGN.md §13). `None` — the default — spawns the plain pool with
    /// every recovery path disabled, preserving the bit-for-bit rails.
    /// Set via [`with_recovery`](Self::with_recovery).
    recovery: Option<(RecoveryConfig, usize)>,
}

/// Restored learner-side progress for a warm-resumed pipelined run (the
/// counterpart of the serial [`TrainState`]). Worker-internal SPEED
/// buffers are NOT part of it: a pipelined checkpoint is taken after the
/// workers quiesced (pool joined, deltas flushed), and their in-flight
/// prefetch is intentionally dropped — fresh workers refill it. What
/// persists is the shared knowledge (predictor store, weights) and the
/// learner's accounting, so step indices and staleness continue.
#[derive(Debug)]
pub struct PipelineResume {
    /// Next learner step to execute (= steps completed so far).
    pub start_step: usize,
    pub inference_s: f64,
    pub update_s: f64,
    pub counters: InferenceCounters,
    pub record: RunRecord,
    pub loader: Loader,
}

impl PipelinedTrainer {
    pub fn new(config: TrainerConfig, algo: AlgoConfig, pipeline: PipelineConfig) -> Self {
        PipelinedTrainer { config, algo, pipeline, engines: 1, recovery: None }
    }

    /// Shard the shared inference service across `engines` data-parallel
    /// replicas (clamped to `1..=MAX_POOL`; ignored unless
    /// `pipeline.service` is on). E=1 is the single-engine service
    /// unchanged.
    pub fn with_engines(mut self, engines: usize) -> Self {
        self.engines = engines.clamp(1, crate::metrics::MAX_POOL);
        self
    }

    /// Arm the service's fault-tolerance machinery (DESIGN.md §13):
    /// bounded retry, execute watchdog, scripted fault injection, and
    /// `spares` pre-forked standby engines for quarantine respawn. Spares
    /// beyond what [`crate::metrics::MAX_POOL`] admits next to the active
    /// replicas are dropped. Ignored unless `pipeline.service` is on.
    pub fn with_recovery(mut self, recovery: RecoveryConfig, spares: usize) -> Self {
        self.recovery = Some((recovery, spares));
        self
    }

    /// Run the full loop; returns the complete run record.
    pub fn run<P: Policy + ForkEngine>(
        &self,
        policy: &mut P,
        spec: CurriculumSpec,
        dataset: &Dataset,
        evals: &[EvalSet],
    ) -> Result<RunRecord> {
        self.run_resumed(policy, spec, dataset, evals, None).map(|(record, _)| record)
    }

    /// [`run`](Self::run) continuing from a restored [`PipelineResume`]
    /// (`None` = a fresh run). Also returns the final prompt-loader state,
    /// which the checkpoint driver persists so a later resume continues
    /// the same prompt stream.
    pub fn run_resumed<P: Policy + ForkEngine>(
        &self,
        policy: &mut P,
        spec: CurriculumSpec,
        dataset: &Dataset,
        evals: &[EvalSet],
        resume: Option<PipelineResume>,
    ) -> Result<(RunRecord, Loader)> {
        if !self.pipeline.enabled || self.pipeline.workers == 0 {
            // The safety rail: the serial trainer IS the reference path.
            // Resume is refused here rather than half-supported: a
            // `PipelineResume` carries no curriculum state (buffered
            // groups / pending continuations), so restoring through this
            // fallback would silently drop it — serial resumes go through
            // the driver's serial path, which restores everything.
            anyhow::ensure!(
                resume.is_none(),
                "cannot resume through the disabled-pipeline fallback; run the serial \
                 driver path instead (it restores curriculum state)"
            );
            let mut curriculum = spec.build();
            let trainer = Trainer::new(self.config.clone(), self.algo);
            let mut state =
                TrainState::fresh(dataset.len(), self.config.seed, self.config.label.clone());
            trainer.run_segment(
                policy,
                curriculum.as_mut(),
                dataset,
                evals,
                &mut state,
                self.config.max_steps,
            )?;
            let mut record = state.record;
            record.counters = state.counters;
            return Ok((record, state.loader));
        }

        let b = self.config.batch_size;
        // Batch accounting is in rollouts (per-prompt budgets make group
        // sizes heterogeneous): the learner pops `b * n_total` rows per
        // step, which may span more than `b` groups when the allocator
        // issues below-reference budgets — the buffer capacity and the
        // production cap must be sized in groups accordingly. With the
        // fixed allocator `groups_per_batch == b` and both reduce to the
        // pre-refactor values exactly.
        let target_rows = b * spec.rule.n_total();
        let groups_per_batch = target_rows.div_ceil(spec.alloc.min_n_total().max(1)).max(b);
        let shared = Arc::new(SharedBuffer::new(self.pipeline.buffer_cap.max(groups_per_batch)));
        // Resume: the learner's restored accounting; workers themselves are
        // always fresh (their prefetch state is not checkpointed — see
        // `PipelineResume`).
        let (start_step, init_update_s, init_counters, init_record, init_loader) = match resume {
            Some(res) => {
                (res.start_step, res.update_s, res.counters, Some(res.record), Some(res.loader))
            }
            None => (0, 0.0, InferenceCounters::default(), None, None),
        };
        // Production is capped at what the learner can still consume, so
        // workers wind down instead of burning inference at run end.
        let remaining_steps = self.config.max_steps.saturating_sub(start_step);
        let demand = (remaining_steps as u64).saturating_mul(groups_per_batch as u64);
        shared.set_demand(demand);
        let loader = Arc::new(Mutex::new(
            init_loader.unwrap_or_else(|| Loader::new(dataset.len(), self.config.seed)),
        ));
        let dataset = Arc::new(dataset.clone());
        let counters = Arc::new(AtomicCounters::default());
        counters.add(&init_counters); // resumed totals keep accumulating
        let weights = Arc::new(WeightStore::new(policy.snapshot()));
        let stop = Arc::new(AtomicBool::new(false));
        // The learner's step clock; workers stamp groups with it (born_step).
        let clock = Arc::new(AtomicUsize::new(start_step));
        let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        // With the service on, a pool of E real engines (fork streams
        // 0..E) sits behind the coalescing router and every worker gets a
        // cheap submit handle advertising capacity x E / K rows; weights
        // install once per version per replica instead of K times.
        let service = self.pipeline.service.then(|| {
            let e = self.engines.max(1);
            let engines: Vec<_> = (0..e).map(|r| policy.fork_engine(r as u64)).collect();
            // The quantum must admit the LARGEST possible group: with
            // adaptive budgets that is n_init + n_cont_max, not the
            // rule's reference total.
            let min_quantum = spec.alloc.max_n_total();
            match &self.recovery {
                Some((recovery, spares)) => {
                    // Spares fork on streams E.. so their RNG streams never
                    // collide with an active replica's; the pool caps total
                    // slots at MAX_POOL.
                    let n_spares = (*spares).min(crate::metrics::MAX_POOL - e);
                    let spares: Vec<_> =
                        (0..n_spares).map(|s| policy.fork_engine((e + s) as u64)).collect();
                    InferenceService::spawn_pool_with_recovery(
                        engines,
                        spares,
                        self.pipeline.service_cfg,
                        recovery.clone(),
                        self.pipeline.workers,
                        min_quantum,
                    )
                }
                None => InferenceService::spawn_pool(
                    engines,
                    self.pipeline.service_cfg,
                    self.pipeline.workers,
                    min_quantum,
                ),
            }
        });

        let pool = ThreadPool::new(self.pipeline.workers);
        for w in 0..self.pipeline.workers {
            let engine: Box<dyn RolloutEngine + Send> = match &service {
                Some(svc) => Box::new(svc.handle()),
                None => policy.fork_engine(w as u64),
            };
            // Each worker builds its own curriculum from a spec clone; the
            // clones share `Arc` state (e.g. the difficulty predictor's
            // store), so observations merge run-wide.
            let spec = spec.clone();
            let shared = Arc::clone(&shared);
            let counters = Arc::clone(&counters);
            let weights = Arc::clone(&weights);
            let stop = Arc::clone(&stop);
            let clock = Arc::clone(&clock);
            let errors = Arc::clone(&errors);
            let source =
                SharedSource { loader: Arc::clone(&loader), dataset: Arc::clone(&dataset) };
            let temperature = self.config.temperature;
            pool.execute(move || {
                if crate::trace::enabled() {
                    crate::trace::set_thread_label(&format!("worker-{w}"));
                }
                rollout_worker(
                    engine, spec, source, shared, counters, weights, stop, clock, errors,
                    temperature, b,
                )
            });
        }

        let mut record = init_record.unwrap_or_else(|| RunRecord {
            label: self.config.label.clone(),
            ..Default::default()
        });
        let result = self.consume(
            policy,
            &shared,
            &loader,
            &counters,
            &weights,
            &clock,
            evals,
            service.as_ref(),
            target_rows,
            &mut record,
            start_step,
            init_update_s,
            init_counters,
        );

        // Shutdown: wake every blocked worker, then join (ThreadPool drop).
        // The service outlives the pool so workers blocked on tickets are
        // served (deadline-dispatched) before they observe the closed
        // buffer and exit; only then is the scheduler closed and joined.
        stop.store(true, Ordering::Relaxed);
        shared.close();
        drop(pool);
        record.counters = counters.snapshot();
        if let Some(svc) = &service {
            // A resumed/segmented record may already carry earlier service
            // generations' totals: fold them in instead of overwriting.
            let mut stats = svc.stats();
            if let Some(prev) = record.service.take() {
                stats.merge(&prev);
            }
            record.service = Some(stats);
        }
        drop(service);
        result?;
        let errs = plock(&errors);
        if !errs.is_empty() {
            bail!("rollout worker failed: {}", errs.join("; "));
        }
        // Workers are joined: the loader is quiescent, and its state here
        // is what a warm resume must continue from.
        let loader_out = Loader::from_state(&plock(&loader).state());
        Ok((record, loader_out))
    }

    /// The learner side: pop exactly-`B` batches, update, publish weights.
    #[allow(clippy::too_many_arguments)]
    fn consume<P: Policy + ForkEngine>(
        &self,
        policy: &mut P,
        shared: &SharedBuffer,
        loader: &Mutex<Loader>,
        counters: &AtomicCounters,
        weights: &WeightStore,
        clock: &AtomicUsize,
        evals: &[EvalSet],
        service: Option<&InferenceService>,
        target_rows: usize,
        record: &mut RunRecord,
        start_step: usize,
        init_update_s: f64,
        init_counters: InferenceCounters,
    ) -> Result<()> {
        if crate::trace::enabled() {
            crate::trace::set_thread_label("learner");
        }
        // Step-0 evaluation so every curve starts at the base model (a
        // resumed record already carries it).
        if start_step == 0 && record.evals.is_empty() {
            let t_eval = crate::trace::start();
            evaluate_all(policy, evals, 0, 0.0, record)?;
            crate::trace::span("evaluate", "learner", t_eval, 0);
        }
        let mut update_s = init_update_s;
        // Per-step deltas difference against the restored totals, so the
        // resumed run's first step reports its own activity, not the whole
        // history's.
        let mut prev_snap = init_counters;
        let mut prev_svc = ServiceCounters::default();

        for step in start_step..self.config.max_steps {
            let version = policy.weight_version();
            let Some(batch) = shared.pop_rollouts(target_rows, step, version) else {
                break; // closed early: a worker failed (caller reports it)
            };
            let groups: Vec<_> =
                batch.into_iter().filter(|g| self.algo.keep_group(&g.rewards())).collect();

            let train_pass_rate = if groups.is_empty() {
                0.0
            } else {
                groups.iter().map(|g| g.pass_rate()).sum::<f64>() / groups.len() as f64
            };

            let mut algo = self.algo;
            algo.lr = self.algo.lr_at(step);
            let t_update = crate::trace::start();
            let tr = policy.train(&groups, &algo)?;
            crate::trace::span("optimizer-update", "learner", t_update, step as i64);
            update_s += tr.cost_s;
            let t_publish = crate::trace::start();
            weights.publish(policy.snapshot());
            crate::trace::span("weight-publish", "learner", t_publish, (step + 1) as i64);
            clock.store(step + 1, Ordering::Relaxed);

            // The record keeps the paper's time = inference + update
            // convention over all inference ISSUED so far (prefetch
            // included — compute spent, not compute consumed); the
            // wall-clock win of overlapping shows up in real steps/sec
            // (bench_micro), not in this virtual total.
            let counter_snap = counters.snapshot();
            let inference_s = counter_snap.cost_s;
            let time_s = inference_s + update_s;
            let stats = shared.stats();
            let (step_skip_rate, step_explore_rate) = step_rates(&prev_snap, &counter_snap);
            let alloc_rows = step_alloc_rows(&prev_snap, &counter_snap);
            prev_snap = counter_snap;
            // Per-step service deltas (same convention as the skip rates):
            // cumulative means would blur the warm-up the charts exist for.
            let (
                service_calls,
                service_fill,
                service_queue_wait_s,
                pool_balance,
                service_queue_wait_p95_s,
                service_exec_p95_s,
                service_faults,
                service_retries,
                slot_occupancy,
            ) = match service.map(|s| s.stats()) {
                Some(cur) => {
                    let d_calls = cur.calls.saturating_sub(prev_svc.calls);
                    let d_rows = cur.rows_used.saturating_sub(prev_svc.rows_used);
                    let d_cap = cur.rows_capacity.saturating_sub(prev_svc.rows_capacity);
                    let d_subs = cur.submissions.saturating_sub(prev_svc.submissions);
                    let d_wait = cur.queue_wait_s - prev_svc.queue_wait_s;
                    let d_disp = cur.pool_dispatches.saturating_sub(prev_svc.pool_dispatches);
                    let d_busy = cur.pool_busy_sum.saturating_sub(prev_svc.pool_busy_sum);
                    let d_faults = cur.faults_injected.saturating_sub(prev_svc.faults_injected);
                    let d_retries = cur.retries.saturating_sub(prev_svc.retries);
                    let d_osum =
                        cur.slot_occupancy_sum.saturating_sub(prev_svc.slot_occupancy_sum);
                    let d_ocap = cur.slot_capacity_sum.saturating_sub(prev_svc.slot_capacity_sum);
                    let engines = cur.engines;
                    // Step-local latency histograms: bucket deltas, then the
                    // p95 upper-edge estimate (trace::hist_quantile).
                    let mut d_qwait = [0u64; crate::trace::HIST_BUCKETS];
                    let mut d_exec = [0u64; crate::trace::HIST_BUCKETS];
                    for i in 0..crate::trace::HIST_BUCKETS {
                        d_qwait[i] =
                            cur.queue_wait_hist[i].saturating_sub(prev_svc.queue_wait_hist[i]);
                        d_exec[i] = cur.exec_hist[i].saturating_sub(prev_svc.exec_hist[i]);
                    }
                    prev_svc = cur;
                    (
                        d_calls,
                        if d_cap == 0 { 0.0 } else { d_rows as f64 / d_cap as f64 },
                        if d_subs == 0 { 0.0 } else { d_wait / d_subs as f64 },
                        if d_disp == 0 || engines == 0 {
                            0.0
                        } else {
                            d_busy as f64 / (d_disp * engines) as f64
                        },
                        crate::trace::hist_quantile(&d_qwait, 0.95),
                        crate::trace::hist_quantile(&d_exec, 0.95),
                        d_faults,
                        d_retries,
                        if d_ocap == 0 { 0.0 } else { d_osum as f64 / d_ocap as f64 },
                    )
                }
                None => (0, 0.0, 0.0, 0.0, 0.0, 0.0, 0, 0, 0.0),
            };
            record.steps.push(StepRecord {
                step,
                time_s,
                inference_s,
                update_s,
                train_pass_rate,
                grad_norm: tr.grad_norm,
                loss: tr.loss,
                clip_frac: tr.clip_frac,
                prompts_consumed: plock(&loader).consumed(),
                buffer_len: stats.len,
                mean_staleness: stats.mean_staleness,
                prompts_skipped: counter_snap.prompts_skipped,
                rollouts_saved: counter_snap.rollouts_saved,
                predictor_brier: counter_snap.predictor_brier(),
                step_skip_rate,
                step_explore_rate,
                service_calls,
                service_fill,
                service_queue_wait_s,
                pool_balance,
                service_queue_wait_p95_s,
                service_exec_p95_s,
                rollouts: counter_snap.rollouts,
                step_alloc_rows: alloc_rows,
                alloc_calibration: counter_snap.alloc_calibration(),
                service_faults,
                service_retries,
                slot_occupancy,
            });

            if self.config.eval_every > 0 && (step + 1) % self.config.eval_every == 0 {
                let t_eval = crate::trace::start();
                evaluate_all(policy, evals, step + 1, time_s, record)?;
                crate::trace::span("evaluate", "learner", t_eval, (step + 1) as i64);
                if let Some((bench, target)) = &self.config.stop_at_target {
                    if target_reached(record, bench, *target) {
                        crate::info!(
                            "pipeline",
                            "{}: target {target} on {bench} reached at step {} ({:.1}s)",
                            self.config.label,
                            step + 1,
                            time_s
                        );
                        break;
                    }
                }
            }
            if time_s >= self.config.max_seconds {
                break;
            }
        }
        Ok(())
    }
}

/// Converts a worker panic into the regular failure path: without this a
/// panicking worker would die silently and the learner would block in
/// `pop_rollouts` forever.
struct PanicGuard {
    shared: Arc<SharedBuffer>,
    errors: Arc<Mutex<Vec<String>>>,
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // plock: a peer's poison must not stop this panic from being
            // reported (the error list stays consistent — push-only).
            plock(&self.errors).push("rollout worker panicked".to_string());
            self.shared.close();
        }
    }
}

/// One rollout worker: pull prompts from the shared loader, run the
/// curriculum's screening/continuation against a private engine, push
/// qualified groups into the shared buffer. Runs until stopped, closed,
/// demand-exhausted, or errored.
#[allow(clippy::too_many_arguments)]
fn rollout_worker(
    mut engine: Box<dyn crate::policy::RolloutEngine + Send>,
    spec: CurriculumSpec,
    mut source: SharedSource,
    shared: Arc<SharedBuffer>,
    counters: Arc<AtomicCounters>,
    weights: Arc<WeightStore>,
    stop: Arc<AtomicBool>,
    clock: Arc<AtomicUsize>,
    errors: Arc<Mutex<Vec<String>>>,
    temperature: f32,
    chunk: usize,
) {
    let _guard =
        PanicGuard { shared: Arc::clone(&shared), errors: Arc::clone(&errors) };
    let mut curriculum = spec.build();
    loop {
        if stop.load(Ordering::Relaxed) || shared.is_closed() || shared.remaining_demand() == 0 {
            return;
        }
        // Weight-version handoff: install the latest snapshot before
        // collecting. Groups are stamped with the clock at the collect that
        // *returns* them, so `mean_staleness` measures shared-buffer
        // residency; residency inside the worker's own SPEED buffer is
        // tracked by that curriculum itself, exactly as in the serial
        // trainer.
        if engine.serving_version() != weights.version() {
            engine.install(&weights.get());
        }
        // Stamp groups with the version serving when this collect BEGAN: a
        // private engine cannot change mid-collect, but behind the shared
        // service another worker's install advances the advertised version
        // at any time — reading it after the collect would under-report
        // the buffer's version-lag staleness.
        let version = engine.serving_version();
        let born_step = clock.load(Ordering::Relaxed);
        let mut local = InferenceCounters::default();
        let t0 = std::time::Instant::now();
        let collected = {
            let mut ctx = StepContext {
                engine: &mut *engine,
                prompts: &mut source,
                train_step: born_step,
                temperature,
                counters: &mut local,
            };
            curriculum.collect_batch(&mut ctx, chunk)
        };
        local.busy_s = t0.elapsed().as_secs_f64();
        crate::trace::span_from("collect-batch", "worker", t0, born_step as i64);
        counters.add(&local);
        match collected {
            Ok(groups) => {
                for group in groups {
                    if !shared.push(group, born_step, version) {
                        return; // closed or demand satisfied
                    }
                }
            }
            Err(e) => {
                plock(&errors).push(format!("{e:#}"));
                shared.close();
                return;
            }
        }
    }
}
