//! Self-contained substrates (no tokio/serde/clap/criterion offline).

pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
