//! Small fixed-size worker pool (tokio is unavailable offline).
//!
//! Used to parallelize CPU-side work that sits next to the PJRT calls:
//! response verification, prompt packing, and SimPolicy sweeps. The pool is
//! deliberately simple: submit closures, wait for completion; `scoped_map`
//! provides a rayon-like parallel map over a slice.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("speedrl-worker-{i}"))
                    .spawn(move || loop {
                        let job = { crate::util::sync::plock(&rx).recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of workers to use by default: cores - 1, clamped to [1, 16].
    pub fn default_size() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1))
            .unwrap_or(4)
            .clamp(1, 16)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("worker alive");
    }

    /// Parallel map over owned items; preserves order. Blocks until done.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("all jobs completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        for _ in rx {}
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
