//! Small statistics toolkit: summaries, EMA smoothing, quantiles.
//!
//! Used by the metrics layer (training curves), the bench harness
//! (median/MAD timing), and the SimPolicy calibration code.

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub var: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: f64::NAN, var: f64::NAN, min: f64::NAN, max: f64::NAN };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Summary {
            n: xs.len(),
            mean,
            var,
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    pub fn std(&self) -> f64 {
        self.var.sqrt()
    }
}

/// Quantile with linear interpolation (q in [0,1]); sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Median absolute deviation (robust spread for bench timings).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Exponential moving average smoother (the paper's Figure 6 bold curves).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

/// Smooth a whole curve with an EMA (returns same length).
pub fn ema_curve(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut ema = Ema::new(alpha);
    xs.iter().map(|&x| ema.update(x)).collect()
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: usize,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }
}

/// First step index where the (EMA-smoothed) curve crosses `target`.
/// Mirrors the paper's "wall-clock hours to reach target accuracy" metric:
/// callers pass cumulative times and read off `times[idx]`.
pub fn first_crossing(curve: &[f64], target: f64) -> Option<usize> {
    curve.iter().position(|&v| v >= target)
}

/// Simple least-squares line fit; returns (slope, intercept).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, sy / n.max(1.0));
    }
    let slope = (n * sxy - sx * sy) / denom;
    (slope, (sy - slope * sx) / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.var - 1.25).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn welford_matches_summary() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.var() - s.var).abs() < 1e-12);
    }

    #[test]
    fn crossing() {
        let curve = [0.1, 0.2, 0.35, 0.5];
        assert_eq!(first_crossing(&curve, 0.3), Some(2));
        assert_eq!(first_crossing(&curve, 0.9), None);
    }

    #[test]
    fn fit_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (m, b) = linear_fit(&xs, &ys);
        assert!((m - 2.0).abs() < 1e-9 && (b - 1.0).abs() < 1e-9);
    }
}
