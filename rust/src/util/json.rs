//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, golden
//! fixtures, run configs, and structured experiment logs. Supports the full
//! JSON grammar; numbers are kept as f64 (adequate for every producer here).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ----
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][3]`-style path access: keys separated by '.'.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    /// Number as u64. Lossy above 2^53 (JSON numbers are f64): payloads
    /// that can exceed it (identity hashes, RNG state) are string-encoded
    /// instead — see `crate::checkpoint::ju64`.
    pub fn as_u64_lossy(&self) -> Option<u64> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as u64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    pub fn as_i32_vec(&self) -> Option<Vec<i32>> {
        self.as_arr()?.iter().map(|x| x.as_f64().map(|f| f as i32)).collect()
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---- parse ----
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ---- write ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_nan() {
                    // Match the python-style literals the parser accepts;
                    // Rust's Display would print "NaN"/"inf", and "inf"
                    // could never be parsed back (e.g. a saved RunConfig
                    // with the default max_seconds = infinity).
                    out.push_str("NaN");
                } else if x.is_infinite() {
                    out.push_str(if *x > 0.0 { "Infinity" } else { "-Infinity" });
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    // `-0.0 as i64` is 0: keep the sign so every finite
                    // f64 round-trips bit-exactly (the checkpoint rail).
                    if *x == 0.0 && x.is_sign_negative() {
                        out.push_str("-0");
                    } else {
                        out.push_str(&format!("{}", *x as i64));
                    }
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)), // python json emits these
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: only BMP producers here; map
                            // lone surrogates to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr(vec![Json::Bool(true), Json::Null, Json::str("x\"y\n")])),
            ("c", Json::obj(vec![("nested", Json::num(-3))])),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_python_style_output() {
        let text = r#"{"name": "nano", "shape": [64, 32], "ok": true, "x": 1e-6, "neg": -2.5E3}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.path("name").unwrap().as_str().unwrap(), "nano");
        assert_eq!(v.path("shape").unwrap().as_usize_vec().unwrap(), vec![64, 32]);
        assert_eq!(v.path("x").unwrap().as_f64().unwrap(), 1e-6);
        assert_eq!(v.path("neg").unwrap().as_f64().unwrap(), -2500.0);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#"{"s": "é\t\\ π"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "é\t\\ π");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![("k", Json::arr(vec![Json::num(1), Json::num(2)]))]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }

    #[test]
    fn non_finite_numbers_roundtrip_through_parser_literals() {
        // Rust's Display prints "inf", which the parser rejects; the
        // writer must emit the python-style literals it accepts (a default
        // RunConfig carries max_seconds = infinity).
        assert_eq!(Json::num(f64::INFINITY).to_string(), "Infinity");
        assert_eq!(Json::num(f64::NEG_INFINITY).to_string(), "-Infinity");
        assert_eq!(Json::num(f64::NAN).to_string(), "NaN");
        let back = Json::parse(&Json::num(f64::INFINITY).to_string()).unwrap();
        assert_eq!(back.as_f64(), Some(f64::INFINITY));
        let back = Json::parse(&Json::num(f64::NEG_INFINITY).to_string()).unwrap();
        assert_eq!(back.as_f64(), Some(f64::NEG_INFINITY));
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        // The checkpoint rail rests on this: every finite f64 the writer
        // emits parses back to the same bits.
        for x in [0.1 + 0.2, 1.0 / 3.0, 5.3e-4, f64::MIN_POSITIVE, -123456.789012345, -0.0, 0.0]
        {
            let back = Json::parse(&Json::num(x).to_string()).unwrap();
            assert_eq!(back.as_f64().unwrap().to_bits(), x.to_bits(), "{x}");
        }
    }
}
