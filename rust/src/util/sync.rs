//! Poison-recovering lock primitives (DESIGN.md §13) and the crate's
//! concurrency abstraction point (DESIGN.md §15).
//!
//! A thread that panics while holding a `Mutex` poisons it; every later
//! `lock().unwrap()` then panics too, cascading one replica's death into
//! every worker that touches the shared state. The service's containment
//! story (catch_unwind + typed errors to every ticket) only works if the
//! survivors can still *take* the lock — so the service and the shared
//! buffer route every acquisition through these helpers, which recover
//! the guard from a poisoned lock instead of propagating the panic.
//!
//! Recovery is sound here because the protected states are kept
//! transactionally consistent: every writer either completes its update
//! under the guard or performs only field-at-a-time writes that leave the
//! invariants intact (queue push/pop, counter bumps, flag stores) — there
//! are no multi-step updates that a mid-panic could tear.
//!
//! ## The loom swap point
//!
//! [`SyncMutex`], [`SyncCondvar`] and [`SyncArc`] are the primitives the
//! two model-checked protocols — `SharedBuffer` push/pop/backpressure
//! (`coordinator/buffer.rs`) and the pool's exactly-once seized-slot claim
//! path (`policy/service.rs`) — declare their shared state with. They are
//! plain aliases for the `std::sync` types today; when a vendored `loom`
//! crate is available, flipping these aliases to `loom::sync::*` under
//! `--cfg loom` (and re-targeting the helpers below at the alias types)
//! swaps the model checker into both protocols without touching either
//! module. Until then the exhaustive-interleaving explorer in
//! `analysis::model` checks the same protocols as abstract state machines
//! (`rust/tests/loom_sync.rs`), and `rust/ci.sh`'s loom leg soft-skips.
//! The `speed-rl lint` L1 pass enforces that no raw `.lock()`/`.wait()`
//! on these primitives appears outside this module.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// The mutex type the modeled sync protocols are declared with — the
/// single place a `--cfg loom` build would substitute `loom::sync::Mutex`.
pub type SyncMutex<T> = Mutex<T>;

/// The condvar type the modeled sync protocols are declared with.
pub type SyncCondvar = Condvar;

/// The shared-ownership type the modeled sync protocols are declared with.
pub type SyncArc<T> = std::sync::Arc<T>;

/// `m.lock()` that shrugs off poisoning: a panicked peer marks the mutex
/// poisoned, but the data is still there and still consistent (see module
/// docs) — take the guard and carry on.
pub fn plock<T>(m: &SyncMutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Poison-recovering [`Condvar::wait`].
pub fn pwait<'a, T>(cv: &SyncCondvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Poison-recovering [`Condvar::wait_timeout`].
pub fn pwait_timeout<'a, T>(
    cv: &SyncCondvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn plock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        // Poison it: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("injected");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = plock(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn pwait_timeout_times_out_and_returns_the_guard() {
        let m = Mutex::new(0u32);
        let cv = Condvar::new();
        let g = plock(&m);
        let (g, res) = pwait_timeout(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
        assert_eq!(*g, 0);
    }

    #[test]
    fn pwait_wakes_on_notify_even_after_poisoning() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        // Poison the mutex first.
        let p2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = p2.0.lock().unwrap();
            panic!("injected");
        })
        .join();
        // A waiter must still see the flag flip through the poisoned lock.
        let p3 = Arc::clone(&pair);
        let waker = std::thread::spawn(move || {
            let mut done = plock(&p3.0);
            *done = true;
            p3.1.notify_all();
        });
        let mut g = plock(&pair.0);
        while !*g {
            g = pwait(&pair.1, g);
        }
        waker.join().unwrap();
    }

    /// The cross-thread recovery scenario PR 8's containment story rests
    /// on: a holder flips the protected flag, notifies, then dies with the
    /// guard — poisoning the mutex on unwind. The waiter's wakeup
    /// reacquisition therefore observes the poison (the holder's release
    /// IS the panic-drop), and `pwait` must hand back a consistent guard
    /// showing the completed write.
    #[test]
    fn pwait_recovers_when_the_holder_panics_mid_wait() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let holder = std::thread::spawn(move || {
            let mut g = p2.0.lock().unwrap();
            *g = true;
            p2.1.notify_all();
            // Unwind with the guard held: the release that lets the waiter
            // reacquire is the poisoning drop itself.
            panic!("injected holder death");
        });
        let mut g = plock(&pair.0);
        while !*g {
            g = pwait(&pair.1, g);
        }
        assert!(*g, "waiter recovered the guard but saw a torn write");
        drop(g);
        assert!(holder.join().is_err(), "holder was scripted to panic");
        assert!(pair.0.is_poisoned());
    }

    /// Timeout-path twin of the test above: the holder poisons the mutex
    /// with no notify at all, and a `pwait_timeout` waiter must both time
    /// out AND recover the poisoned guard with the holder's write intact.
    #[test]
    fn pwait_timeout_recovers_a_lock_poisoned_by_another_thread() {
        let pair = Arc::new((Mutex::new(0u32), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let holder = std::thread::spawn(move || {
            let mut g = p2.0.lock().unwrap();
            *g = 7;
            panic!("injected holder death");
        });
        assert!(holder.join().is_err(), "holder was scripted to panic");
        assert!(pair.0.is_poisoned());
        let g = plock(&pair.0);
        let (g, res) = pwait_timeout(&pair.1, g, Duration::from_millis(1));
        assert!(res.timed_out());
        assert_eq!(*g, 7, "recovered guard must show the holder's last write");
    }
}
