//! Poison-recovering lock primitives (DESIGN.md §13).
//!
//! A thread that panics while holding a `Mutex` poisons it; every later
//! `lock().unwrap()` then panics too, cascading one replica's death into
//! every worker that touches the shared state. The service's containment
//! story (catch_unwind + typed errors to every ticket) only works if the
//! survivors can still *take* the lock — so the service and the shared
//! buffer route every acquisition through these helpers, which recover
//! the guard from a poisoned lock instead of propagating the panic.
//!
//! Recovery is sound here because the protected states are kept
//! transactionally consistent: every writer either completes its update
//! under the guard or performs only field-at-a-time writes that leave the
//! invariants intact (queue push/pop, counter bumps, flag stores) — there
//! are no multi-step updates that a mid-panic could tear.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// `m.lock()` that shrugs off poisoning: a panicked peer marks the mutex
/// poisoned, but the data is still there and still consistent (see module
/// docs) — take the guard and carry on.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Poison-recovering [`Condvar::wait`].
pub fn pwait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Poison-recovering [`Condvar::wait_timeout`].
pub fn pwait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn plock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        // Poison it: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("injected");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = plock(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn pwait_timeout_times_out_and_returns_the_guard() {
        let m = Mutex::new(0u32);
        let cv = Condvar::new();
        let g = plock(&m);
        let (g, res) = pwait_timeout(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
        assert_eq!(*g, 0);
    }

    #[test]
    fn pwait_wakes_on_notify_even_after_poisoning() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        // Poison the mutex first.
        let p2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = p2.0.lock().unwrap();
            panic!("injected");
        })
        .join();
        // A waiter must still see the flag flip through the poisoned lock.
        let p3 = Arc::clone(&pair);
        let waker = std::thread::spawn(move || {
            let mut done = plock(&p3.0);
            *done = true;
            p3.1.notify_all();
        });
        let mut g = plock(&pair.0);
        while !*g {
            g = pwait(&pair.1, g);
        }
        waker.join().unwrap();
    }
}
