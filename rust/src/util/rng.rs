//! Deterministic, splittable PRNG (xoshiro256++) used everywhere in L3.
//!
//! Every stochastic component (data sampling, SimPolicy, property tests,
//! rollout keys handed to the compiled graphs) draws from a seeded `Rng` so
//! runs are exactly reproducible from the run config's seed.

/// xoshiro256++ by Blackman & Vigna (public domain reference rewritten).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 — used for seeding / stream splitting.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for a worker, a component, a step).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; bias is negligible for our n << 2^64 and
        // irrelevant for simulation purposes, but do one rejection round to
        // keep property tests honest.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Binomial(n, p) draw (direct for small n, normal approx for large).
    pub fn binomial(&mut self, n: u32, p: f64) -> u32 {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n <= 64 {
            (0..n).filter(|_| self.bool(p)).count() as u32
        } else {
            let mean = n as f64 * p;
            let sd = (n as f64 * p * (1.0 - p)).sqrt();
            let x = (mean + sd * self.normal()).round();
            x.clamp(0.0, n as f64) as u32
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Two fresh u32s — the PRNG key payload passed to the compiled graphs.
    pub fn jax_key(&mut self) -> [u32; 2] {
        [self.next_u32(), self.next_u32()]
    }

    /// The raw xoshiro256++ state, for warm-resume checkpoints: restoring
    /// it with [`from_state`](Self::from_state) continues the exact stream
    /// where it left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`state`](Self::state) snapshot. The
    /// all-zero state is a fixed point of xoshiro256++ (the stream would be
    /// constant zero), so it is re-seeded instead of trusted — a truncated
    /// or hand-rolled checkpoint cannot wedge the stream.
    pub fn from_state(s: [u64; 4]) -> Rng {
        if s == [0, 0, 0, 0] {
            return Rng::new(0);
        }
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_streams_independent_of_parent_consumption() {
        let mut a = Rng::new(7);
        let mut s1 = a.split(1);
        let mut s2 = a.split(1); // parent advanced -> different stream
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn binomial_moments() {
        let mut r = Rng::new(11);
        let n = 5000;
        let mean: f64 = (0..n).map(|_| r.binomial(24, 0.3) as f64).sum::<f64>() / n as f64;
        assert!((mean - 7.2).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::new(21);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the degenerate all-zero state is refused (re-seeded), not trusted
        let mut z = Rng::from_state([0; 4]);
        assert_ne!(z.next_u64(), 0);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let idx = r.sample_indices(20, 10);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
