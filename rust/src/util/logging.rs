//! Leveled stderr logger + CSV/JSONL file sinks (tracing is unavailable).
//!
//! The trainer writes one JSONL record per training step and per evaluation;
//! benches write CSV curves that EXPERIMENTS.md references.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: Mutex<Option<Instant>> = Mutex::new(None);

/// Pin the log epoch to "now". Called once at process startup (`main`)
/// and by the run drivers: without it the epoch was lazily set by the
/// *first log call*, so early lines always read `0.000s` and timestamps
/// were not comparable across sinks (or with the trace spine, which
/// shares this epoch via [`epoch`]). Idempotent — later calls keep the
/// first epoch.
pub fn init() {
    let _ = epoch();
}

/// The shared wall-clock epoch all log timestamps (and trace-event
/// timestamps) are measured from, initializing it to "now" on first use.
pub fn epoch() -> Instant {
    *crate::util::sync::plock(&START).get_or_insert_with(Instant::now)
}

/// Set the global log level (from `--log-level` or `SPEED_RL_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Level::Debug,
        "warn" => Level::Warn,
        "error" => Level::Error,
        _ => Level::Info,
    }
}

fn elapsed() -> f64 {
    epoch().elapsed().as_secs_f64()
}

pub fn log(level: Level, target: &str, msg: &str) {
    if (level as u8) < LEVEL.load(Ordering::Relaxed) {
        return;
    }
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{:9.3}s {tag} {target}] {msg}", elapsed());
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug_log {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

/// Append-only JSONL sink (one `Json` record per line).
pub struct JsonlSink {
    w: BufWriter<File>,
}

impl JsonlSink {
    pub fn create(path: &Path) -> anyhow::Result<JsonlSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(JsonlSink { w: BufWriter::new(File::create(path)?) })
    }

    pub fn write(&mut self, record: &Json) -> anyhow::Result<()> {
        writeln!(self.w, "{record}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// CSV sink with a fixed header.
pub struct CsvSink {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvSink {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<CsvSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvSink { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(values.len() == self.cols, "csv row width mismatch");
        let cells: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_pinned_by_init_and_stable() {
        init();
        let e1 = epoch();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let e2 = epoch();
        assert_eq!(e1, e2, "epoch must not move after init");
        assert!(e1.elapsed().as_secs_f64() > 0.0);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join(format!("speedrl_log_test_{}", std::process::id()));
        let path = dir.join("x.jsonl");
        let mut sink = JsonlSink::create(&path).unwrap();
        sink.write(&Json::obj(vec![("step", Json::num(1)), ("acc", Json::num(0.5))])).unwrap();
        sink.write(&Json::obj(vec![("step", Json::num(2)), ("acc", Json::num(0.6))])).unwrap();
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(Json::parse(lines[1]).unwrap().get("step").unwrap().as_i64(), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_enforces_width() {
        let dir = std::env::temp_dir().join(format!("speedrl_csv_test_{}", std::process::id()));
        let path = dir.join("x.csv");
        let mut sink = CsvSink::create(&path, &["a", "b"]).unwrap();
        sink.row(&[1.0, 2.0]).unwrap();
        assert!(sink.row(&[1.0]).is_err());
        sink.flush().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
