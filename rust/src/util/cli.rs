//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Each binary declares its options up front so `--help` is generated.

use std::collections::BTreeMap;

/// Declarative option spec for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0} (see --help)")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    Invalid(String, String),
}

pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub specs: Vec<OptSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Cli {
        Cli { program, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Cli {
        self.specs.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Cli {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <value>", spec.name)
            };
            let default = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("{head:<28} {}{default}\n", spec.help));
        }
        s
    }

    /// Parse; on `--help` prints help and exits.
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let known = |name: &str| self.specs.iter().find(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = known(&name).ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.is_flag {
                    args.flags.push(name);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    args.values.insert(name, value);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse::<T>()
            .map_err(|_| CliError::Invalid(name.to_string(), raw.to_string()))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.get_parsed(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.get_parsed(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.get_parsed(name)
    }

    pub fn string(&self, name: &str) -> Result<String, CliError> {
        self.get_parsed(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("steps", Some("100"), "number of steps")
            .opt("name", None, "run name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&["--name", "x"])).unwrap();
        assert_eq!(a.usize("steps").unwrap(), 100);
        assert_eq!(a.string("name").unwrap(), "x");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cli().parse(&argv(&["--steps=7", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.usize("steps").unwrap(), 7);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse(&argv(&["--bogus"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&argv(&["--name"])).is_err());
    }

    #[test]
    fn bad_parse_rejected() {
        let a = cli().parse(&argv(&["--steps", "abc"])).unwrap();
        assert!(a.usize("steps").is_err());
    }
}
