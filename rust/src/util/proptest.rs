//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a property against `cases` random
//! inputs drawn through the deterministic [`crate::util::rng::Rng`]. On
//! failure it reports the case seed so the exact input can be replayed with
//! `check_seeded`. Coordinator/RL invariants (routing, batching, buffer
//! state, advantage identities) are tested through this harness.

use crate::util::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Convenience assertion helpers for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

/// Run `prop` against `cases` random cases; panics with the failing seed.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    for case in 0..cases {
        let seed = base_seed(name) ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay one specific case by seed (for debugging failures).
pub fn check_seeded<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed on replay seed {seed:#x}: {msg}");
    }
}

fn base_seed(name: &str) -> u64 {
    // FNV-1a over the property name keeps cases stable across runs while
    // differing between properties.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check("sum-commutes", 50, |rng| {
            let a = rng.range_i64(-100, 100);
            let b = rng.range_i64(-100, 100);
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure_with_seed() {
        check("always-fails", 10, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.0, "x={x} not negative");
            Ok(())
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first = Vec::new();
        check("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
