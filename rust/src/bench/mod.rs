//! In-tree benchmark harness (criterion is unavailable offline).
//!
//! `harness = false` benches call [`BenchRunner`] for timed micro-sections
//! and use plain stdout tables for the paper-figure regenerations. Timing
//! methodology: warmup, then fixed-count timed iterations, reporting
//! median and MAD (robust to scheduler noise).

use std::time::Instant;

use crate::util::stats::{mad, median};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median_s
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters   median {:>12}   mad {:>12}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

pub struct BenchRunner {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 3, iters: 15 }
    }
}

impl BenchRunner {
    pub fn new(warmup: usize, iters: usize) -> BenchRunner {
        BenchRunner { warmup, iters }
    }

    /// Time `f` (which should perform one unit of work per call).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            median_s: median(&times),
            mad_s: mad(&times),
        };
        res.print();
        res
    }
}

/// Markdown-ish table printer for the paper-figure benches.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (cell, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {cell:<w$} |"));
            }
            s
        };
        println!("{}", line(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_times_work() {
        let r = BenchRunner::new(1, 5).run("noop-ish", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.median_s >= 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn table_checks_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
