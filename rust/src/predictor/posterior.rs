//! Discounted Beta posterior over a prompt's pass rate.
//!
//! Each tracked prompt identity carries pseudo-counts `(alpha, beta)` of
//! observed passes/fails. Before every new observation the counts are
//! multiplied by a discount `gamma < 1`, so the effective sample size is
//! bounded by `1 / (1 - gamma)` and the estimate tracks the *current*
//! policy's pass rate as training moves it (the non-stationarity that makes
//! a plain running average go stale).
//!
//! The quantity the skip rule needs is not the posterior mean but the
//! predictive probability that SPEED's screening test would accept the
//! prompt: `P(p_low < K/N_init < p_high)` with `K ~ BetaBinomial(N_init,
//! alpha, beta)` — the exact posterior-predictive analogue of
//! [`crate::rl::theory::acceptance_probability`], which it converges to as
//! the posterior concentrates.

/// Observed (discounted) pass/fail pseudo-counts for one prompt identity.
/// Prior mass is *not* stored here; [`super::Predictor`] blends the feature
/// model's prior in at prediction time.
#[derive(Clone, Copy, Debug, Default)]
pub struct BetaPosterior {
    pub alpha: f64,
    pub beta: f64,
}

impl BetaPosterior {
    /// Fold one batch of binary rewards in, discounting once per rollout so
    /// a batch update equals the same rollouts observed one at a time.
    pub fn observe(&mut self, rewards: &[f32], discount: f64) {
        for r in rewards {
            self.alpha *= discount;
            self.beta *= discount;
            if *r > 0.5 {
                self.alpha += 1.0;
            } else {
                self.beta += 1.0;
            }
        }
    }

    /// Discounted observation count (the posterior's evidence weight).
    pub fn weight(&self) -> f64 {
        self.alpha + self.beta
    }
}

/// Beta-Binomial pmf vector `P(K = k)` for `k = 0..=n`, `K` the number of
/// successes in `n` draws with success probability `p ~ Beta(a, b)`.
/// Computed by the stable pmf ratio recurrence (no gamma functions needed):
/// `P(0) = prod_i (b+i)/(a+b+i)`, then
/// `P(k+1) = P(k) * (n-k)/(k+1) * (a+k)/(b+n-k-1)`.
pub fn beta_binomial_pmf(n: usize, a: f64, b: f64) -> Vec<f64> {
    debug_assert!(a > 0.0 && b > 0.0, "Beta parameters must be positive");
    let nf = n as f64;
    let mut pmf = Vec::with_capacity(n + 1);
    let mut p0 = 1.0f64;
    for i in 0..n {
        p0 *= (b + i as f64) / (a + b + i as f64);
    }
    pmf.push(p0);
    let mut pk = p0;
    for k in 0..n {
        let kf = k as f64;
        pk *= (nf - kf) / (kf + 1.0) * (a + kf) / (b + nf - kf - 1.0);
        pmf.push(pk);
    }
    pmf
}

/// Posterior-predictive probability that the screening test accepts: the
/// Beta-Binomial mass on realized pass rates strictly inside `(p_low,
/// p_high)` — the same accepted-`k` set as
/// [`crate::rl::theory::acceptance_probability`], with the point pass rate
/// replaced by a `Beta(a, b)` belief.
pub fn predicted_acceptance(n_init: usize, a: f64, b: f64, p_low: f64, p_high: f64) -> f64 {
    let pmf = beta_binomial_pmf(n_init, a, b);
    let mut acc = 0.0;
    for (k, mass) in pmf.iter().enumerate() {
        let rate = k as f64 / n_init as f64;
        if rate > p_low && rate < p_high {
            acc += mass;
        }
    }
    acc.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::theory::acceptance_probability;
    use crate::util::proptest::check;
    use crate::prop_assert;

    #[test]
    fn pmf_sums_to_one() {
        check("beta-binomial-normalized", 40, |rng| {
            let n = rng.range_usize(1, 32);
            let a = 0.05 + 20.0 * rng.f64();
            let b = 0.05 + 20.0 * rng.f64();
            let pmf = beta_binomial_pmf(n, a, b);
            let sum: f64 = pmf.iter().sum();
            prop_assert!((sum - 1.0).abs() < 1e-9, "pmf sum {sum} (n={n}, a={a}, b={b})");
            prop_assert!(pmf.iter().all(|p| *p >= 0.0), "negative mass");
            Ok(())
        });
    }

    #[test]
    fn concentrated_posterior_recovers_point_acceptance() {
        // As alpha+beta -> inf at fixed mean p, the posterior predictive
        // must converge to the closed-form binomial acceptance probability.
        for &(n_init, p) in &[(8usize, 0.5f64), (8, 0.1), (4, 0.9), (6, 0.02)] {
            let scale = 5e6;
            let got = predicted_acceptance(n_init, scale * p, scale * (1.0 - p), 0.0, 1.0);
            let want = acceptance_probability(n_init, p, 0.0, 1.0);
            assert!(
                (got - want).abs() < 5e-3,
                "n={n_init} p={p}: predictive {got} vs point {want}"
            );
        }
    }

    #[test]
    fn discounting_bounds_evidence_and_tracks_shifts() {
        let mut post = BetaPosterior::default();
        let discount = 0.9;
        // Long run of passes: weight saturates at 1/(1-gamma) = 10.
        let passes = vec![1.0f32; 200];
        post.observe(&passes, discount);
        assert!(post.weight() <= 1.0 / (1.0 - discount) + 1e-9, "weight {}", post.weight());
        let mean_before = post.alpha / post.weight();
        assert!(mean_before > 0.95, "mean {mean_before}");
        // The pass rate collapses (policy drifted): 20 fails must drag the
        // mean most of the way down despite the long pass history.
        let fails = vec![0.0f32; 20];
        post.observe(&fails, discount);
        let mean_after = post.alpha / post.weight();
        assert!(mean_after < 0.15, "discounted posterior too sticky: {mean_after}");
    }

    #[test]
    fn batch_observe_matches_sequential() {
        let mut a = BetaPosterior::default();
        let mut b = BetaPosterior::default();
        let rewards = [1.0f32, 0.0, 1.0, 1.0, 0.0];
        a.observe(&rewards, 0.95);
        for r in rewards {
            b.observe(&[r], 0.95);
        }
        assert!((a.alpha - b.alpha).abs() < 1e-12);
        assert!((a.beta - b.beta).abs() < 1e-12);
    }

    #[test]
    fn strict_band_rejects_only_the_extremes() {
        // Default band (0,1): rejection mass = P(K=0) + P(K=n).
        let (a, b) = (2.0, 3.0);
        let n = 8;
        let pmf = beta_binomial_pmf(n, a, b);
        let accept = predicted_acceptance(n, a, b, 0.0, 1.0);
        assert!((accept - (1.0 - pmf[0] - pmf[n])).abs() < 1e-12);
    }
}
