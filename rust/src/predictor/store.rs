//! The difficulty store: per-prompt discounted Beta posteriors behind
//! sharded locks, shared by every rollout worker.
//!
//! The store is keyed by [`TaskInstance::identity`] (a stable hash of
//! family + level + prompt text, so the same instance re-drawn in a later
//! epoch hits the same posterior). K pipelined workers hold one `Arc` to a
//! single store; shards keep their observation merges from serializing on
//! one mutex, the same contention shape as
//! [`crate::metrics::AtomicCounters`] merges.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::predictor::posterior::BetaPosterior;

/// Shard count: enough to make contention negligible at the repo's worker
/// counts (K <= 8) while keeping the iteration cost of `len` trivial.
const N_SHARDS: usize = 16;

#[derive(Debug)]
pub struct DifficultyStore {
    shards: Vec<Mutex<HashMap<u64, BetaPosterior>>>,
}

impl Default for DifficultyStore {
    fn default() -> Self {
        DifficultyStore::new()
    }
}

impl DifficultyStore {
    pub fn new() -> DifficultyStore {
        DifficultyStore {
            shards: (0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<HashMap<u64, BetaPosterior>> {
        &self.shards[(key % N_SHARDS as u64) as usize]
    }

    /// Fold a batch of binary rewards into `key`'s posterior.
    pub fn observe(&self, key: u64, rewards: &[f32], discount: f64) {
        let mut shard = self.shard(key).lock().unwrap();
        shard.entry(key).or_default().observe(rewards, discount);
    }

    /// Current discounted counts for `key` (`None` if never observed).
    pub fn counts(&self, key: u64) -> Option<BetaPosterior> {
        self.shard(key).lock().unwrap().get(&key).copied()
    }

    /// Number of prompt identities tracked.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total discounted evidence across all identities (diagnostic).
    pub fn total_weight(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().values().map(|p| p.weight()).sum::<f64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn observe_and_read_back() {
        let store = DifficultyStore::new();
        assert!(store.counts(42).is_none());
        store.observe(42, &[1.0, 1.0, 0.0], 1.0);
        let post = store.counts(42).unwrap();
        assert_eq!(post.alpha, 2.0);
        assert_eq!(post.beta, 1.0);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn keys_are_independent() {
        let store = DifficultyStore::new();
        // Adjacent keys land in different shards; same-shard keys (stride
        // N_SHARDS) stay independent entries.
        store.observe(3, &[1.0], 1.0);
        store.observe(3 + N_SHARDS as u64, &[0.0], 1.0);
        assert_eq!(store.counts(3).unwrap().alpha, 1.0);
        assert_eq!(store.counts(3 + N_SHARDS as u64).unwrap().beta, 1.0);
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn concurrent_observations_all_land() {
        // 4 threads x 250 undiscounted observations over 8 shared keys:
        // total evidence must be conserved exactly (no lost updates).
        let store = Arc::new(DifficultyStore::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let store = Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    store.observe((t + i) % 8, &[1.0], 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!((store.total_weight() - 1000.0).abs() < 1e-9);
        assert_eq!(store.len(), 8);
    }
}
