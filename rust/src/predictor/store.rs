//! The difficulty store: per-prompt discounted Beta posteriors behind
//! sharded locks, shared by every rollout worker.
//!
//! The store is keyed by [`TaskInstance::identity`] (a stable hash of
//! family + level + prompt text, so the same instance re-drawn in a later
//! epoch hits the same posterior). K pipelined workers hold one `Arc` to a
//! single store; shards keep their observation merges from serializing on
//! one mutex, the same contention shape as
//! [`crate::metrics::AtomicCounters`] merges.
//!
//! Workers do not take a shard lock per observed group: they accumulate an
//! [`ObservationDelta`] locally during result processing and [`merge`] it
//! once per inference call — each shard lock is taken at most once per
//! merge, mirroring how `InferenceCounters` are merged into
//! `AtomicCounters` once per collect (ROADMAP item).
//!
//! [`TaskInstance::identity`]: crate::data::tasks::TaskInstance::identity
//! [`merge`]: DifficultyStore::merge

use std::collections::HashMap;
use std::sync::Mutex;

use crate::predictor::posterior::BetaPosterior;
use crate::util::sync::plock;

/// Worker-local batch of pending observations, kept in
/// observation-sequence order. The discounted fold is order-dependent per
/// key, so the runs for one key must be applied in the order they were
/// pushed — folding `r1 ++ r2` equals folding `r1` then `r2`, which is
/// what makes deferred merging exact. The former hash-map representation
/// preserved per-key order but applied *keys* in hash-iteration order,
/// which made merge traversal (and with it checkpoint/debug dumps of a
/// merge) nondeterministic across processes; a sequence of runs keeps the
/// whole delta in one deterministic order.
#[derive(Debug, Default)]
pub struct ObservationDelta {
    /// `(key, rewards)` runs in push order; a key pushed twice holds two
    /// runs whose relative order is its observation order.
    entries: Vec<(u64, Vec<f32>)>,
}

impl ObservationDelta {
    pub fn push(&mut self, key: u64, rewards: &[f32]) {
        // Coalesce into the previous run when it is the same key (the
        // common screening-then-continuation pattern); order is preserved
        // either way.
        if let Some((last_key, last)) = self.entries.last_mut() {
            if *last_key == key {
                last.extend_from_slice(rewards);
                return;
            }
        }
        self.entries.push((key, rewards.to_vec()));
    }

    /// Pending reward observations (rollouts, not keys).
    pub fn len(&self) -> usize {
        self.entries.iter().map(|(_, v)| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Shard count: enough to make contention negligible at the repo's worker
/// counts (K <= 8) while keeping the iteration cost of `len` trivial.
const N_SHARDS: usize = 16;

#[derive(Debug)]
pub struct DifficultyStore {
    shards: Vec<Mutex<HashMap<u64, BetaPosterior>>>,
}

impl Default for DifficultyStore {
    fn default() -> Self {
        DifficultyStore::new()
    }
}

impl DifficultyStore {
    pub fn new() -> DifficultyStore {
        DifficultyStore {
            shards: (0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<HashMap<u64, BetaPosterior>> {
        &self.shards[(key % N_SHARDS as u64) as usize]
    }

    /// Fold a batch of binary rewards into `key`'s posterior.
    pub fn observe(&self, key: u64, rewards: &[f32], discount: f64) {
        let mut shard = plock(self.shard(key));
        shard.entry(key).or_default().observe(rewards, discount);
    }

    /// Current discounted counts for `key` (`None` if never observed).
    pub fn counts(&self, key: u64) -> Option<BetaPosterior> {
        plock(self.shard(key)).get(&key).copied()
    }

    /// Merge a worker-local observation batch, taking each shard lock at
    /// most once (vs once per observed group for [`observe`]); the delta is
    /// drained so the caller's buffer is ready for the next accumulation.
    ///
    /// Runs are applied in observation-sequence order: the delta's push
    /// order is preserved when bucketing by shard (a stable partition), so
    /// each key's discounted fold sees its rewards exactly as they were
    /// observed and the traversal is deterministic — keys never interact
    /// across shards, so per-shard sequence order is global sequence order
    /// for every posterior.
    ///
    /// [`observe`]: DifficultyStore::observe
    pub fn merge(&self, delta: &mut ObservationDelta, discount: f64) {
        if delta.entries.is_empty() {
            return;
        }
        let mut by_shard: Vec<Vec<(u64, Vec<f32>)>> = (0..N_SHARDS).map(|_| Vec::new()).collect();
        for (key, rewards) in delta.entries.drain(..) {
            by_shard[(key % N_SHARDS as u64) as usize].push((key, rewards));
        }
        for (i, bucket) in by_shard.into_iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let mut shard = plock(&self.shards[i]);
            for (key, rewards) in bucket {
                shard.entry(key).or_default().observe(&rewards, discount);
            }
        }
    }

    /// Deterministic (key-sorted) dump of every identity's discounted
    /// counts — the store half of a warm-resume checkpoint. Sorting makes
    /// the serialized sidecar byte-stable across runs and hash seeds.
    pub fn snapshot(&self) -> Vec<(u64, BetaPosterior)> {
        let mut out: Vec<(u64, BetaPosterior)> = Vec::new();
        for shard in &self.shards {
            let guard = plock(shard);
            out.extend(guard.iter().map(|(k, p)| (*k, *p)));
        }
        out.sort_unstable_by_key(|(k, _)| *k);
        out
    }

    /// Replace the store's contents with a [`snapshot`](Self::snapshot)
    /// (the resume path). Callers quiesce writers first — restoring under
    /// concurrent observes would interleave old and new evidence.
    pub fn restore(&self, entries: &[(u64, BetaPosterior)]) {
        for shard in &self.shards {
            plock(shard).clear();
        }
        for (key, post) in entries {
            plock(self.shard(*key)).insert(*key, *post);
        }
    }

    /// Number of prompt identities tracked.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| plock(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total discounted evidence across all identities (diagnostic).
    pub fn total_weight(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| plock(s).values().map(|p| p.weight()).sum::<f64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn observe_and_read_back() {
        let store = DifficultyStore::new();
        assert!(store.counts(42).is_none());
        store.observe(42, &[1.0, 1.0, 0.0], 1.0);
        let post = store.counts(42).unwrap();
        assert_eq!(post.alpha, 2.0);
        assert_eq!(post.beta, 1.0);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn keys_are_independent() {
        let store = DifficultyStore::new();
        // Adjacent keys land in different shards; same-shard keys (stride
        // N_SHARDS) stay independent entries.
        store.observe(3, &[1.0], 1.0);
        store.observe(3 + N_SHARDS as u64, &[0.0], 1.0);
        assert_eq!(store.counts(3).unwrap().alpha, 1.0);
        assert_eq!(store.counts(3 + N_SHARDS as u64).unwrap().beta, 1.0);
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn batched_merge_equals_sequential_observes() {
        // The deferred path must be numerically identical to per-group
        // observes, including repeated keys (order preserved per key) and
        // discounting.
        let direct = DifficultyStore::new();
        let batched = DifficultyStore::new();
        let discount = 0.9;
        let obs: Vec<(u64, Vec<f32>)> = vec![
            (1, vec![1.0, 0.0, 1.0]),
            (2, vec![0.0; 4]),
            (1, vec![0.0, 1.0]),
            (2 + N_SHARDS as u64, vec![1.0]),
        ];
        let mut delta = ObservationDelta::default();
        for (key, rewards) in &obs {
            direct.observe(*key, rewards, discount);
            delta.push(*key, rewards);
        }
        assert_eq!(delta.len(), 10);
        batched.merge(&mut delta, discount);
        assert!(delta.is_empty(), "merge must drain the delta");
        for key in [1, 2, 2 + N_SHARDS as u64] {
            let a = direct.counts(key).unwrap();
            let b = batched.counts(key).unwrap();
            assert!((a.alpha - b.alpha).abs() < 1e-12, "key {key} alpha");
            assert!((a.beta - b.beta).abs() < 1e-12, "key {key} beta");
        }
        assert_eq!(direct.len(), batched.len());
        // merging an empty delta is a no-op
        batched.merge(&mut ObservationDelta::default(), discount);
        assert_eq!(batched.len(), 3);
    }

    #[test]
    fn merge_applies_runs_in_observation_sequence_order() {
        // Two runs for the same key in one delta must fold in push order —
        // the discounted fold makes [1,1,0] then [0,0] differ from the
        // reverse — and the traversal must not depend on any hash order.
        let store = DifficultyStore::new();
        let mut delta = ObservationDelta::default();
        delta.push(5, &[1.0, 1.0, 0.0]);
        delta.push(5 + N_SHARDS as u64, &[1.0]); // interleaved other key
        delta.push(5, &[0.0, 0.0]);
        store.merge(&mut delta, 0.8);
        let mut want = BetaPosterior::default();
        want.observe(&[1.0, 1.0, 0.0, 0.0, 0.0], 0.8);
        let got = store.counts(5).unwrap();
        assert!((got.alpha - want.alpha).abs() < 1e-12);
        assert!((got.beta - want.beta).abs() < 1e-12);
    }

    #[test]
    fn snapshot_is_sorted_and_restore_roundtrips() {
        let store = DifficultyStore::new();
        for key in [901u64, 7, 7 + N_SHARDS as u64, 3] {
            store.observe(key, &[1.0, 0.0, 1.0], 0.9);
        }
        let snap = store.snapshot();
        let keys: Vec<u64> = snap.iter().map(|(k, _)| *k).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "snapshot iteration order must be deterministic");
        assert_eq!(snap.len(), 4);

        let fresh = DifficultyStore::new();
        fresh.observe(999, &[0.0], 1.0); // stale content must be cleared
        fresh.restore(&snap);
        assert_eq!(fresh.len(), store.len());
        assert!(fresh.counts(999).is_none());
        for (key, post) in &snap {
            let got = fresh.counts(*key).unwrap();
            assert_eq!(got.alpha.to_bits(), post.alpha.to_bits(), "key {key}");
            assert_eq!(got.beta.to_bits(), post.beta.to_bits(), "key {key}");
        }
    }

    #[test]
    fn concurrent_observations_all_land() {
        // 4 threads x 250 undiscounted observations over 8 shared keys:
        // total evidence must be conserved exactly (no lost updates).
        let store = Arc::new(DifficultyStore::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let store = Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    store.observe((t + i) % 8, &[1.0], 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!((store.total_weight() - 1000.0).abs() < 1e-9);
        assert_eq!(store.len(), 8);
    }
}
