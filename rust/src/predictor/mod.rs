//! Online difficulty prediction: learned pass-rate estimation that
//! pre-screens prompts *before any rollout is spent*.
//!
//! SPEED's screening pass (paper §4.1) is cheap relative to continuation,
//! but it still burns `N_init` rollouts on every sampled prompt — including
//! the large mass whose pass rate is predictably 0 or 1 (Fig. 2's zero-pass
//! tail). Following the online difficulty-prediction line (arXiv
//! 2507.04632, 2602.01970), this subsystem routes that compute away before
//! inference happens:
//!
//! * [`store`]     — [`DifficultyStore`]: a discounted Beta posterior over
//!                   pass rate per prompt identity, updated from every
//!                   rollout observation and shared across pipelined
//!                   rollout workers (`Arc` + sharded locks).
//! * [`model`]     — [`FeatureModel`]: an online logistic model over task
//!                   features, trained from realized screening outcomes, so
//!                   *unseen* prompts are priced too (no cold-start
//!                   blindness).
//! * [`posterior`] — the discounted Beta algebra and the Beta-Binomial
//!                   posterior-predictive acceptance probability the skip
//!                   rule evaluates.
//! * [`Predictor`] — the facade the `predictive-speed` curriculum consults:
//!                   `decide` (skip / screen / explore), `observe_*`
//!                   (posterior + feature-model updates), `predict`.
//!
//! Skip rule: a prompt is skipped when the predicted probability that
//! screening would *reject* it reaches `skip_confidence` — i.e. the
//! posterior predictive puts at least that much mass on realized pass
//! rates outside the informative band `(p_low, p_high)`. Confidently
//! skipped prompts are still re-measured with probability `explore_rate`
//! so a wrong belief cannot lock a prompt out forever. `skip_confidence =
//! 1.0` disables skipping entirely, reproducing the plain `speed`
//! curriculum's batch stream exactly (asserted in
//! `rust/tests/predictor_sim.rs`).

pub mod model;
pub mod posterior;
pub mod store;

pub use model::{FeatureModel, FeatureModelState};
pub use posterior::{beta_binomial_pmf, predicted_acceptance, BetaPosterior};
pub use store::{DifficultyStore, ObservationDelta};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::screening::ScreeningRule;
use crate::data::tasks::TaskInstance;
use crate::rl::advantage::pass_rate;
use crate::util::sync::plock;
use crate::util::rng::Rng;

/// Knobs of the difficulty predictor (the `--skip-confidence`,
/// `--predictor-discount`, `--explore-rate` CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct PredictorConfig {
    /// Per-rollout exponential discount of the Beta pseudo-counts; bounds
    /// the effective sample size at `1/(1-discount)` so estimates track the
    /// policy's moving pass rate.
    pub discount: f64,
    /// Skip screening when the predicted rejection probability reaches this
    /// threshold. `1.0` = never skip (the plain SPEED semantics).
    pub skip_confidence: f64,
    /// Probability of screening a confidently-skipped prompt anyway.
    pub explore_rate: f64,
    /// Pseudo-observations the feature model's prediction contributes to an
    /// identity's pseudo-posterior (small: a few real observations dominate
    /// it).
    pub prior_strength: f64,
    /// Seed for the exploration streams handed to curriculum instances.
    pub seed: u64,
}

impl Default for PredictorConfig {
    fn default() -> Self {
        PredictorConfig {
            discount: 0.97,
            skip_confidence: 0.9,
            explore_rate: 0.05,
            prior_strength: 2.0,
            seed: 0,
        }
    }
}

/// One pass-rate forecast for a task.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    /// Blended posterior mean pass rate (feature-model prior + identity
    /// observations).
    pub mean: f64,
    /// Discounted per-identity evidence behind the forecast (0 = unseen,
    /// priced by the feature model alone).
    pub weight: f64,
    /// Posterior-predictive probability that screening would accept.
    pub accept_prob: f64,
    /// Whether the skip rule fires for this forecast.
    pub would_skip: bool,
}

/// What the curriculum should do with the next candidate prompt.
#[derive(Clone, Copy, Debug)]
pub enum Decision {
    /// Confidently uninformative: spend zero rollouts, move on.
    Skip(Prediction),
    /// Screen normally (the skip rule did not fire).
    Screen(Prediction),
    /// The skip rule fired but the exploration coin chose to re-measure.
    Explore(Prediction),
}

/// The shared difficulty predictor: one instance per run, `Arc`-shared by
/// every rollout worker's `predictive-speed` curriculum.
#[derive(Debug)]
pub struct Predictor {
    cfg: PredictorConfig,
    rule: ScreeningRule,
    store: DifficultyStore,
    model: Mutex<FeatureModel>,
    /// Counter handing each curriculum instance an exploration RNG stream.
    instances: AtomicU64,
}

impl Predictor {
    pub fn new(rule: ScreeningRule, cfg: PredictorConfig) -> Predictor {
        Predictor {
            cfg,
            rule,
            store: DifficultyStore::new(),
            model: Mutex::new(FeatureModel::default()),
            instances: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &PredictorConfig {
        &self.cfg
    }

    /// A fresh deterministic exploration-stream seed (stream 0 for the
    /// first — serial — curriculum instance).
    pub fn instance_seed(&self) -> u64 {
        let stream = self.instances.fetch_add(1, Ordering::Relaxed);
        self.cfg.seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Forecast a task's screening outcome: per-identity discounted
    /// observations, with the feature model contributing `prior_strength`
    /// pseudo-observations (all an unseen prompt has).
    pub fn predict(&self, task: &TaskInstance) -> Prediction {
        let obs = self.store.counts(task.identity()).unwrap_or_default();
        let m = plock(&self.model).predict(task).clamp(1e-3, 1.0 - 1e-3);
        let a = self.cfg.prior_strength * m + obs.alpha;
        let b = self.cfg.prior_strength * (1.0 - m) + obs.beta;
        let accept_prob =
            predicted_acceptance(self.rule.n_init, a, b, self.rule.p_low, self.rule.p_high);
        // `skip_confidence = 1.0` disables skipping outright (even when the
        // predicted rejection probability is exactly 1, as with a band no
        // realized rate can satisfy).
        let would_skip =
            self.cfg.skip_confidence < 1.0 && 1.0 - accept_prob >= self.cfg.skip_confidence;
        Prediction { mean: a / (a + b), weight: obs.weight(), accept_prob, would_skip }
    }

    /// The routing decision for one candidate prompt. Draws from `rng` only
    /// when the skip rule fires (so with skipping disabled the caller's RNG
    /// stream is untouched — the exact-equivalence rail).
    pub fn decide(&self, task: &TaskInstance, rng: &mut Rng) -> Decision {
        let pred = self.predict(task);
        if pred.would_skip {
            if rng.f64() < self.cfg.explore_rate {
                Decision::Explore(pred)
            } else {
                Decision::Skip(pred)
            }
        } else {
            Decision::Screen(pred)
        }
    }

    /// Fold a realized screening outcome in: updates the identity's
    /// posterior *and* the generalizing feature model.
    pub fn observe_screening(&self, task: &TaskInstance, rewards: &[f32]) {
        self.store.observe(task.identity(), rewards, self.cfg.discount);
        plock(&self.model).update(task, pass_rate(rewards));
    }

    /// Fold non-screening rollouts in (continuation rows; any training
    /// group's rollouts): posterior only — the feature model trains on
    /// screening outcomes, whose distribution matches what it forecasts.
    pub fn observe_rollouts(&self, task: &TaskInstance, rewards: &[f32]) {
        self.store.observe(task.identity(), rewards, self.cfg.discount);
    }

    /// [`observe_screening`](Self::observe_screening) with the posterior
    /// update deferred into a worker-local delta: the feature model (one
    /// uncontended mutex) updates immediately, the sharded store is touched
    /// only at [`flush`](Self::flush) — once per inference call instead of
    /// once per observed group.
    pub fn observe_screening_deferred(
        &self,
        task: &TaskInstance,
        rewards: &[f32],
        delta: &mut ObservationDelta,
    ) {
        delta.push(task.identity(), rewards);
        plock(&self.model).update(task, pass_rate(rewards));
    }

    /// [`observe_rollouts`](Self::observe_rollouts) deferred into a
    /// worker-local delta (see above).
    pub fn observe_rollouts_deferred(
        &self,
        task: &TaskInstance,
        rewards: &[f32],
        delta: &mut ObservationDelta,
    ) {
        delta.push(task.identity(), rewards);
    }

    /// Merge a worker-local delta into the shared store (each shard locked
    /// at most once) and drain it for reuse.
    pub fn flush(&self, delta: &mut ObservationDelta) {
        self.store.merge(delta, self.cfg.discount);
    }

    /// Prompt identities tracked so far.
    pub fn tracked(&self) -> usize {
        self.store.len()
    }

    /// Snapshot the predictor's accumulated knowledge for a warm-resume
    /// checkpoint: per-identity discounted Beta counts (key-sorted, so the
    /// sidecar is byte-stable), the feature model's logistic weights, and
    /// the instance counter (so resumed curriculum instances continue the
    /// exploration-stream sequence instead of replaying stream 0).
    ///
    /// Callers quiesce first: rollout workers joined and every pending
    /// [`ObservationDelta`] flushed — a snapshot taken mid-merge would
    /// tear the store.
    pub fn snapshot(&self) -> PredictorState {
        PredictorState {
            entries: self.store.snapshot(),
            model: plock(&self.model).snapshot(),
            instances: self.instances.load(Ordering::Relaxed),
        }
    }

    /// Restore knowledge written by [`snapshot`](Self::snapshot). The
    /// predictor's own config (discount, skip confidence, band) is NOT in
    /// the state — the checkpoint loader verifies the config fingerprint
    /// and rejects a mismatched resume before calling this.
    pub fn restore(&self, state: &PredictorState) {
        self.store.restore(&state.entries);
        plock(&self.model).restore(&state.model);
        self.instances.store(state.instances, Ordering::Relaxed);
    }
}

/// Serializable knowledge of a [`Predictor`] (see [`Predictor::snapshot`]).
#[derive(Clone, Debug)]
pub struct PredictorState {
    /// Key-sorted per-identity discounted Beta counts.
    pub entries: Vec<(u64, BetaPosterior)>,
    pub model: FeatureModelState,
    /// Exploration-stream instance counter.
    pub instances: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Dataset, DatasetKind};
    use crate::data::tasks::{generate, TaskFamily};
    use crate::policy::sim::{SimCostModel, SimModelSpec, SimPolicy};

    fn rule() -> ScreeningRule {
        ScreeningRule::new(8, 16)
    }

    #[test]
    fn posterior_calibrates_to_sim_ground_truth() {
        // Observe rollouts drawn from SimPolicy's true pass rates; the
        // per-identity posterior mean must land near the ground truth.
        let sim = SimPolicy::new(SimModelSpec::qwen_7b(), SimCostModel::default(), 1);
        let data = Dataset::training(DatasetKind::SynthDapo17k, 200, 3, 20);
        let predictor = Predictor::new(rule(), PredictorConfig::default());
        let mut rng = Rng::new(2);
        for _ in 0..3 {
            for t in &data.instances {
                let p = sim.pass_prob(t);
                let rewards: Vec<f32> =
                    (0..8).map(|_| if rng.bool(p) { 1.0 } else { 0.0 }).collect();
                predictor.observe_screening(t, &rewards);
            }
        }
        let mae: f64 = data
            .instances
            .iter()
            .map(|t| (predictor.predict(t).mean - sim.pass_prob(t)).abs())
            .sum::<f64>()
            / data.len() as f64;
        assert!(mae < 0.15, "posterior MAE vs sim ground truth: {mae:.3}");
        assert_eq!(predictor.tracked(), data.len());
    }

    #[test]
    fn feature_model_prices_unseen_prompts() {
        // Train only on observed screening outcomes, then predict *fresh*
        // instances (empty posteriors): the generalizing model must rank
        // trivial far above hopeless.
        let sim = SimPolicy::new(SimModelSpec::qwen_7b(), SimCostModel::default(), 4);
        let data = Dataset::training(DatasetKind::SynthDapo17k, 600, 5, 20);
        let predictor = Predictor::new(rule(), PredictorConfig::default());
        let mut rng = Rng::new(6);
        for t in &data.instances {
            let p = sim.pass_prob(t);
            let rewards: Vec<f32> =
                (0..8).map(|_| if rng.bool(p) { 1.0 } else { 0.0 }).collect();
            predictor.observe_screening(t, &rewards);
        }
        let mut fresh = Rng::new(77);
        let mean_pred = |fam: TaskFamily, level: u8, rng: &mut Rng| -> f64 {
            (0..40).map(|_| predictor.predict(&generate(rng, fam, level, 20)).mean).sum::<f64>()
                / 40.0
        };
        let easy = mean_pred(TaskFamily::Add, 1, &mut fresh);
        let hard = mean_pred(TaskFamily::Mul, 10, &mut fresh);
        assert!(
            easy > hard + 0.15,
            "unseen-prompt pricing failed to separate: easy {easy:.3} vs hard {hard:.3}"
        );
    }

    #[test]
    fn skip_rule_fires_on_confident_extremes_only() {
        let predictor = Predictor::new(rule(), PredictorConfig::default());
        let mut rng = Rng::new(9);
        let t = generate(&mut rng, TaskFamily::Add, 3, 20);
        // Cold start (no observations, neutral model): must screen — the
        // prior alone can never reach skip confidence.
        assert!(!predictor.predict(&t).would_skip);
        // Teach the predictor what screening would: level-10 Mul never
        // passes. Both the feature model and the visited identities learn.
        for _ in 0..400 {
            let hard = generate(&mut rng, TaskFamily::Mul, 10, 20);
            predictor.observe_screening(&hard, &[0.0; 8]);
        }
        // A *fresh* hopeless-looking prompt now skips before any rollout.
        let fresh = generate(&mut rng, TaskFamily::Mul, 10, 20);
        let pred = predictor.predict(&fresh);
        assert!(pred.weight == 0.0, "fresh instance must be unseen");
        assert!(
            pred.would_skip,
            "model-priced hopeless prompt should skip (accept_prob {:.3})",
            pred.accept_prob
        );
        // A mixed observation history keeps a prompt informative: screen.
        let t2 = generate(&mut rng, TaskFamily::Add, 3, 20);
        predictor.observe_rollouts(&t2, &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!(!predictor.predict(&t2).would_skip);
    }

    #[test]
    fn skip_confidence_one_never_skips() {
        let mut cfg = PredictorConfig::default();
        cfg.skip_confidence = 1.0;
        // Even with a degenerate rule that rejects every realized rate
        // (n_init = 1 under the strict default band), 1.0 must not skip.
        let predictor = Predictor::new(ScreeningRule::new(1, 8), cfg);
        let mut rng = Rng::new(11);
        let t = generate(&mut rng, TaskFamily::Mul, 10, 20);
        for _ in 0..8 {
            predictor.observe_rollouts(&t, &[0.0; 8]);
        }
        let pred = predictor.predict(&t);
        assert!(pred.accept_prob == 0.0, "n_init=1 strict band accepts nothing");
        assert!(!pred.would_skip);
        match predictor.decide(&t, &mut rng) {
            Decision::Screen(_) => {}
            other => panic!("expected Screen, got {other:?}"),
        }
    }

    #[test]
    fn decide_consumes_rng_only_when_skipping() {
        let predictor = Predictor::new(rule(), PredictorConfig::default());
        let mut rng = Rng::new(13);
        let mut rng_clone = rng.clone();
        let mut t_rng = Rng::new(14);
        let t = generate(&mut t_rng, TaskFamily::Add, 3, 20);
        match predictor.decide(&t, &mut rng) {
            Decision::Screen(_) => {}
            other => panic!("neutral predictor must screen, got {other:?}"),
        }
        // The RNG stream must be untouched by a Screen decision.
        assert_eq!(rng.next_u64(), rng_clone.next_u64());
    }

    #[test]
    fn deferred_observation_path_matches_immediate() {
        // Same observation stream through both paths: identical forecasts
        // for every task afterwards (store AND feature model agree).
        let sim = SimPolicy::new(SimModelSpec::qwen_7b(), SimCostModel::default(), 8);
        let data = Dataset::training(DatasetKind::SynthDapo17k, 120, 9, 20);
        let immediate = Predictor::new(rule(), PredictorConfig::default());
        let deferred = Predictor::new(rule(), PredictorConfig::default());
        let mut rng = Rng::new(10);
        let mut delta = ObservationDelta::default();
        for (i, t) in data.instances.iter().enumerate() {
            let p = sim.pass_prob(t);
            let rewards: Vec<f32> = (0..8).map(|_| if rng.bool(p) { 1.0 } else { 0.0 }).collect();
            if i % 2 == 0 {
                immediate.observe_screening(t, &rewards);
                deferred.observe_screening_deferred(t, &rewards, &mut delta);
            } else {
                immediate.observe_rollouts(t, &rewards);
                deferred.observe_rollouts_deferred(t, &rewards, &mut delta);
            }
            // Flush every few observations, as one inference call would.
            if i % 7 == 6 {
                deferred.flush(&mut delta);
            }
        }
        deferred.flush(&mut delta);
        assert!(delta.is_empty());
        assert_eq!(immediate.tracked(), deferred.tracked());
        for t in &data.instances {
            let a = immediate.predict(t);
            let b = deferred.predict(t);
            assert!((a.mean - b.mean).abs() < 1e-12, "posterior mean diverged");
            assert!((a.accept_prob - b.accept_prob).abs() < 1e-12, "forecast diverged");
            assert_eq!(a.would_skip, b.would_skip);
        }
    }

    #[test]
    fn snapshot_restore_reproduces_forecasts_bit_for_bit() {
        let sim = SimPolicy::new(SimModelSpec::qwen_7b(), SimCostModel::default(), 3);
        let data = Dataset::training(DatasetKind::SynthDapo17k, 150, 21, 20);
        let predictor = Predictor::new(rule(), PredictorConfig::default());
        let mut rng = Rng::new(5);
        for t in &data.instances {
            let p = sim.pass_prob(t);
            let rewards: Vec<f32> = (0..8).map(|_| if rng.bool(p) { 1.0 } else { 0.0 }).collect();
            predictor.observe_screening(t, &rewards);
        }
        let _ = predictor.instance_seed(); // advance the instance counter
        let state = predictor.snapshot();

        let fresh = Predictor::new(rule(), PredictorConfig::default());
        fresh.restore(&state);
        assert_eq!(fresh.tracked(), predictor.tracked());
        for t in &data.instances {
            let a = predictor.predict(t);
            let b = fresh.predict(t);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "posterior mean diverged");
            assert_eq!(a.accept_prob.to_bits(), b.accept_prob.to_bits());
            assert_eq!(a.would_skip, b.would_skip);
        }
        // instance streams continue the sequence instead of replaying
        assert_eq!(fresh.instance_seed(), predictor.instance_seed());
    }

    #[test]
    fn instance_seeds_are_distinct_streams() {
        let predictor = Predictor::new(rule(), PredictorConfig::default());
        let s0 = predictor.instance_seed();
        let s1 = predictor.instance_seed();
        assert_ne!(s0, s1);
        assert_eq!(s0, predictor.config().seed); // stream 0 = the base seed
    }
}
