//! Generalizing difficulty model: online logistic regression from task
//! features (family one-hot, difficulty level, prompt length) to pass rate.
//!
//! The per-identity Beta posteriors in [`super::store`] are exact but
//! cold-start blind: a prompt seen for the first time has no counts. This
//! model prices *unseen* prompts by what screening revealed about prompts
//! with similar features — the role of the small predictive models in
//! arXiv 2507.04632 / 2602.01970 — and its prediction seeds the pseudo-
//! posterior the skip rule evaluates.
//!
//! Training signal: every realized screening outcome `(features, k/N_init)`.
//! Fractional targets are fine for the logistic cross-entropy gradient.

use crate::data::tasks::{TaskInstance, N_TASK_FEATURES};

#[inline]
fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Online logistic regression over [`TaskInstance::features`].
#[derive(Clone, Debug)]
pub struct FeatureModel {
    w: [f64; N_TASK_FEATURES],
    lr: f64,
    updates: u64,
}

/// Serializable weights of a [`FeatureModel`] (warm-resume checkpoints).
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureModelState {
    pub w: [f64; N_TASK_FEATURES],
    pub lr: f64,
    pub updates: u64,
}

impl Default for FeatureModel {
    fn default() -> Self {
        FeatureModel::new(0.1)
    }
}

impl FeatureModel {
    pub fn new(lr: f64) -> FeatureModel {
        FeatureModel { w: [0.0; N_TASK_FEATURES], lr, updates: 0 }
    }

    /// Predicted pass rate for a task (0.5 before any update: the zero
    /// weight vector is the neutral prior).
    pub fn predict(&self, task: &TaskInstance) -> f64 {
        let x = task.features();
        let z: f64 = self.w.iter().zip(x.iter()).map(|(w, x)| w * x).sum();
        sigmoid(z)
    }

    /// One SGD step on the cross-entropy loss toward `target` (a realized
    /// pass rate in `[0, 1]`).
    pub fn update(&mut self, task: &TaskInstance, target: f64) {
        let target = target.clamp(0.0, 1.0);
        let x = task.features();
        let p = self.predict(task);
        let g = p - target;
        for (w, xi) in self.w.iter_mut().zip(x.iter()) {
            *w -= self.lr * g * xi;
        }
        self.updates += 1;
    }

    /// Screening outcomes consumed so far.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Snapshot for a warm-resume checkpoint.
    pub fn snapshot(&self) -> FeatureModelState {
        FeatureModelState { w: self.w, lr: self.lr, updates: self.updates }
    }

    /// Restore weights written by [`snapshot`](Self::snapshot).
    pub fn restore(&mut self, state: &FeatureModelState) {
        self.w = state.w;
        self.lr = state.lr;
        self.updates = state.updates;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, TaskFamily};
    use crate::util::rng::Rng;

    #[test]
    fn neutral_before_training() {
        let m = FeatureModel::default();
        let mut rng = Rng::new(0);
        let t = generate(&mut rng, TaskFamily::Add, 5, 20);
        assert!((m.predict(&t) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn snapshot_restore_reproduces_predictions_bit_for_bit() {
        let mut m = FeatureModel::default();
        let mut rng = Rng::new(31);
        for _ in 0..200 {
            let t = generate(&mut rng, TaskFamily::Mul, 7, 20);
            m.update(&t, 0.1);
        }
        let mut back = FeatureModel::new(0.5); // different lr, overwritten
        back.restore(&m.snapshot());
        assert_eq!(back.updates(), m.updates());
        let t = generate(&mut rng, TaskFamily::Add, 2, 20);
        assert_eq!(m.predict(&t).to_bits(), back.predict(&t).to_bits());
        // further training stays in lockstep (lr restored too)
        m.update(&t, 0.9);
        back.update(&t, 0.9);
        assert_eq!(m.predict(&t).to_bits(), back.predict(&t).to_bits());
    }

    #[test]
    fn learns_level_monotone_pass_rates() {
        // Ground truth: easy levels pass, hard levels fail. After online
        // training the model must rank fresh unseen instances correctly.
        let mut m = FeatureModel::default();
        let mut rng = Rng::new(1);
        for _ in 0..4000 {
            let level = rng.range_i64(1, 10) as u8;
            let fam = crate::data::tasks::ALL_FAMILIES[rng.range_usize(0, 6)];
            let t = generate(&mut rng, fam, level, 20);
            let target = if level <= 3 { 0.95 } else if level >= 8 { 0.05 } else { 0.5 };
            m.update(&t, target);
        }
        let mut fresh = Rng::new(99);
        let easy: f64 = (0..50)
            .map(|_| m.predict(&generate(&mut fresh, TaskFamily::Add, 1, 20)))
            .sum::<f64>()
            / 50.0;
        let hard: f64 = (0..50)
            .map(|_| m.predict(&generate(&mut fresh, TaskFamily::Mul, 10, 20)))
            .sum::<f64>()
            / 50.0;
        assert!(easy > 0.7, "easy prediction {easy:.3}");
        assert!(hard < 0.3, "hard prediction {hard:.3}");
        assert!(m.updates() == 4000);
    }
}
