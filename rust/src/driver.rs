//! Run driver: turns a [`RunConfig`] into a complete training run on
//! either substrate. Shared by the CLI, the examples, and the benches so
//! every entrypoint exercises the same code path.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{CheckpointIo, CheckpointSpec, Fingerprint, RunState};
use crate::config::{RunConfig, Substrate};
use crate::coordinator::alloc::{AllocKind, Allocator};
use crate::coordinator::curriculum::{Curriculum, CurriculumKind, CurriculumSpec};
use crate::coordinator::pipeline::{PipelineConfig, PipelineResume, PipelinedTrainer};
use crate::coordinator::screening::ScreeningRule;
use crate::coordinator::trainer::{EvalSet, TrainState, Trainer, TrainerConfig};
use crate::data::dataset::Dataset;
use crate::data::loader::Loader;
use crate::eval::benchmark_suite;
use crate::metrics::RunRecord;
use crate::policy::fault::{FaultPlan, RecoveryConfig};
use crate::policy::real::RealPolicy;
use crate::policy::service::{InferenceService, ServiceConfig, ServicedPolicy};
use crate::policy::sim::{SimCostModel, SimModelSpec, SimPolicy};
use crate::policy::{ForkEngine, Policy, RolloutEngine, Trainable};
use crate::predictor::{Predictor, PredictorConfig};
use crate::rl::algo::AlgoConfig;

/// Benchmark-seed shared by all runs so curves are comparable.
pub const BENCH_SEED: u64 = 123;

/// Maximum prompt chars for generated tasks (fits every compiled prompt
/// width; the nano plan uses 24).
pub const MAX_PROMPT_CHARS: usize = 20;

pub fn screening_rule(cfg: &RunConfig) -> ScreeningRule {
    ScreeningRule::new(cfg.n_init, cfg.n_cont).with_thresholds(cfg.p_low, cfg.p_high)
}

pub fn predictor_config(cfg: &RunConfig) -> PredictorConfig {
    PredictorConfig {
        discount: cfg.predictor_discount,
        skip_confidence: cfg.skip_confidence,
        explore_rate: cfg.explore_rate,
        seed: cfg.seed,
        ..PredictorConfig::default()
    }
}

/// The per-prompt continuation-budget allocator for a run. Adaptive
/// allocation prices budgets from a posterior: `predictive-speed` shares
/// the curriculum's own predictor (which already observes every outcome),
/// while plain `speed` hands the allocator a predictor it must feed itself
/// from the screening outcomes it allocates on.
pub fn build_allocator(cfg: &RunConfig, predictor: Option<Arc<Predictor>>) -> Allocator {
    let rule = screening_rule(cfg);
    match cfg.alloc {
        AllocKind::Fixed => Allocator::fixed(rule),
        AllocKind::Adaptive => {
            let (n_cont_min, n_cont_max) = cfg.alloc_bounds();
            let feed_posterior = cfg.curriculum != CurriculumKind::PredictiveSpeed;
            Allocator::adaptive(rule, n_cont_min, n_cont_max, predictor, feed_posterior)
        }
    }
}

pub fn curriculum_spec(cfg: &RunConfig) -> CurriculumSpec {
    let rule = screening_rule(cfg);
    // One shared difficulty predictor per run: every rollout worker's
    // predictive-speed instance observes into (and prices from) the same
    // store. Adaptive allocation wants one too (for any screening
    // curriculum), so budgets learn across prompt revisits.
    let needs_predictor = cfg.curriculum == CurriculumKind::PredictiveSpeed
        || (cfg.alloc == AllocKind::Adaptive && cfg.curriculum == CurriculumKind::Speed);
    let predictor = needs_predictor.then(|| Arc::new(Predictor::new(rule, predictor_config(cfg))));
    CurriculumSpec {
        kind: cfg.curriculum,
        rule,
        alloc: build_allocator(cfg, predictor.clone()),
        pool_factor: cfg.pool_factor,
        // In pipelined runs `buffer_cap` bounds the SHARED buffer (see
        // `pipeline_config`), so worker-internal SPEED buffers keep the
        // reference semantics — bounding both would silently evict
        // qualified groups inside workers. 0 = auto: the serial SPEED
        // buffer also stays unbounded (its backlog throttle limits growth).
        buffer_cap: if cfg.buffer_cap == 0 || cfg.pipeline {
            usize::MAX
        } else {
            cfg.buffer_cap.max(cfg.batch_size)
        },
        predictor,
    }
}

pub fn build_curriculum(cfg: &RunConfig) -> Box<dyn Curriculum> {
    curriculum_spec(cfg).build()
}

pub fn service_config(cfg: &RunConfig) -> ServiceConfig {
    ServiceConfig {
        batching: cfg.batching,
        coalesce_wait_ms: cfg.coalesce_wait_ms,
        fill_waterline: cfg.fill_waterline,
        adaptive: cfg.coalesce_adaptive,
    }
}

/// The fault-tolerance configuration for a run, or `None` when no fault
/// knob is set — plain spawns then run the exact pre-fault service state
/// machine (the no-faults bit-for-bit rail, DESIGN.md §13). Returns the
/// recovery config plus the number of spare engines to pre-fork: one per
/// active replica under `--respawn`, bounded so active + spares fit the
/// fixed-size per-replica counter arrays.
pub fn recovery_config(cfg: &RunConfig) -> Result<Option<(RecoveryConfig, usize)>> {
    if cfg.fault_plan.is_none() && cfg.exec_timeout_ms == 0 && !cfg.respawn {
        return Ok(None);
    }
    let recovery = RecoveryConfig {
        exec_timeout_ms: cfg.exec_timeout_ms,
        respawn: cfg.respawn,
        fault_plan: match &cfg.fault_plan {
            Some(spec) => FaultPlan::parse(spec).context("--fault-plan")?,
            None => FaultPlan::default(),
        },
        ..RecoveryConfig::default()
    };
    let e = cfg.engines.max(1);
    let spares = if cfg.respawn { e.min(crate::metrics::MAX_POOL - e) } else { 0 };
    Ok(Some((recovery, spares)))
}

pub fn pipeline_config(cfg: &RunConfig) -> PipelineConfig {
    PipelineConfig {
        workers: cfg.workers.max(1),
        enabled: cfg.pipeline,
        // 0 = auto: four batches of headroom between producers and the
        // learner (the same backlog target the serial curriculum uses).
        buffer_cap: if cfg.buffer_cap == 0 {
            4 * cfg.batch_size
        } else {
            cfg.buffer_cap.max(cfg.batch_size)
        },
        service: cfg.service,
        service_cfg: service_config(cfg),
    }
}

pub fn build_algo(cfg: &RunConfig) -> AlgoConfig {
    let mut algo = AlgoConfig::new(cfg.algo);
    algo.lr = cfg.lr;
    algo
}

pub fn build_sim_policy(cfg: &RunConfig) -> Result<SimPolicy> {
    let spec = SimModelSpec::parse(&cfg.model)
        .with_context(|| format!("unknown sim model '{}'", cfg.model))?;
    // Paper shapes: generation batch 64 prompts worth of rows; train batch
    // B x N rows. The call must also fit the allocator's largest possible
    // group (n_init + n_cont_max under adaptive budgets).
    let capacity = (cfg.batch_size * cfg.n_total()).max(cfg.max_group_rollouts());
    Ok(SimPolicy::new(spec, SimCostModel::default(), cfg.seed)
        .with_shapes(capacity, cfg.batch_size * cfg.n_total(), 512))
}

pub fn trainer_config(cfg: &RunConfig) -> TrainerConfig {
    TrainerConfig {
        batch_size: cfg.batch_size,
        temperature: cfg.temperature,
        eval_every: cfg.eval_every,
        max_steps: cfg.max_steps,
        max_seconds: cfg.max_seconds,
        stop_at_target: None,
        seed: cfg.seed,
        label: cfg.label.clone(),
    }
}

/// Run a config on the simulator substrate. With `cfg.pipeline` on, the
/// run goes through the [`PipelinedTrainer`] (K forked rollout engines
/// overlapping inference with updates); otherwise the serial reference
/// trainer.
pub fn run_sim(cfg: &RunConfig) -> Result<RunRecord> {
    run_sim_with(cfg, &CheckpointIo::default())
}

/// [`run_sim`] with run-state checkpointing: `io.resume` warm-starts from
/// a saved checkpoint (weights + curriculum knowledge + run progress),
/// `io.save` writes one at the end of the run and — with `io.save_every` —
/// periodically during it. Periodic saving runs the trainer in segments,
/// which the sim-substrate equivalence rail guarantees is bit-for-bit
/// identical to an uninterrupted run (`rust/tests/checkpoint_sim.rs`).
pub fn run_sim_with(cfg: &RunConfig, io: &CheckpointIo) -> Result<RunRecord> {
    anyhow::ensure!(cfg.substrate == Substrate::Sim, "config is not a sim run");
    cfg.validate()?;
    io.validate()?;
    with_trace(cfg, || {
        let dataset = Dataset::training(cfg.dataset, cfg.dataset_size, cfg.seed, MAX_PROMPT_CHARS);
        let mut policy = build_sim_policy(cfg)?;
        let evals = benchmark_suite(BENCH_SEED, MAX_PROMPT_CHARS);
        if cfg.pipeline {
            check_capacity(cfg, policy.rollout_capacity())?;
            return run_pipelined_sim(cfg, &mut policy, &dataset, &evals, io);
        }
        if cfg.service {
            // Serial loop delegated through the coalescing service with one
            // producer — DESIGN.md §8's equivalence rail: this must reproduce
            // the plain serial RunRecord bit for bit (rust/tests/service_sim.rs).
            // The service owns no run state, so checkpointing threads through
            // the same segmented runner as the plain serial path; the learner
            // restore re-publishes the snapshot so the pool's forked replicas
            // serve the restored weights.
            check_capacity(cfg, policy.rollout_capacity())?;
            let e = cfg.engines.max(1);
            let engines: Vec<_> = (0..e).map(|r| policy.fork_engine(r as u64)).collect();
            let service = match recovery_config(cfg)? {
                Some((recovery, n_spares)) => InferenceService::spawn_pool_with_recovery(
                    engines,
                    // Spares continue the replica seed streams so an
                    // activated spare is just "replica E+s" — deterministic
                    // and disjoint from every active stream.
                    (0..n_spares).map(|s| policy.fork_engine((e + s) as u64)).collect(),
                    service_config(cfg),
                    recovery,
                    1,
                    cfg.max_group_rollouts(),
                ),
                None => InferenceService::spawn_pool(
                    engines,
                    service_config(cfg),
                    1,
                    cfg.max_group_rollouts(),
                ),
            };
            let handle = service.handle();
            let mut serviced = ServicedPolicy::new(handle, &mut policy);
            return run_serial_segments(cfg, &mut serviced, &dataset, &evals, io, Some(&service));
        }
        run_with_policy_io(cfg, &mut policy, &dataset, &evals, io)
    })
}

/// Run `f` with the trace spine enabled when `cfg.trace` is set, exporting
/// the collected timeline to that path afterwards — even when the run
/// fails, since a partial timeline is the artifact you want most then.
/// Without `--trace` this is just `f()` behind one branch; the spine stays
/// disabled and every instrumentation point is a relaxed load.
fn with_trace<T>(cfg: &RunConfig, f: impl FnOnce() -> Result<T>) -> Result<T> {
    // Pin the shared log/trace epoch before any spans are cut, so trace
    // timestamps and log timestamps are directly comparable.
    crate::util::logging::init();
    let Some(path) = cfg.trace.clone() else {
        return f();
    };
    crate::trace::enable();
    let result = f();
    if let Some(data) = crate::trace::finish() {
        match std::fs::write(&path, data.to_chrome_json().to_string()) {
            Ok(()) => crate::info!(
                "trace",
                "wrote {} events from {} threads to {path} ({} dropped)",
                data.event_count(),
                data.thread_count(),
                data.dropped_events
            ),
            Err(e) => {
                if result.is_ok() {
                    return Err(e).with_context(|| format!("write trace to {path}"));
                }
                crate::warn_log!("trace", "failed to write trace to {path}: {e:#}");
            }
        }
    }
    result
}

/// Restore shared (substrate + predictor) state from a checkpoint; returns
/// the progress pieces the caller threads into its trainer.
fn load_resume_state(
    cfg: &RunConfig,
    spec: &CheckpointSpec,
    cspec: &CurriculumSpec,
    policy: &mut dyn Policy,
    dataset_len: usize,
) -> Result<(RunState, Loader)> {
    let rs = RunState::load(&spec.dir, &spec.tag)?;
    rs.fingerprint.check_matches(cfg).with_context(|| format!("resume from {spec}"))?;
    policy
        .load_params(&spec.dir, &spec.tag)
        .with_context(|| format!("load checkpoint weights from {spec}"))?;
    // Cross-file generation check: the weights on disk must be the ones
    // this sidecar was saved with — a crash between the weight writes and
    // the sidecar write leaves two generations mixed, and resuming that
    // would silently re-train finished steps on newer weights.
    if let (Some(want), Some(have)) = (rs.params_token, policy.params_token()) {
        anyhow::ensure!(
            want == have,
            "checkpoint {spec} is torn: weight files are generation {have} but the run-state \
             sidecar was saved with generation {want} (crash mid-save?) — restore from an \
             older tag"
        );
    }
    if let Some(pj) = &rs.policy {
        policy.restore_state_json(pj).context("restore substrate state")?;
    }
    if let Some(pred_state) = &rs.predictor {
        let pred = cspec.predictor.as_ref().with_context(|| {
            format!(
                "checkpoint {spec} carries difficulty-predictor state but this run builds \
                 no predictor — fingerprint drift?"
            )
        })?;
        pred.restore(pred_state);
    }
    let loader = rs
        .loader
        .as_ref()
        .map(Loader::from_state)
        .unwrap_or_else(|| Loader::new(dataset_len, cfg.seed));
    crate::info!(
        "checkpoint",
        "resumed from {spec}: step {}, {} tracked identities",
        rs.step,
        rs.predictor.as_ref().map(|p| p.entries.len()).unwrap_or(0)
    );
    Ok((rs, loader))
}

/// Snapshot the full run state (quiesced: between steps, no workers
/// running, deltas flushed) and write weights + sidecar — the ONE
/// checkpoint-assembly site, shared by the serial and pipelined runners so
/// a new `RunState` field cannot be persisted on one path and silently
/// dropped on the other. Weights go first, sidecar last, both via
/// temp-file + rename, so a crash at any point leaves a loadable
/// checkpoint on disk.
#[allow(clippy::too_many_arguments)]
fn save_run_state(
    cfg: &RunConfig,
    policy: &dyn Policy,
    curriculum_state: Option<crate::util::json::Json>,
    spec: &CurriculumSpec,
    step: usize,
    inference_s: f64,
    update_s: f64,
    counters: crate::metrics::InferenceCounters,
    record: &RunRecord,
    loader_state: crate::data::loader::LoaderState,
    save: &CheckpointSpec,
) -> Result<()> {
    let t_save = crate::trace::start();
    policy.save_params(&save.dir, &save.tag)?;
    let mut record = record.clone();
    record.counters = counters;
    let rs = RunState {
        fingerprint: Fingerprint::of(cfg),
        step,
        weight_version: policy.weight_version(),
        inference_s,
        update_s,
        counters,
        record,
        loader: Some(loader_state),
        params_token: policy.params_token(),
        policy: policy.state_json(),
        curriculum: curriculum_state,
        predictor: spec.predictor.as_ref().map(|p| p.snapshot()),
    };
    rs.save(&save.dir, &save.tag)?;
    crate::trace::span("checkpoint-save", "checkpoint", t_save, step as i64);
    crate::info!("checkpoint", "run state saved to {save} at step {step}");
    Ok(())
}

/// Best-effort emergency checkpoint for a run that is about to die with an
/// error: write the last consistent state to the sidecar tag `<tag>-crash`
/// (same atomic temp-file + rename path as every other save) so the work
/// is salvageable, and log the resume command. Never masks the original
/// error — a failing emergency save only warns.
#[allow(clippy::too_many_arguments)]
fn save_crash_state(
    cfg: &RunConfig,
    policy: &dyn Policy,
    curriculum_state: Option<crate::util::json::Json>,
    spec: &CurriculumSpec,
    step: usize,
    inference_s: f64,
    update_s: f64,
    counters: crate::metrics::InferenceCounters,
    record: &RunRecord,
    loader_state: crate::data::loader::LoaderState,
    save: &CheckpointSpec,
) {
    let crash = CheckpointSpec::new(save.dir.clone(), format!("{}-crash", save.tag));
    match save_run_state(
        cfg,
        policy,
        curriculum_state,
        spec,
        step,
        inference_s,
        update_s,
        counters,
        record,
        loader_state,
        &crash,
    ) {
        Ok(()) => crate::info!(
            "checkpoint",
            "emergency checkpoint at step {step}; resume with: --resume {crash}"
        ),
        Err(e) => crate::warn_log!("checkpoint", "emergency checkpoint to {crash} failed: {e:#}"),
    }
}

/// The serial segmented runner shared by the sim and real substrates: run
/// until the next save point, snapshot, repeat. With no `io.save` this is
/// one segment — exactly the plain serial run. When the serial loop is
/// routed through the inference service, `service` threads its counters
/// into every sidecar and the final record: the live counters (this
/// process only) are merged onto the counters carried by the resumed
/// record, taken out once at resume so segments cannot double-merge.
/// `ServiceCounters::merge` folds the per-replica arrays index by index,
/// so resumed pool runs report stable totals in replica order.
fn run_serial_segments(
    cfg: &RunConfig,
    policy: &mut dyn Policy,
    dataset: &Dataset,
    evals: &[EvalSet],
    io: &CheckpointIo,
    service: Option<&InferenceService>,
) -> Result<RunRecord> {
    let spec = curriculum_spec(cfg);
    let mut curriculum = spec.build();
    let trainer = Trainer::new(trainer_config(cfg), build_algo(cfg));
    let mut state = TrainState::fresh(dataset.len(), cfg.seed, cfg.label.clone());
    if let Some(resume) = &io.resume {
        let (rs, loader) = load_resume_state(cfg, resume, &spec, policy, dataset.len())?;
        if let Some(cj) = &rs.curriculum {
            curriculum.restore_state_json(cj).context("restore curriculum state")?;
        }
        state = TrainState {
            loader,
            counters: rs.counters,
            next_step: rs.step,
            inference_s: rs.inference_s,
            update_s: rs.update_s,
            record: rs.record,
            stopped: false,
        };
    }
    let prior_service = state.record.service.take();
    let merged_service = |svc: &InferenceService| {
        let mut s = svc.stats();
        if let Some(prev) = &prior_service {
            s.merge(prev);
        }
        s
    };
    loop {
        let until = if io.save.is_some() && io.save_every > 0 {
            (state.next_step + io.save_every).min(cfg.max_steps)
        } else {
            cfg.max_steps
        };
        if let Err(err) =
            trainer.run_segment(policy, curriculum.as_mut(), dataset, evals, &mut state, until)
        {
            // The state is mid-step but internally consistent (the trainer
            // mutates it between phases, never partially within one), so a
            // dying run with --save leaves a salvageable sidecar behind.
            if let Some(save) = &io.save {
                if let Some(svc) = service {
                    state.record.service = Some(merged_service(svc));
                }
                save_crash_state(
                    cfg,
                    &*policy,
                    curriculum.state_json(),
                    &spec,
                    state.next_step,
                    state.inference_s,
                    state.update_s,
                    state.counters,
                    &state.record,
                    state.loader.state(),
                    save,
                );
            }
            return Err(err);
        }
        if let Some(save) = &io.save {
            if let Some(svc) = service {
                state.record.service = Some(merged_service(svc));
            }
            save_run_state(
                cfg,
                &*policy,
                curriculum.state_json(),
                &spec,
                state.next_step,
                state.inference_s,
                state.update_s,
                state.counters,
                &state.record,
                state.loader.state(),
                save,
            )?;
        }
        if state.stopped || state.next_step >= cfg.max_steps {
            break;
        }
    }
    let mut record = state.record;
    record.counters = state.counters;
    if let Some(svc) = service {
        record.service = Some(merged_service(svc));
    }
    Ok(record)
}

/// The pipelined segmented runner. Each segment spawns rollout workers,
/// runs the learner to the next save point, then quiesces (pool joined,
/// observation deltas flushed — they are flushed per inference call, so a
/// joined worker has none pending) before the snapshot: no torn state.
/// Worker-internal prefetch (their SPEED buffers / pending continuations)
/// is deliberately dropped at each quiesce — fresh workers refill it — so
/// a pipelined checkpoint persists the *shared* knowledge (predictor
/// store, weights, loader position, learner accounting), not the racy
/// in-flight groups; pipelined runs are scheduling-nondeterministic
/// anyway, the serial path carries the bit-exact rail.
fn run_pipelined_sim(
    cfg: &RunConfig,
    policy: &mut SimPolicy,
    dataset: &Dataset,
    evals: &[EvalSet],
    io: &CheckpointIo,
) -> Result<RunRecord> {
    let spec = curriculum_spec(cfg);
    let mut resume: Option<PipelineResume> = None;
    if let Some(r) = &io.resume {
        let (rs, loader) = load_resume_state(cfg, r, &spec, policy, dataset.len())?;
        if rs.curriculum.is_some() {
            // A serial checkpoint carries buffered groups / pending
            // continuations; pipelined workers build fresh curricula, so
            // that prefetch (already paid for in the counters) is dropped.
            // Loud, because the rollout accounting will look inflated.
            crate::warn_log!(
                "checkpoint",
                "resuming a serial checkpoint into the pipelined coordinator: its buffered \
                 groups and pending continuations are dropped (fresh workers refill the \
                 prefetch); resume without --pipeline to keep them"
            );
        }
        resume = Some(PipelineResume {
            start_step: rs.step,
            inference_s: rs.inference_s,
            update_s: rs.update_s,
            counters: rs.counters,
            record: rs.record,
            loader,
        });
    }
    loop {
        let start = resume.as_ref().map(|r| r.start_step).unwrap_or(0);
        let until = if io.save.is_some() && io.save_every > 0 {
            (start + io.save_every).min(cfg.max_steps)
        } else {
            cfg.max_steps
        };
        let mut segment_cfg = trainer_config(cfg);
        segment_cfg.max_steps = until;
        let mut trainer = PipelinedTrainer::new(segment_cfg, build_algo(cfg), pipeline_config(cfg))
            .with_engines(cfg.engines);
        if let Some((recovery, spares)) = recovery_config(cfg)? {
            trainer = trainer.with_recovery(recovery, spares);
        }
        // Progress as of the segment start, kept for the crash path below:
        // a failing segment cannot return its in-flight record, so the
        // emergency sidecar records the last segment boundary (the weights
        // and shared predictor still carry whatever the crash allowed).
        let crash_progress = resume.as_ref().map(|r| {
            (r.start_step, r.inference_s, r.update_s, r.counters, r.record.clone(), r.loader.state())
        });
        let (record, loader) =
            match trainer.run_resumed(policy, spec.clone(), dataset, evals, resume.take()) {
                Ok(v) => v,
                Err(err) => {
                    if let Some(save) = &io.save {
                        let (step, inference_s, update_s, counters, record, loader_state) =
                            crash_progress.unwrap_or_else(|| {
                                (
                                    0,
                                    0.0,
                                    0.0,
                                    Default::default(),
                                    RunRecord { label: cfg.label.clone(), ..Default::default() },
                                    Loader::new(dataset.len(), cfg.seed).state(),
                                )
                            });
                        save_crash_state(
                            cfg,
                            &*policy,
                            None,
                            &spec,
                            step,
                            inference_s,
                            update_s,
                            counters,
                            &record,
                            loader_state,
                            save,
                        );
                    }
                    return Err(err);
                }
            };
        let next_step = record.steps.last().map(|s| s.step + 1).unwrap_or(start);
        let update_s = record.steps.last().map(|s| s.update_s).unwrap_or(0.0);
        if let Some(save) = &io.save {
            // Quiesced here: run_resumed joined its worker pool. No
            // curriculum state: worker prefetch is not checkpointed.
            save_run_state(
                cfg,
                &*policy,
                None,
                &spec,
                next_step,
                record.counters.cost_s,
                update_s,
                record.counters,
                &record,
                loader.state(),
                save,
            )?;
        }
        // Done when finished, stopped mid-segment, or a stop condition
        // fired — the explicit checks mirror the learner's own break
        // conditions (time cap, target reached), which are invisible in
        // `next_step` when they land exactly on a save boundary (a fresh
        // segment would otherwise train past the stop).
        let time_capped =
            record.steps.last().map(|s| s.time_s >= cfg.max_seconds).unwrap_or(false);
        let target_hit = trainer
            .config
            .stop_at_target
            .as_ref()
            .is_some_and(|(bench, target)| {
                crate::coordinator::trainer::target_reached(&record, bench, *target)
            });
        if next_step >= cfg.max_steps || next_step < until || time_capped || target_hit {
            return Ok(record);
        }
        resume = Some(PipelineResume {
            start_step: next_step,
            inference_s: record.counters.cost_s,
            update_s,
            counters: record.counters,
            record,
            loader,
        });
    }
}

/// The compiled (or simulated) inference call must fit a full group — the
/// LARGEST one the allocator can issue, not just the reference split.
fn check_capacity(cfg: &RunConfig, rollout_capacity: usize) -> Result<()> {
    let max_group = cfg.max_group_rollouts();
    if max_group > rollout_capacity {
        bail!(
            "a maximum-budget group of {max_group} rollouts exceeds rollout capacity \
             {rollout_capacity} — recompile artifacts or lower n_init/n_cont/n_cont_max"
        );
    }
    Ok(())
}

/// Run a config on the real PJRT substrate (artifacts required).
pub fn run_real(cfg: &RunConfig, artifacts_dir: &Path) -> Result<(RunRecord, RealPolicy)> {
    anyhow::ensure!(cfg.substrate == Substrate::Real, "config is not a real run");
    cfg.validate()?;
    with_trace(cfg, || {
        let mut policy = RealPolicy::load(artifacts_dir, cfg.seed)?;
        let max_chars = policy.runtime.manifest.plan.prompt_len.min(MAX_PROMPT_CHARS + 4);
        let dataset = Dataset::training(cfg.dataset, cfg.dataset_size, cfg.seed, max_chars);
        let evals = benchmark_suite(BENCH_SEED, max_chars);
        let record = run_with_policy(cfg, &mut policy, &dataset, &evals)?;
        Ok((record, policy))
    })
}

/// Shared inner loop.
pub fn run_with_policy(
    cfg: &RunConfig,
    policy: &mut dyn Policy,
    dataset: &Dataset,
    evals: &[EvalSet],
) -> Result<RunRecord> {
    run_with_policy_io(cfg, policy, dataset, evals, &CheckpointIo::default())
}

/// [`run_with_policy`] with run-state checkpointing (resume / periodic
/// save) — the real substrate's `train --resume/--save/--save-every` path;
/// `run_sim_with` routes its serial runs through here too.
pub fn run_with_policy_io(
    cfg: &RunConfig,
    policy: &mut dyn Policy,
    dataset: &Dataset,
    evals: &[EvalSet],
    io: &CheckpointIo,
) -> Result<RunRecord> {
    cfg.validate()?;
    io.validate()?;
    check_capacity(cfg, policy.rollout_capacity())?;
    if cfg.pipeline || cfg.service {
        // Only `run_sim` has a forkable engine; everything else (the real
        // substrate in particular, with its single PJRT engine) runs the
        // serial reference loop.
        crate::warn_log!(
            "driver",
            "pipeline={}/service={} with workers={} requested, but this substrate runs serially",
            cfg.pipeline,
            cfg.service,
            cfg.workers
        );
    }
    run_serial_segments(cfg, policy, dataset, evals, io, None)
}

/// Table-1 accuracy targets per benchmark for each sim model scale,
/// following the caption's convention (lower thresholds for the smaller
/// model), recalibrated to the synthetic benchmarks' base accuracies.
pub fn paper_targets(model: &str) -> Vec<(&'static str, f64)> {
    match model {
        "sim-1.5b" => vec![("dapo1k", 0.30), ("math500", 0.70), ("amc2023", 0.40), ("aime", 0.10)],
        _ => vec![("dapo1k", 0.50), ("math500", 0.90), ("amc2023", 0.55), ("aime", 0.18)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::curriculum::CurriculumKind;

    #[test]
    fn sim_run_from_default_config() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 5;
        cfg.eval_every = 5;
        cfg.dataset_size = 2000;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 5);
        assert!(rec.total_time() > 0.0);
    }

    #[test]
    fn rejects_oversized_n() {
        // The guard matters for the real substrate, whose call capacity is
        // fixed by the compiled artifacts; emulate that with explicit
        // small sim shapes.
        let mut cfg = RunConfig::default();
        cfg.n_init = 60;
        cfg.n_cont = 60;
        cfg.dataset_size = 100;
        let dataset = Dataset::training(cfg.dataset, 100, 0, MAX_PROMPT_CHARS);
        let mut policy = crate::policy::sim::SimPolicy::new(
            crate::policy::sim::SimModelSpec::qwen_7b(),
            crate::policy::sim::SimCostModel::default(),
            0,
        )
        .with_shapes(64, 64, 512);
        let evals = benchmark_suite(BENCH_SEED, MAX_PROMPT_CHARS);
        assert!(run_with_policy(&cfg, &mut policy, &dataset, &evals).is_err());
    }

    #[test]
    fn pipelined_sim_run_completes() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 4;
        cfg.eval_every = 2;
        cfg.dataset_size = 2000;
        cfg.pipeline = true;
        cfg.workers = 2;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 4);
        assert!(rec.counters.rollouts > 0);
        assert!(rec.total_time() > 0.0);
        // engine-busy accounting only exists on the pipelined path
        assert!(rec.counters.busy_s > 0.0);
        // no service was requested, so no service counters are attached
        assert!(rec.service.is_none());
    }

    #[test]
    fn serviced_serial_sim_run_completes_with_service_counters() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 3;
        cfg.eval_every = 3;
        cfg.dataset_size = 2000;
        cfg.service = true;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 3);
        let svc = rec.service.expect("service counters attached");
        assert!(svc.calls > 0);
        // one producer: every call carries exactly one submission
        assert_eq!(svc.submissions, svc.calls);
        assert_eq!(svc.coalesced_hist[0], svc.calls);
        assert!(svc.max_call_rows > 0);
    }

    #[test]
    fn pipelined_service_sim_run_completes() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 4;
        cfg.eval_every = 2;
        cfg.dataset_size = 2000;
        cfg.pipeline = true;
        cfg.workers = 2;
        cfg.service = true;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 4);
        let svc = rec.service.expect("service counters attached");
        assert!(svc.calls > 0 && svc.submissions >= svc.calls);
        // per-step deltas: sum to at most the run totals, never out of range
        let step_calls: u64 = rec.steps.iter().map(|s| s.service_calls).sum();
        assert!(step_calls > 0, "per-step service deltas missing");
        assert!(step_calls <= svc.calls);
        assert!(rec.steps.iter().all(|s| (0.0..=1.0).contains(&s.service_fill)));
    }

    #[test]
    fn curriculum_construction_matches_kind() {
        for kind in [
            CurriculumKind::Uniform,
            CurriculumKind::DapoFilter,
            CurriculumKind::Speed,
            CurriculumKind::PredictiveSpeed,
            CurriculumKind::VarianceMax,
        ] {
            let mut cfg = RunConfig::default();
            cfg.curriculum = kind;
            assert_eq!(build_curriculum(&cfg).kind(), kind);
        }
    }

    #[test]
    fn predictive_spec_carries_a_shared_predictor() {
        let mut cfg = RunConfig::default();
        cfg.curriculum = CurriculumKind::PredictiveSpeed;
        let spec = curriculum_spec(&cfg);
        assert!(spec.predictor.is_some());
        // Clones (one per rollout worker) share the same store.
        let clone = spec.clone();
        assert!(Arc::ptr_eq(
            spec.predictor.as_ref().unwrap(),
            clone.predictor.as_ref().unwrap()
        ));
        // Non-predictive kinds carry none.
        let plain = curriculum_spec(&RunConfig::default());
        assert!(plain.predictor.is_none());
    }

    #[test]
    fn run_sim_rejects_invalid_config() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 1;
        cfg.n_init = 0;
        assert!(run_sim(&cfg).is_err());
    }
}
