//! Run driver: turns a [`RunConfig`] into a complete training run on
//! either substrate. Shared by the CLI, the examples, and the benches so
//! every entrypoint exercises the same code path.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{RunConfig, Substrate};
use crate::coordinator::alloc::{AllocKind, Allocator};
use crate::coordinator::curriculum::{Curriculum, CurriculumKind, CurriculumSpec};
use crate::coordinator::pipeline::{PipelineConfig, PipelinedTrainer};
use crate::coordinator::screening::ScreeningRule;
use crate::coordinator::trainer::{EvalSet, Trainer, TrainerConfig};
use crate::data::dataset::Dataset;
use crate::eval::benchmark_suite;
use crate::metrics::RunRecord;
use crate::policy::real::RealPolicy;
use crate::policy::service::{InferenceService, ServiceConfig, ServicedPolicy};
use crate::policy::sim::{SimCostModel, SimModelSpec, SimPolicy};
use crate::policy::{ForkEngine, Policy, RolloutEngine};
use crate::predictor::{Predictor, PredictorConfig};
use crate::rl::algo::AlgoConfig;

/// Benchmark-seed shared by all runs so curves are comparable.
pub const BENCH_SEED: u64 = 123;

/// Maximum prompt chars for generated tasks (fits every compiled prompt
/// width; the nano plan uses 24).
pub const MAX_PROMPT_CHARS: usize = 20;

pub fn screening_rule(cfg: &RunConfig) -> ScreeningRule {
    ScreeningRule::new(cfg.n_init, cfg.n_cont).with_thresholds(cfg.p_low, cfg.p_high)
}

pub fn predictor_config(cfg: &RunConfig) -> PredictorConfig {
    PredictorConfig {
        discount: cfg.predictor_discount,
        skip_confidence: cfg.skip_confidence,
        explore_rate: cfg.explore_rate,
        seed: cfg.seed,
        ..PredictorConfig::default()
    }
}

/// The per-prompt continuation-budget allocator for a run. Adaptive
/// allocation prices budgets from a posterior: `predictive-speed` shares
/// the curriculum's own predictor (which already observes every outcome),
/// while plain `speed` hands the allocator a predictor it must feed itself
/// from the screening outcomes it allocates on.
pub fn build_allocator(cfg: &RunConfig, predictor: Option<Arc<Predictor>>) -> Allocator {
    let rule = screening_rule(cfg);
    match cfg.alloc {
        AllocKind::Fixed => Allocator::fixed(rule),
        AllocKind::Adaptive => {
            let (n_cont_min, n_cont_max) = cfg.alloc_bounds();
            let feed_posterior = cfg.curriculum != CurriculumKind::PredictiveSpeed;
            Allocator::adaptive(rule, n_cont_min, n_cont_max, predictor, feed_posterior)
        }
    }
}

pub fn curriculum_spec(cfg: &RunConfig) -> CurriculumSpec {
    let rule = screening_rule(cfg);
    // One shared difficulty predictor per run: every rollout worker's
    // predictive-speed instance observes into (and prices from) the same
    // store. Adaptive allocation wants one too (for any screening
    // curriculum), so budgets learn across prompt revisits.
    let needs_predictor = cfg.curriculum == CurriculumKind::PredictiveSpeed
        || (cfg.alloc == AllocKind::Adaptive && cfg.curriculum == CurriculumKind::Speed);
    let predictor = needs_predictor.then(|| Arc::new(Predictor::new(rule, predictor_config(cfg))));
    CurriculumSpec {
        kind: cfg.curriculum,
        rule,
        alloc: build_allocator(cfg, predictor.clone()),
        pool_factor: cfg.pool_factor,
        // In pipelined runs `buffer_cap` bounds the SHARED buffer (see
        // `pipeline_config`), so worker-internal SPEED buffers keep the
        // reference semantics — bounding both would silently evict
        // qualified groups inside workers. 0 = auto: the serial SPEED
        // buffer also stays unbounded (its backlog throttle limits growth).
        buffer_cap: if cfg.buffer_cap == 0 || cfg.pipeline {
            usize::MAX
        } else {
            cfg.buffer_cap.max(cfg.batch_size)
        },
        predictor,
    }
}

pub fn build_curriculum(cfg: &RunConfig) -> Box<dyn Curriculum> {
    curriculum_spec(cfg).build()
}

pub fn service_config(cfg: &RunConfig) -> ServiceConfig {
    ServiceConfig {
        coalesce_wait_ms: cfg.coalesce_wait_ms,
        fill_waterline: cfg.fill_waterline,
        adaptive: cfg.coalesce_adaptive,
    }
}

pub fn pipeline_config(cfg: &RunConfig) -> PipelineConfig {
    PipelineConfig {
        workers: cfg.workers.max(1),
        enabled: cfg.pipeline,
        // 0 = auto: four batches of headroom between producers and the
        // learner (the same backlog target the serial curriculum uses).
        buffer_cap: if cfg.buffer_cap == 0 {
            4 * cfg.batch_size
        } else {
            cfg.buffer_cap.max(cfg.batch_size)
        },
        service: cfg.service,
        service_cfg: service_config(cfg),
    }
}

pub fn build_algo(cfg: &RunConfig) -> AlgoConfig {
    let mut algo = AlgoConfig::new(cfg.algo);
    algo.lr = cfg.lr;
    algo
}

pub fn build_sim_policy(cfg: &RunConfig) -> Result<SimPolicy> {
    let spec = SimModelSpec::parse(&cfg.model)
        .with_context(|| format!("unknown sim model '{}'", cfg.model))?;
    // Paper shapes: generation batch 64 prompts worth of rows; train batch
    // B x N rows. The call must also fit the allocator's largest possible
    // group (n_init + n_cont_max under adaptive budgets).
    let capacity = (cfg.batch_size * cfg.n_total()).max(cfg.max_group_rollouts());
    Ok(SimPolicy::new(spec, SimCostModel::default(), cfg.seed)
        .with_shapes(capacity, cfg.batch_size * cfg.n_total(), 512))
}

pub fn trainer_config(cfg: &RunConfig) -> TrainerConfig {
    TrainerConfig {
        batch_size: cfg.batch_size,
        temperature: cfg.temperature,
        eval_every: cfg.eval_every,
        max_steps: cfg.max_steps,
        max_seconds: cfg.max_seconds,
        stop_at_target: None,
        seed: cfg.seed,
        label: cfg.label.clone(),
    }
}

/// Run a config on the simulator substrate. With `cfg.pipeline` on, the
/// run goes through the [`PipelinedTrainer`] (K forked rollout engines
/// overlapping inference with updates); otherwise the serial reference
/// trainer.
pub fn run_sim(cfg: &RunConfig) -> Result<RunRecord> {
    anyhow::ensure!(cfg.substrate == Substrate::Sim, "config is not a sim run");
    cfg.validate()?;
    let dataset = Dataset::training(cfg.dataset, cfg.dataset_size, cfg.seed, MAX_PROMPT_CHARS);
    let mut policy = build_sim_policy(cfg)?;
    let evals = benchmark_suite(BENCH_SEED, MAX_PROMPT_CHARS);
    if cfg.pipeline {
        check_capacity(cfg, policy.rollout_capacity())?;
        let trainer =
            PipelinedTrainer::new(trainer_config(cfg), build_algo(cfg), pipeline_config(cfg));
        return trainer.run(&mut policy, curriculum_spec(cfg), &dataset, &evals);
    }
    if cfg.service {
        // Serial loop delegated through the coalescing service with one
        // producer — DESIGN.md §8's equivalence rail: this must reproduce
        // the plain serial RunRecord bit for bit (rust/tests/service_sim.rs).
        check_capacity(cfg, policy.rollout_capacity())?;
        let service = InferenceService::spawn(
            policy.fork_engine(0),
            service_config(cfg),
            1,
            cfg.max_group_rollouts(),
        );
        let handle = service.handle();
        let record = {
            let mut serviced = ServicedPolicy::new(handle, &mut policy);
            let mut curriculum = build_curriculum(cfg);
            let trainer = Trainer::new(trainer_config(cfg), build_algo(cfg));
            trainer.run(&mut serviced, curriculum.as_mut(), &dataset, &evals)
        };
        let mut record = record?;
        record.service = Some(service.stats());
        return Ok(record);
    }
    run_with_policy(cfg, &mut policy, &dataset, &evals)
}

/// The compiled (or simulated) inference call must fit a full group — the
/// LARGEST one the allocator can issue, not just the reference split.
fn check_capacity(cfg: &RunConfig, rollout_capacity: usize) -> Result<()> {
    let max_group = cfg.max_group_rollouts();
    if max_group > rollout_capacity {
        bail!(
            "a maximum-budget group of {max_group} rollouts exceeds rollout capacity \
             {rollout_capacity} — recompile artifacts or lower n_init/n_cont/n_cont_max"
        );
    }
    Ok(())
}

/// Run a config on the real PJRT substrate (artifacts required).
pub fn run_real(cfg: &RunConfig, artifacts_dir: &Path) -> Result<(RunRecord, RealPolicy)> {
    anyhow::ensure!(cfg.substrate == Substrate::Real, "config is not a real run");
    cfg.validate()?;
    let mut policy = RealPolicy::load(artifacts_dir, cfg.seed)?;
    let max_chars = policy.runtime.manifest.plan.prompt_len.min(MAX_PROMPT_CHARS + 4);
    let dataset = Dataset::training(cfg.dataset, cfg.dataset_size, cfg.seed, max_chars);
    let evals = benchmark_suite(BENCH_SEED, max_chars);
    let record = run_with_policy(cfg, &mut policy, &dataset, &evals)?;
    Ok((record, policy))
}

/// Shared inner loop.
pub fn run_with_policy(
    cfg: &RunConfig,
    policy: &mut dyn Policy,
    dataset: &Dataset,
    evals: &[EvalSet],
) -> Result<RunRecord> {
    cfg.validate()?;
    check_capacity(cfg, policy.rollout_capacity())?;
    if cfg.pipeline || cfg.service {
        // Only `run_sim` has a forkable engine; everything else (the real
        // substrate in particular, with its single PJRT engine) runs the
        // serial reference loop.
        crate::warn_log!(
            "driver",
            "pipeline={}/service={} with workers={} requested, but this substrate runs serially",
            cfg.pipeline,
            cfg.service,
            cfg.workers
        );
    }
    let mut curriculum = build_curriculum(cfg);
    let trainer = Trainer::new(trainer_config(cfg), build_algo(cfg));
    trainer.run(policy, curriculum.as_mut(), dataset, evals)
}

/// Table-1 accuracy targets per benchmark for each sim model scale,
/// following the caption's convention (lower thresholds for the smaller
/// model), recalibrated to the synthetic benchmarks' base accuracies.
pub fn paper_targets(model: &str) -> Vec<(&'static str, f64)> {
    match model {
        "sim-1.5b" => vec![("dapo1k", 0.30), ("math500", 0.70), ("amc2023", 0.40), ("aime", 0.10)],
        _ => vec![("dapo1k", 0.50), ("math500", 0.90), ("amc2023", 0.55), ("aime", 0.18)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::curriculum::CurriculumKind;

    #[test]
    fn sim_run_from_default_config() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 5;
        cfg.eval_every = 5;
        cfg.dataset_size = 2000;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 5);
        assert!(rec.total_time() > 0.0);
    }

    #[test]
    fn rejects_oversized_n() {
        // The guard matters for the real substrate, whose call capacity is
        // fixed by the compiled artifacts; emulate that with explicit
        // small sim shapes.
        let mut cfg = RunConfig::default();
        cfg.n_init = 60;
        cfg.n_cont = 60;
        cfg.dataset_size = 100;
        let dataset = Dataset::training(cfg.dataset, 100, 0, MAX_PROMPT_CHARS);
        let mut policy = crate::policy::sim::SimPolicy::new(
            crate::policy::sim::SimModelSpec::qwen_7b(),
            crate::policy::sim::SimCostModel::default(),
            0,
        )
        .with_shapes(64, 64, 512);
        let evals = benchmark_suite(BENCH_SEED, MAX_PROMPT_CHARS);
        assert!(run_with_policy(&cfg, &mut policy, &dataset, &evals).is_err());
    }

    #[test]
    fn pipelined_sim_run_completes() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 4;
        cfg.eval_every = 2;
        cfg.dataset_size = 2000;
        cfg.pipeline = true;
        cfg.workers = 2;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 4);
        assert!(rec.counters.rollouts > 0);
        assert!(rec.total_time() > 0.0);
        // engine-busy accounting only exists on the pipelined path
        assert!(rec.counters.busy_s > 0.0);
        // no service was requested, so no service counters are attached
        assert!(rec.service.is_none());
    }

    #[test]
    fn serviced_serial_sim_run_completes_with_service_counters() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 3;
        cfg.eval_every = 3;
        cfg.dataset_size = 2000;
        cfg.service = true;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 3);
        let svc = rec.service.expect("service counters attached");
        assert!(svc.calls > 0);
        // one producer: every call carries exactly one submission
        assert_eq!(svc.submissions, svc.calls);
        assert_eq!(svc.coalesced_hist[0], svc.calls);
        assert!(svc.max_call_rows > 0);
    }

    #[test]
    fn pipelined_service_sim_run_completes() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 4;
        cfg.eval_every = 2;
        cfg.dataset_size = 2000;
        cfg.pipeline = true;
        cfg.workers = 2;
        cfg.service = true;
        let rec = run_sim(&cfg).unwrap();
        assert_eq!(rec.steps.len(), 4);
        let svc = rec.service.expect("service counters attached");
        assert!(svc.calls > 0 && svc.submissions >= svc.calls);
        // per-step deltas: sum to at most the run totals, never out of range
        let step_calls: u64 = rec.steps.iter().map(|s| s.service_calls).sum();
        assert!(step_calls > 0, "per-step service deltas missing");
        assert!(step_calls <= svc.calls);
        assert!(rec.steps.iter().all(|s| (0.0..=1.0).contains(&s.service_fill)));
    }

    #[test]
    fn curriculum_construction_matches_kind() {
        for kind in [
            CurriculumKind::Uniform,
            CurriculumKind::DapoFilter,
            CurriculumKind::Speed,
            CurriculumKind::PredictiveSpeed,
            CurriculumKind::VarianceMax,
        ] {
            let mut cfg = RunConfig::default();
            cfg.curriculum = kind;
            assert_eq!(build_curriculum(&cfg).kind(), kind);
        }
    }

    #[test]
    fn predictive_spec_carries_a_shared_predictor() {
        let mut cfg = RunConfig::default();
        cfg.curriculum = CurriculumKind::PredictiveSpeed;
        let spec = curriculum_spec(&cfg);
        assert!(spec.predictor.is_some());
        // Clones (one per rollout worker) share the same store.
        let clone = spec.clone();
        assert!(Arc::ptr_eq(
            spec.predictor.as_ref().unwrap(),
            clone.predictor.as_ref().unwrap()
        ));
        // Non-predictive kinds carry none.
        let plain = curriculum_spec(&RunConfig::default());
        assert!(plain.predictor.is_none());
    }

    #[test]
    fn run_sim_rejects_invalid_config() {
        let mut cfg = RunConfig::default();
        cfg.max_steps = 1;
        cfg.n_init = 0;
        assert!(run_sim(&cfg).is_err());
    }
}
