//! Held-out evaluation: builds the paper's benchmark suite and scores a
//! policy on it (greedy decode, exact-match accuracy).

use anyhow::Result;

use crate::coordinator::trainer::EvalSet;
use crate::data::dataset::{Dataset, EvalBenchmark, ALL_BENCHMARKS};
use crate::policy::Policy;

/// Materialize all four paper benchmarks (DAPO-1k / MATH500 / AMC2023 /
/// AIME analogues) as trainer eval sets.
pub fn benchmark_suite(seed: u64, max_prompt_chars: usize) -> Vec<EvalSet> {
    ALL_BENCHMARKS
        .iter()
        .map(|b| {
            let d = Dataset::benchmark(*b, seed, max_prompt_chars);
            EvalSet { name: b.name().to_string(), tasks: d.instances }
        })
        .collect()
}

/// A subset of the suite by name (e.g. only the cheap ones during training).
pub fn benchmarks_by_name(names: &[&str], seed: u64, max_prompt_chars: usize) -> Vec<EvalSet> {
    names
        .iter()
        .filter_map(|n| EvalBenchmark::parse(n))
        .map(|b| {
            let d = Dataset::benchmark(b, seed, max_prompt_chars);
            EvalSet { name: b.name().to_string(), tasks: d.instances }
        })
        .collect()
}

/// Score a policy on every benchmark; returns (name, accuracy).
pub fn score_all(policy: &mut dyn Policy, sets: &[EvalSet]) -> Result<Vec<(String, f64)>> {
    sets.iter()
        .map(|s| Ok((s.name.clone(), policy.evaluate(&s.tasks)?.accuracy)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_all_benchmarks() {
        let suite = benchmark_suite(0, 24);
        let names: Vec<&str> = suite.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["dapo1k", "math500", "amc2023", "aime"]);
        assert_eq!(suite[0].tasks.len(), 1000);
        assert_eq!(suite[3].tasks.len(), 30);
    }

    #[test]
    fn by_name_filters() {
        let sets = benchmarks_by_name(&["math500", "aime"], 0, 24);
        assert_eq!(sets.len(), 2);
    }
}
