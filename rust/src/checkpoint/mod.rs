//! Run-state checkpointing: warm resume for the curriculum knowledge the
//! run accumulated online (ROADMAP item; ISSUE 5 tentpole).
//!
//! [`crate::runtime::ParamStore`] persists weights + optimizer state, but
//! SPEED's whole advantage is the *difficulty knowledge* built up during
//! training: the [`DifficultyStore`]'s discounted Beta posteriors, the
//! [`FeatureModel`]'s logistic weights, and the run's progress accounting.
//! Before this module a restart threw all of that away, so a resumed run
//! re-screened the easy/zero-pass tail from scratch — exactly the waste
//! the paper's screening stage exists to avoid.
//!
//! The checkpoint format extends `ParamStore::save`'s layout (versioned
//! JSON meta + raw buffers) with a **sidecar**, `<tag>.run_state.json`,
//! holding:
//!
//! * a config **fingerprint** (screening band, allocator bounds, predictor
//!   discount/skip-confidence, dataset, seed, …) so a mismatched resume is
//!   rejected loudly instead of silently blending incompatible posteriors;
//! * the [`Predictor`]'s knowledge (key-sorted Beta counts + feature-model
//!   weights + instance counter);
//! * run progress: next train step, weight version, cumulative
//!   [`InferenceCounters`], inference/update clocks, and the
//!   [`RunRecord`] so far — `StepRecord` indices and staleness accounting
//!   continue instead of restarting at zero;
//! * substrate/curriculum internals (sim policy RNG + skill, loader
//!   shuffle state, sampling-buffer contents, pending continuations),
//!   which is what makes the sim-substrate equivalence rail exact:
//!   train N → save → load → train N ≡ an uninterrupted 2N-step run, bit
//!   for bit (`rust/tests/checkpoint_sim.rs`).
//!
//! Quiesce-then-snapshot protocol (DESIGN.md §10): snapshots are taken
//! only between training steps with no rollout worker running and every
//! pending [`ObservationDelta`] flushed — the pipelined driver winds its
//! workers down (pool joined) before snapshotting, so no torn state can be
//! serialized.
//!
//! All u64 payloads (identity keys, RNG state, staleness sums) are encoded
//! as decimal *strings*: the JSON layer stores numbers as f64, which would
//! silently round anything above 2^53. f64/f32 payloads round-trip exactly
//! through the writer's shortest-representation formatting.
//!
//! [`DifficultyStore`]: crate::predictor::DifficultyStore
//! [`FeatureModel`]: crate::predictor::FeatureModel
//! [`Predictor`]: crate::predictor::Predictor
//! [`ObservationDelta`]: crate::predictor::ObservationDelta
//! [`InferenceCounters`]: crate::metrics::InferenceCounters
//! [`RunRecord`]: crate::metrics::RunRecord
//! [`StepRecord`]: crate::metrics::StepRecord

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::batcher::PendingContinuation;
use crate::coordinator::buffer::SamplingBufferState;
use crate::data::loader::LoaderState;
use crate::data::tasks::{TaskFamily, TaskInstance};
use crate::metrics::{InferenceCounters, RunRecord};
use crate::predictor::{BetaPosterior, FeatureModelState, PredictorState};
use crate::rl::update::{PromptGroup, Rollout};
use crate::util::json::Json;

/// Sidecar format version; bumped on incompatible layout changes. Loads
/// reject unknown versions loudly (checkpoint-format drift must fail the
/// resume, not corrupt it).
pub const FORMAT_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Checkpoint locations: the `dir:tag` spec grammar
// ---------------------------------------------------------------------------

/// A checkpoint location: directory + tag, the `dir:tag` grammar of the
/// `--checkpoint` / `--save` / `--resume` CLI flags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointSpec {
    pub dir: PathBuf,
    pub tag: String,
}

impl CheckpointSpec {
    pub fn new(dir: impl Into<PathBuf>, tag: impl Into<String>) -> CheckpointSpec {
        CheckpointSpec { dir: dir.into(), tag: tag.into() }
    }

    /// Parse a `dir:tag` spec. Split on the LAST colon — paths may contain
    /// colons (`runs:2026/ck:warm` means dir `runs:2026/ck`, tag `warm`);
    /// the old `split_once` parse mis-split exactly those. Tags therefore
    /// cannot contain colons, which the error text spells out.
    pub fn parse(spec: &str) -> Result<CheckpointSpec> {
        let Some((dir, tag)) = spec.rsplit_once(':') else {
            bail!("checkpoint spec '{spec}' must be dir:tag (e.g. ckpts:warm)");
        };
        if dir.is_empty() {
            bail!("checkpoint spec '{spec}' has an empty directory (want dir:tag)");
        }
        if tag.is_empty() {
            bail!(
                "checkpoint spec '{spec}' has an empty tag (want dir:tag; the tag follows \
                 the last ':' and cannot contain one)"
            );
        }
        Ok(CheckpointSpec::new(dir, tag))
    }
}

impl std::fmt::Display for CheckpointSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.dir.display(), self.tag)
    }
}

/// Run-state checkpoint I/O plan for one run: where to resume from, where
/// to save, and how often (0 = final save only).
#[derive(Clone, Debug, Default)]
pub struct CheckpointIo {
    pub resume: Option<CheckpointSpec>,
    pub save: Option<CheckpointSpec>,
    /// Save every this many training steps (0 = only the final save).
    pub save_every: usize,
}

impl CheckpointIo {
    pub fn is_noop(&self) -> bool {
        self.resume.is_none() && self.save.is_none()
    }

    /// Reject inconsistent plans at config time, not mid-run.
    pub fn validate(&self) -> Result<()> {
        if self.save_every > 0 && self.save.is_none() {
            bail!("--save-every {} given without a --save dir:tag target", self.save_every);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Config fingerprint
// ---------------------------------------------------------------------------

/// The config knobs that shape the *meaning* of persisted run state. A
/// resume whose config disagrees on any of these is rejected loudly: e.g.
/// posteriors accumulated under one discount are not valid evidence under
/// another, and a different screening band changes what "accept" meant.
///
/// Deliberately excluded: stop conditions (`max_steps`, `max_seconds`,
/// `eval_every`) — resuming with a larger step budget is the whole point —
/// and execution topology (`workers`, `pipeline`, `service`, coalescing
/// knobs), which changes scheduling but not the meaning of the state.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint(Json);

impl Fingerprint {
    pub fn of(cfg: &RunConfig) -> Fingerprint {
        Fingerprint(Json::obj(vec![
            ("model", Json::str(cfg.model.clone())),
            ("dataset", Json::str(cfg.dataset.name())),
            ("dataset_size", Json::num(cfg.dataset_size as f64)),
            ("seed", ju64(cfg.seed)),
            ("curriculum", Json::str(cfg.curriculum.name())),
            ("algo", Json::str(cfg.algo.name())),
            ("n_init", Json::num(cfg.n_init as f64)),
            ("n_cont", Json::num(cfg.n_cont as f64)),
            ("alloc", Json::str(cfg.alloc.name())),
            ("n_cont_min", Json::num(cfg.n_cont_min as f64)),
            ("n_cont_max", Json::num(cfg.n_cont_max as f64)),
            ("p_low", Json::num(cfg.p_low)),
            ("p_high", Json::num(cfg.p_high)),
            ("batch_size", Json::num(cfg.batch_size as f64)),
            ("temperature", Json::num(cfg.temperature as f64)),
            ("lr", Json::num(cfg.lr)),
            ("skip_confidence", Json::num(cfg.skip_confidence)),
            ("predictor_discount", Json::num(cfg.predictor_discount)),
            ("explore_rate", Json::num(cfg.explore_rate)),
        ]))
    }

    pub fn to_json(&self) -> Json {
        self.0.clone()
    }

    pub fn from_json(j: &Json) -> Fingerprint {
        Fingerprint(j.clone())
    }

    /// Reject a resume whose config disagrees with the checkpoint's,
    /// listing every mismatched knob with both values.
    pub fn check_matches(&self, cfg: &RunConfig) -> Result<()> {
        let want = Fingerprint::of(cfg);
        let saved = self.0.as_obj().cloned().unwrap_or_default();
        let live = want.0.as_obj().cloned().unwrap_or_default();
        let mut mismatches = Vec::new();
        let keys: std::collections::BTreeSet<&String> =
            saved.keys().chain(live.keys()).collect();
        for key in keys {
            let a = saved.get(key.as_str());
            let b = live.get(key.as_str());
            if a != b {
                mismatches.push(format!(
                    "{key}: checkpoint {} vs run {}",
                    a.map(Json::to_string).unwrap_or_else(|| "<absent>".into()),
                    b.map(Json::to_string).unwrap_or_else(|| "<absent>".into()),
                ));
            }
        }
        if !mismatches.is_empty() {
            bail!(
                "checkpoint config fingerprint does not match this run — resuming would blend \
                 incompatible curriculum state. Mismatches: {}",
                mismatches.join("; ")
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The run-state sidecar
// ---------------------------------------------------------------------------

/// Everything beyond raw weights that a warm resume needs; written as
/// `<tag>.run_state.json` next to the `ParamStore` files (sim runs have no
/// weight files — the sidecar alone is the checkpoint).
#[derive(Clone, Debug)]
pub struct RunState {
    pub fingerprint: Fingerprint,
    /// Next training step (the checkpoint was taken after `step` steps).
    pub step: usize,
    pub weight_version: u64,
    /// Cumulative inference/update clocks (the paper's time axis).
    pub inference_s: f64,
    pub update_s: f64,
    /// Cumulative run counters at the snapshot.
    pub counters: InferenceCounters,
    /// Step/eval records so far (so the resumed record is the full run's).
    pub record: RunRecord,
    pub loader: Option<LoaderState>,
    /// Generation token of the weight files saved alongside this sidecar
    /// ([`crate::policy::Trainable::params_token`]); checked at resume so
    /// a crash between the weight writes and the sidecar write (two save
    /// generations on disk) is detected instead of resumed torn.
    pub params_token: Option<u64>,
    /// Substrate-internal state ([`crate::policy::Trainable::state_json`]).
    pub policy: Option<Json>,
    /// Curriculum-internal state (sampling buffer, pending continuations,
    /// exploration RNG; [`crate::coordinator::curriculum::Curriculum::state_json`]).
    pub curriculum: Option<Json>,
    pub predictor: Option<PredictorState>,
}

impl RunState {
    /// Sidecar file name for a tag.
    pub fn file_name(tag: &str) -> String {
        format!("{tag}.run_state.json")
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("format_version", Json::num(FORMAT_VERSION as f64)),
            ("fingerprint", self.fingerprint.to_json()),
            ("step", Json::num(self.step as f64)),
            ("weight_version", ju64(self.weight_version)),
            ("inference_s", Json::num(self.inference_s)),
            ("update_s", Json::num(self.update_s)),
            ("counters", self.counters.to_json()),
            ("record", self.record.to_json()),
        ];
        if let Some(l) = &self.loader {
            fields.push(("loader", loader_state_to_json(l)));
        }
        if let Some(t) = self.params_token {
            fields.push(("params_token", ju64(t)));
        }
        if let Some(p) = &self.policy {
            fields.push(("policy", p.clone()));
        }
        if let Some(c) = &self.curriculum {
            fields.push(("curriculum", c.clone()));
        }
        if let Some(p) = &self.predictor {
            fields.push(("predictor", predictor_state_to_json(p)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<RunState> {
        let version = j.get("format_version").and_then(|x| x.as_u64_lossy()).unwrap_or(0);
        if version != FORMAT_VERSION {
            bail!(
                "run-state checkpoint format v{version} is not supported by this binary \
                 (expected v{FORMAT_VERSION}) — the checkpoint was written by an \
                 incompatible version"
            );
        }
        let fingerprint = Fingerprint::from_json(
            j.get("fingerprint").context("run state missing 'fingerprint'")?,
        );
        let counters = j
            .get("counters")
            .map(InferenceCounters::from_json)
            .context("run state missing 'counters'")?;
        let record = crate::metrics::report::record_from_json(
            j.get("record").context("run state missing 'record'")?,
        )?;
        Ok(RunState {
            fingerprint,
            step: j.get("step").and_then(|x| x.as_usize()).context("run state missing 'step'")?,
            weight_version: j.get("weight_version").map(pu64).transpose()?.unwrap_or(0),
            inference_s: j.get("inference_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            update_s: j.get("update_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            counters,
            record,
            loader: j.get("loader").map(loader_state_from_json).transpose()?,
            params_token: j.get("params_token").map(pu64).transpose()?,
            policy: j.get("policy").cloned(),
            curriculum: j.get("curriculum").cloned(),
            predictor: j.get("predictor").map(predictor_state_from_json).transpose()?,
        })
    }

    /// Write the sidecar (creating `dir` if needed). Written to a temp
    /// file and renamed into place: periodic saves reuse one tag, and an
    /// in-place rewrite would destroy the only good checkpoint if the
    /// process died mid-write.
    pub fn save(&self, dir: &Path, tag: &str) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        let path = dir.join(Self::file_name(tag));
        atomic_write(&path, self.to_json().to_string_pretty().as_bytes())
    }

    /// Load a sidecar written by [`save`](Self::save).
    pub fn load(dir: &Path, tag: &str) -> Result<RunState> {
        let path = dir.join(Self::file_name(tag));
        let j = Json::parse_file(&path)
            .with_context(|| format!("load run-state checkpoint {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("parse {}", path.display()))
    }
}

/// Crash-safe file write: write to `<path>.tmp`, then rename over `path`.
/// A checkpoint tag is reused by every periodic save, so the previous good
/// file must survive until the new one is fully on disk (shared by the
/// sidecar writer and `ParamStore::save`).
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, bytes).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// JSON encoding helpers (u64-safe, f32/f64 bit-exact)
// ---------------------------------------------------------------------------

/// u64 → JSON string (JSON numbers are f64: anything above 2^53 — identity
/// hashes, RNG state — would silently round).
pub fn ju64(x: u64) -> Json {
    Json::str(x.to_string())
}

/// Parse a [`ju64`]-encoded value (a plain number is accepted too, for
/// hand-written fixtures).
pub fn pu64(j: &Json) -> Result<u64> {
    if let Some(s) = j.as_str() {
        return s.parse::<u64>().with_context(|| format!("bad u64 '{s}'"));
    }
    j.as_u64_lossy().context("expected a u64 (string or number)")
}

pub fn rng_state_to_json(s: [u64; 4]) -> Json {
    Json::arr(s.iter().map(|x| ju64(*x)))
}

pub fn rng_state_from_json(j: &Json) -> Result<[u64; 4]> {
    let arr = j.as_arr().context("rng state must be an array")?;
    anyhow::ensure!(arr.len() == 4, "rng state must have 4 words, got {}", arr.len());
    let mut s = [0u64; 4];
    for (slot, v) in s.iter_mut().zip(arr) {
        *slot = pu64(v)?;
    }
    Ok(s)
}

pub fn task_to_json(t: &TaskInstance) -> Json {
    Json::obj(vec![
        ("family", Json::num(t.family.index() as f64)),
        ("level", Json::num(t.level as f64)),
        ("prompt", Json::str(t.prompt.clone())),
        ("answer", Json::num(t.answer as f64)),
    ])
}

pub fn task_from_json(j: &Json) -> Result<TaskInstance> {
    let family_idx = j.get("family").and_then(|x| x.as_usize()).context("task missing family")?;
    Ok(TaskInstance {
        family: TaskFamily::from_index(family_idx)
            .with_context(|| format!("unknown task family index {family_idx}"))?,
        level: j.get("level").and_then(|x| x.as_usize()).context("task missing level")? as u8,
        prompt: j.get("prompt").and_then(|x| x.as_str()).context("task missing prompt")?.into(),
        answer: j.get("answer").and_then(|x| x.as_i64()).context("task missing answer")?,
    })
}

pub fn rollout_to_json(r: &Rollout) -> Json {
    Json::obj(vec![
        ("tokens", Json::arr(r.gen_tokens.iter().map(|t| Json::num(*t as f64)))),
        ("logprobs", Json::arr(r.gen_logprobs.iter().map(|l| Json::num(*l as f64)))),
        ("reward", Json::num(r.reward as f64)),
    ])
}

pub fn rollout_from_json(j: &Json) -> Result<Rollout> {
    Ok(Rollout {
        gen_tokens: j.get("tokens").and_then(|x| x.as_i32_vec()).context("rollout tokens")?,
        gen_logprobs: j
            .get("logprobs")
            .and_then(|x| x.as_f64_vec())
            .context("rollout logprobs")?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        reward: j.get("reward").and_then(|x| x.as_f64()).context("rollout reward")? as f32,
    })
}

pub fn group_to_json(g: &PromptGroup) -> Json {
    Json::obj(vec![
        ("prompt_idx", Json::num(g.prompt_idx as f64)),
        ("task", task_to_json(&g.task)),
        ("rollouts", Json::arr(g.rollouts.iter().map(rollout_to_json))),
    ])
}

pub fn group_from_json(j: &Json) -> Result<PromptGroup> {
    Ok(PromptGroup {
        prompt_idx: j.get("prompt_idx").and_then(|x| x.as_usize()).context("group prompt_idx")?,
        task: task_from_json(j.get("task").context("group task")?)?,
        rollouts: j
            .get("rollouts")
            .and_then(|x| x.as_arr())
            .context("group rollouts")?
            .iter()
            .map(rollout_from_json)
            .collect::<Result<_>>()?,
    })
}

pub fn buffer_state_to_json(b: &SamplingBufferState) -> Json {
    Json::obj(vec![
        (
            "entries",
            Json::arr(b.entries.iter().map(|(g, born)| {
                Json::obj(vec![("group", group_to_json(g)), ("born_step", Json::num(*born as f64))])
            })),
        ),
        ("staleness_sum", ju64(b.staleness_sum)),
        ("consumed", ju64(b.consumed)),
        ("evicted", ju64(b.evicted)),
    ])
}

pub fn buffer_state_from_json(j: &Json) -> Result<SamplingBufferState> {
    let entries = j
        .get("entries")
        .and_then(|x| x.as_arr())
        .context("buffer entries")?
        .iter()
        .map(|e| -> Result<(PromptGroup, usize)> {
            Ok((
                group_from_json(e.get("group").context("buffer entry group")?)?,
                e.get("born_step").and_then(|x| x.as_usize()).context("buffer born_step")?,
            ))
        })
        .collect::<Result<_>>()?;
    Ok(SamplingBufferState {
        entries,
        staleness_sum: j.get("staleness_sum").map(pu64).transpose()?.unwrap_or(0),
        consumed: j.get("consumed").map(pu64).transpose()?.unwrap_or(0),
        evicted: j.get("evicted").map(pu64).transpose()?.unwrap_or(0),
    })
}

pub fn pending_to_json(p: &PendingContinuation) -> Json {
    Json::obj(vec![
        ("prompt_idx", Json::num(p.prompt_idx as f64)),
        ("task", task_to_json(&p.task)),
        ("screening", Json::arr(p.screening.iter().map(rollout_to_json))),
        ("born_step", Json::num(p.born_step as f64)),
        ("n_cont", Json::num(p.n_cont as f64)),
        ("forecast_var", Json::num(p.forecast_var)),
    ])
}

pub fn pending_from_json(j: &Json) -> Result<PendingContinuation> {
    Ok(PendingContinuation {
        prompt_idx: j.get("prompt_idx").and_then(|x| x.as_usize()).context("pending prompt_idx")?,
        task: task_from_json(j.get("task").context("pending task")?)?,
        screening: j
            .get("screening")
            .and_then(|x| x.as_arr())
            .context("pending screening")?
            .iter()
            .map(rollout_from_json)
            .collect::<Result<_>>()?,
        born_step: j.get("born_step").and_then(|x| x.as_usize()).context("pending born_step")?,
        n_cont: j.get("n_cont").and_then(|x| x.as_usize()).context("pending n_cont")?,
        forecast_var: j.get("forecast_var").and_then(|x| x.as_f64()).unwrap_or(0.0),
    })
}

fn loader_state_to_json(l: &LoaderState) -> Json {
    Json::obj(vec![
        ("order", Json::arr(l.order.iter().map(|i| Json::num(*i as f64)))),
        ("cursor", Json::num(l.cursor as f64)),
        ("epoch", Json::num(l.epoch as f64)),
        ("rng", rng_state_to_json(l.rng)),
    ])
}

fn loader_state_from_json(j: &Json) -> Result<LoaderState> {
    Ok(LoaderState {
        order: j.get("order").and_then(|x| x.as_usize_vec()).context("loader order")?,
        cursor: j.get("cursor").and_then(|x| x.as_usize()).context("loader cursor")?,
        epoch: j.get("epoch").and_then(|x| x.as_usize()).context("loader epoch")?,
        rng: rng_state_from_json(j.get("rng").context("loader rng")?)?,
    })
}

fn predictor_state_to_json(p: &PredictorState) -> Json {
    Json::obj(vec![
        (
            "entries",
            Json::arr(p.entries.iter().map(|(key, post)| {
                Json::arr(vec![ju64(*key), Json::num(post.alpha), Json::num(post.beta)])
            })),
        ),
        (
            "model",
            Json::obj(vec![
                ("w", Json::arr(p.model.w.iter().map(|w| Json::num(*w)))),
                ("lr", Json::num(p.model.lr)),
                ("updates", ju64(p.model.updates)),
            ]),
        ),
        ("instances", ju64(p.instances)),
    ])
}

fn predictor_state_from_json(j: &Json) -> Result<PredictorState> {
    let entries = j
        .get("entries")
        .and_then(|x| x.as_arr())
        .context("predictor entries")?
        .iter()
        .map(|e| -> Result<(u64, BetaPosterior)> {
            let triple = e.as_arr().context("predictor entry must be [key, alpha, beta]")?;
            anyhow::ensure!(triple.len() == 3, "predictor entry must be [key, alpha, beta]");
            Ok((
                pu64(&triple[0])?,
                BetaPosterior {
                    alpha: triple[1].as_f64().context("entry alpha")?,
                    beta: triple[2].as_f64().context("entry beta")?,
                },
            ))
        })
        .collect::<Result<_>>()?;
    let mj = j.get("model").context("predictor model")?;
    let w_vec = mj.get("w").and_then(|x| x.as_f64_vec()).context("model weights")?;
    let mut w = [0.0f64; crate::data::tasks::N_TASK_FEATURES];
    anyhow::ensure!(
        w_vec.len() == w.len(),
        "feature-model weight count {} does not match this binary's {} features — \
         checkpoint from an incompatible feature layout",
        w_vec.len(),
        w.len()
    );
    w.copy_from_slice(&w_vec);
    Ok(PredictorState {
        entries,
        model: FeatureModelState {
            w,
            lr: mj.get("lr").and_then(|x| x.as_f64()).unwrap_or(0.1),
            updates: mj.get("updates").map(pu64).transpose()?.unwrap_or(0),
        },
        instances: j.get("instances").map(pu64).transpose()?.unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::curriculum::CurriculumKind;

    #[test]
    fn spec_parse_splits_on_the_last_colon() {
        // The satellite bugfix: colon-bearing paths parse correctly.
        let s = CheckpointSpec::parse("runs:2026/ck:warm").unwrap();
        assert_eq!(s.dir, PathBuf::from("runs:2026/ck"));
        assert_eq!(s.tag, "warm");
        let s = CheckpointSpec::parse("ckpts:warm").unwrap();
        assert_eq!(s.dir, PathBuf::from("ckpts"));
        assert_eq!(s.tag, "warm");
        // empty dir/tag and missing colon are loud errors
        assert!(CheckpointSpec::parse("no-colon").is_err());
        assert!(CheckpointSpec::parse(":tag").is_err());
        assert!(CheckpointSpec::parse("dir:").is_err());
        let err = CheckpointSpec::parse("a/b:").unwrap_err().to_string();
        assert!(err.contains("empty tag"), "{err}");
    }

    #[test]
    fn io_validation_rejects_save_every_without_target() {
        let mut io = CheckpointIo::default();
        assert!(io.validate().is_ok());
        io.save_every = 5;
        assert!(io.validate().unwrap_err().to_string().contains("--save-every"));
        io.save = Some(CheckpointSpec::new("ck", "t"));
        assert!(io.validate().is_ok());
    }

    #[test]
    fn fingerprint_accepts_same_config_and_rejects_drift() {
        let cfg = RunConfig::default();
        let fp = Fingerprint::of(&cfg);
        assert!(fp.check_matches(&cfg).is_ok());
        // stop conditions may change freely on resume
        let mut more_steps = cfg.clone();
        more_steps.max_steps = 10 * cfg.max_steps;
        more_steps.eval_every = 1;
        assert!(fp.check_matches(&more_steps).is_ok());
        // ...but state-shaping knobs may not
        let mut drifted = cfg.clone();
        drifted.predictor_discount = 0.5;
        drifted.n_init = cfg.n_init + 1;
        let err = fp.check_matches(&drifted).unwrap_err().to_string();
        assert!(err.contains("predictor_discount"), "{err}");
        assert!(err.contains("n_init"), "{err}");
        let mut other_curriculum = cfg.clone();
        other_curriculum.curriculum = CurriculumKind::PredictiveSpeed;
        assert!(fp.check_matches(&other_curriculum).is_err());
    }

    #[test]
    fn u64_and_rng_state_roundtrip_above_2_53() {
        let big = u64::MAX - 12345;
        assert_eq!(pu64(&ju64(big)).unwrap(), big);
        let s = [u64::MAX, 1, 0, 0x9E37_79B9_7F4A_7C15];
        let back = rng_state_from_json(&rng_state_to_json(s)).unwrap();
        assert_eq!(back, s);
        // the round trip survives the actual serializer too
        let text = rng_state_to_json(s).to_string();
        assert_eq!(rng_state_from_json(&Json::parse(&text).unwrap()).unwrap(), s);
    }

    #[test]
    fn group_roundtrip_is_bit_exact() {
        let g = PromptGroup {
            prompt_idx: 7,
            task: TaskInstance {
                family: TaskFamily::Count,
                level: 9,
                prompt: "#7(17477)=".into(),
                answer: 3,
            },
            rollouts: vec![
                Rollout {
                    gen_tokens: vec![3, 1, -2],
                    gen_logprobs: vec![-0.1, -2.5e-3, f32::MIN_POSITIVE],
                    reward: 1.0,
                },
                Rollout { gen_tokens: vec![], gen_logprobs: vec![], reward: 0.0 },
            ],
        };
        let text = group_to_json(&g).to_string_pretty();
        let back = group_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.prompt_idx, g.prompt_idx);
        assert_eq!(back.task, g.task);
        assert_eq!(back.rollouts.len(), g.rollouts.len());
        for (a, b) in g.rollouts.iter().zip(&back.rollouts) {
            assert_eq!(a.gen_tokens, b.gen_tokens);
            assert_eq!(a.reward.to_bits(), b.reward.to_bits());
            assert_eq!(
                a.gen_logprobs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.gen_logprobs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn run_state_roundtrips_through_disk() {
        let cfg = RunConfig::default();
        let state = RunState {
            fingerprint: Fingerprint::of(&cfg),
            step: 12,
            weight_version: 12,
            inference_s: 123.456789,
            update_s: 7.0 / 3.0,
            counters: InferenceCounters {
                calls: 40,
                rollouts: 960,
                cost_s: 0.1 + 0.2, // a value with no short decimal form
                prompts_screened: 100,
                prompts_accepted: 60,
                brier_sum: 1.25,
                brier_n: 100,
                ..Default::default()
            },
            record: RunRecord { label: "rt".into(), ..Default::default() },
            loader: Some(LoaderState {
                order: vec![2, 0, 1],
                cursor: 1,
                epoch: 3,
                rng: [u64::MAX, 2, 3, 4],
            }),
            params_token: Some(312),
            policy: Some(Json::obj(vec![("skill", Json::num(6.125))])),
            curriculum: None,
            predictor: Some(PredictorState {
                entries: vec![(u64::MAX - 7, BetaPosterior { alpha: 1.5, beta: 0.25 })],
                model: FeatureModelState {
                    w: [0.125; crate::data::tasks::N_TASK_FEATURES],
                    lr: 0.1,
                    updates: 17,
                },
                instances: 2,
            }),
        };
        let dir = std::env::temp_dir().join(format!("speedrl-ckpt-test-{}", std::process::id()));
        state.save(&dir, "t").unwrap();
        let back = RunState::load(&dir, "t").unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(back.step, 12);
        assert_eq!(back.weight_version, 12);
        assert_eq!(back.inference_s.to_bits(), state.inference_s.to_bits());
        assert_eq!(back.update_s.to_bits(), state.update_s.to_bits());
        assert_eq!(back.counters.cost_s.to_bits(), state.counters.cost_s.to_bits());
        assert_eq!(back.counters.rollouts, 960);
        assert_eq!(back.loader.as_ref().unwrap().rng[0], u64::MAX);
        assert_eq!(back.params_token, Some(312));
        let pred = back.predictor.unwrap();
        assert_eq!(pred.entries[0].0, u64::MAX - 7);
        assert_eq!(pred.entries[0].1.alpha.to_bits(), 1.5f64.to_bits());
        assert_eq!(pred.model.updates, 17);
        assert!(back.fingerprint.check_matches(&cfg).is_ok());
        assert_eq!(back.policy.unwrap().get("skill").unwrap().as_f64(), Some(6.125));
    }

    #[test]
    fn random_predictor_and_counter_states_roundtrip_bitwise() {
        // The satellite property test: random posterior counts, feature
        // weights and counters must survive save → load with every bit
        // intact (the rail's foundation — one rounded f64 would desync a
        // resumed run's forecasts from the uninterrupted one's).
        use crate::util::proptest::check;
        check("checkpoint-roundtrip", 40, |rng| {
            let n_entries = rng.range_usize(0, 40);
            let entries: Vec<(u64, BetaPosterior)> = (0..n_entries)
                .map(|_| {
                    (
                        rng.next_u64(),
                        BetaPosterior {
                            alpha: 32.0 * rng.f64(),
                            beta: 32.0 * rng.f64(),
                        },
                    )
                })
                .collect();
            let mut w = [0.0f64; crate::data::tasks::N_TASK_FEATURES];
            for slot in w.iter_mut() {
                *slot = 4.0 * rng.f64() - 2.0;
            }
            let state = PredictorState {
                entries,
                model: FeatureModelState { w, lr: rng.f64().max(1e-3), updates: rng.next_u64() },
                instances: rng.next_u64(),
            };
            let counters = InferenceCounters {
                calls: rng.next_u64() >> 12,
                rollouts: rng.next_u64() >> 12,
                cost_s: 1e4 * rng.f64(),
                busy_s: rng.f64(),
                brier_sum: rng.f64(),
                brier_n: rng.next_u64() >> 12,
                ..Default::default()
            };
            let text = Json::obj(vec![
                ("predictor", predictor_state_to_json(&state)),
                ("counters", counters.to_json()),
            ])
            .to_string_pretty();
            let j = Json::parse(&text).map_err(|e| format!("reparse: {e}"))?;
            let back = predictor_state_from_json(j.get("predictor").unwrap())
                .map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(back.entries.len() == state.entries.len(), "entry count");
            for ((ka, pa), (kb, pb)) in state.entries.iter().zip(&back.entries) {
                crate::prop_assert!(ka == kb, "key changed");
                crate::prop_assert!(pa.alpha.to_bits() == pb.alpha.to_bits(), "alpha bits");
                crate::prop_assert!(pa.beta.to_bits() == pb.beta.to_bits(), "beta bits");
            }
            crate::prop_assert!(back.model.updates == state.model.updates, "updates");
            for (a, b) in state.model.w.iter().zip(&back.model.w) {
                crate::prop_assert!(a.to_bits() == b.to_bits(), "weight bits");
            }
            crate::prop_assert!(back.instances == state.instances, "instances");
            let cback = InferenceCounters::from_json(j.get("counters").unwrap());
            crate::prop_assert!(cback.calls == counters.calls, "calls");
            crate::prop_assert!(cback.rollouts == counters.rollouts, "rollouts");
            crate::prop_assert!(cback.cost_s.to_bits() == counters.cost_s.to_bits(), "cost_s");
            crate::prop_assert!(cback.busy_s.to_bits() == counters.busy_s.to_bits(), "busy_s");
            crate::prop_assert!(
                cback.brier_sum.to_bits() == counters.brier_sum.to_bits(),
                "brier_sum"
            );
            crate::prop_assert!(cback.brier_n == counters.brier_n, "brier_n");
            Ok(())
        });
    }

    #[test]
    fn run_state_rejects_unknown_format_version() {
        let j = Json::obj(vec![("format_version", Json::num(99))]);
        let err = RunState::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("v99"), "{err}");
    }
}
