#!/usr/bin/env bash
# Tier-1 verification (referenced from ROADMAP.md): build, tests, format,
# lints. Run from anywhere; operates on the repository root.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release --all-targets =="
# Lib, bins, tests, benches and examples all compile-gated in one step
# (benches/examples would otherwise rot — tests alone don't build them).
cargo build --release --all-targets

echo "== cargo test -q =="
cargo test -q

echo "== speed-rl bench (coalescing smoke -> BENCH_coalesce.json) =="
# Machine-readable perf trajectory: serial vs pipelined vs
# pipelined+service on the sim scenario (mean fill %, engine calls,
# steps/sec). Reuses the release build from the first step.
cargo run --release --bin speed-rl -- bench --steps 6 --workers 4 --out BENCH_coalesce.json

echo "== speed-rl bench --mode alloc (fixed vs adaptive budgets -> BENCH_alloc.json) =="
# Fixed vs posterior-variance-proportional continuation budgets on the
# serial SPEED curriculum: rollouts spent to reach the same dapo1k bar
# (adaptive should get there on fewer rollouts).
cargo run --release --bin speed-rl -- bench --mode alloc --steps 40 --target 0.45 \
  --out BENCH_alloc.json

echo "== resume smoke (train -> save -> resume must equal the uninterrupted run) =="
# The checkpoint-format drift gate: a 6+6-step predictive-speed resume must
# reproduce the uninterrupted 12-step run's record byte for byte (the
# sim-substrate equivalence rail of DESIGN.md §10). Any change to the
# sidecar layout, the restore order, or the RNG/loader state capture that
# breaks warm resume fails here, not in a week-long production run.
CK_DIR="ck_resume_smoke"
rm -rf "$CK_DIR" full_run.json resumed_run.json
SIM_FLAGS="--curriculum predictive-speed --dataset-size 2000 --batch-size 8 --eval-every 6 --log-level warn"
cargo run --release --bin speed-rl -- simulate $SIM_FLAGS --steps 12 --out full_run.json
cargo run --release --bin speed-rl -- simulate $SIM_FLAGS --steps 6 --save "$CK_DIR:smoke"
cargo run --release --bin speed-rl -- simulate $SIM_FLAGS --steps 12 --resume "$CK_DIR:smoke" \
  --out resumed_run.json
if ! diff -q full_run.json resumed_run.json; then
  echo "resume smoke FAILED: resumed run diverged from the uninterrupted run"
  diff -u full_run.json resumed_run.json | head -40
  exit 1
fi
rm -rf "$CK_DIR" full_run.json resumed_run.json
echo "resume smoke: resumed record identical to uninterrupted run"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "ci: all green"
