#!/usr/bin/env bash
# Tier-1 verification (referenced from ROADMAP.md): build, tests, format,
# lints. Run from anywhere; operates on the repository root.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release --all-targets =="
# Lib, bins, tests, benches and examples all compile-gated in one step
# (benches/examples would otherwise rot — tests alone don't build them).
cargo build --release --all-targets

echo "== speed-rl lint (invariant linter, DESIGN.md 15) =="
# Hard gate, ahead of fmt/clippy: lock discipline + declared lock orders,
# counter-schema completeness (incl. the chaos-smoke normalization set
# below), harness registration, wall-clock hygiene, metric-table coverage.
cargo run --release --bin speed-rl -- lint

echo "== cargo test -q =="
cargo test -q

echo "== model checking (exhaustive interleaving explorer) =="
# Every schedule of the SharedBuffer push/pop/close protocol and the
# pool's exactly-once seized-slot claim (DESIGN.md 15). Also runs inside
# `cargo test -q` above; the explicit leg keeps the gate visible.
cargo test -q --test loom_sync
if [ "${SPEED_RL_LOOM:-0}" = "1" ]; then
  echo "== loom model checking (SPEED_RL_LOOM=1) =="
  # Real loom run against the util::sync aliases: needs a toolchain with
  # the loom crate vendored (unavailable in the offline image).
  RUSTFLAGS="--cfg loom" cargo test -q --test loom_sync
else
  echo "loom leg skipped (set SPEED_RL_LOOM=1 with a loom-vendored toolchain)"
fi
if command -v rustup >/dev/null 2>&1 && rustup toolchain list 2>/dev/null | grep -q nightly; then
  echo "== ThreadSanitizer smoke (nightly, soft gate) =="
  if ! RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -q --test loom_sync; then
    echo "WARNING: TSan smoke failed (soft gate; inspect before release)"
  fi
else
  echo "tsan smoke skipped (nightly toolchain unavailable)"
fi

echo "== speed-rl bench (coalescing smoke -> BENCH_coalesce.json) =="
# Machine-readable perf trajectory: serial vs pipelined vs
# pipelined+service on the sim scenario (mean fill %, engine calls,
# steps/sec). Reuses the release build from the first step.
cargo run --release --bin speed-rl -- bench --steps 6 --workers 4 --out BENCH_coalesce.json

echo "== speed-rl bench --mode alloc (fixed vs adaptive budgets -> BENCH_alloc.json) =="
# Fixed vs posterior-variance-proportional continuation budgets on the
# serial SPEED curriculum: rollouts spent to reach the same dapo1k bar
# (adaptive should get there on fewer rollouts).
cargo run --release --bin speed-rl -- bench --mode alloc --steps 40 --target 0.45 \
  --out BENCH_alloc.json

echo "== speed-rl bench --mode pool (engine-pool scaling -> BENCH_pool.json) =="
# K workers x E data-parallel engine replicas behind the shared service.
# Gate: scaling the pool changes WHERE plans execute, never how many the
# router forms — at a fixed worker count E=2 may not issue more engine calls
# than E=1, and the final dapo1k accuracy must stay matched.
cargo run --release --bin speed-rl -- bench --mode pool --steps 12 --workers 8 \
  --engines 1,2,4 --out BENCH_pool.json
python3 - <<'EOF'
import json
modes = {int(m["engines"]): m for m in json.load(open("BENCH_pool.json"))["modes"]}
e1, e2 = modes[1], modes[2]
assert e2["engine_calls"] <= e1["engine_calls"], (
    f"pool fragmented the stream: E=2 made {e2['engine_calls']:.0f} engine calls "
    f"vs E=1's {e1['engine_calls']:.0f}")
assert abs(e2["final_dapo1k"] - e1["final_dapo1k"]) < 0.15, (
    f"pool changed learning: E=2 dapo1k {e2['final_dapo1k']:.3f} "
    f"vs E=1 {e1['final_dapo1k']:.3f}")
print(f"pool smoke: E=1 {e1['engine_calls']:.0f} calls / E=2 {e2['engine_calls']:.0f} calls, "
      f"dapo1k {e1['final_dapo1k']:.3f} vs {e2['final_dapo1k']:.3f}")
EOF

echo "== speed-rl bench --mode slots (deadline vs slot admission -> BENCH_slots.json) =="
# Deadline coalescing vs slot-level admission on the same seed. Gate: the
# slots router admits each submission as a full-quantum call, so its mean
# fill must not fall below the deadline router's, and accuracy must stay
# matched (same training run, different dispatch). Queue-wait p95 is
# wall-clock — printed for the trajectory, soft-gated with generous slack.
cargo run --release --bin speed-rl -- bench --mode slots --steps 12 --workers 8 \
  --engines 2 --out BENCH_slots.json
python3 - <<'EOF'
import json
modes = {m["batching"]: m for m in json.load(open("BENCH_slots.json"))["modes"]}
dl, sl = modes["deadline"], modes["slots"]
assert sl["mean_fill"] + 1e-9 >= dl["mean_fill"], (
    f"slot admission lost fill: slots {sl['mean_fill']:.3f} "
    f"vs deadline {dl['mean_fill']:.3f}")
assert abs(sl["final_dapo1k"] - dl["final_dapo1k"]) < 0.15, (
    f"batching mode changed learning: slots dapo1k {sl['final_dapo1k']:.3f} "
    f"vs deadline {dl['final_dapo1k']:.3f}")
assert sl["mean_slot_occupancy"] > 0, "slots leg recorded no slot occupancy"
if sl["queue_wait_p95_s"] > dl["queue_wait_p95_s"] * 2 + 1e-3:
    print(f"WARNING: slots queue-wait p95 {1e3 * sl['queue_wait_p95_s']:.3f}ms well above "
          f"deadline's {1e3 * dl['queue_wait_p95_s']:.3f}ms (wall-clock; not gated hard)")
print(f"slots smoke: fill {dl['mean_fill']:.3f} -> {sl['mean_fill']:.3f}, "
      f"queue-wait p95 {1e3 * dl['queue_wait_p95_s']:.3f}ms -> "
      f"{1e3 * sl['queue_wait_p95_s']:.3f}ms, "
      f"dapo1k {dl['final_dapo1k']:.3f} vs {sl['final_dapo1k']:.3f}")
EOF

echo "== resume smoke (train -> save -> resume must equal the uninterrupted run) =="
# The checkpoint-format drift gate: a 6+6-step predictive-speed resume must
# reproduce the uninterrupted 12-step run's record byte for byte (the
# sim-substrate equivalence rail of DESIGN.md §10). Any change to the
# sidecar layout, the restore order, or the RNG/loader state capture that
# breaks warm resume fails here, not in a week-long production run.
CK_DIR="ck_resume_smoke"
rm -rf "$CK_DIR" full_run.json resumed_run.json
SIM_FLAGS="--curriculum predictive-speed --dataset-size 2000 --batch-size 8 --eval-every 6 --log-level warn"
cargo run --release --bin speed-rl -- simulate $SIM_FLAGS --steps 12 --out full_run.json
cargo run --release --bin speed-rl -- simulate $SIM_FLAGS --steps 6 --save "$CK_DIR:smoke"
cargo run --release --bin speed-rl -- simulate $SIM_FLAGS --steps 12 --resume "$CK_DIR:smoke" \
  --out resumed_run.json
if ! diff -q full_run.json resumed_run.json; then
  echo "resume smoke FAILED: resumed run diverged from the uninterrupted run"
  diff -u full_run.json resumed_run.json | head -40
  exit 1
fi
rm -rf "$CK_DIR" full_run.json resumed_run.json
echo "resume smoke: resumed record identical to uninterrupted run"

echo "== trace smoke (--trace must not perturb the run; trace JSON must load) =="
# The zero-perturbation gate of DESIGN.md §12: a serial --trace run's
# record must be byte-for-byte identical to the untraced one (the same
# rail tests/trace_sim.rs holds on the library API), and the exported
# Chrome trace must be Perfetto-loadable JSON with spans from the
# instrumented layers. Pipelined runs are scheduling-nondeterministic
# (DESIGN.md §8), so the pipelined K=4/E=2 leg checks trace shape and
# the analyzer, not record bytes.
rm -f trace_base.json trace_traced.json trace_smoke.json trace_pipe.json trace_reexport.json
TRACE_FLAGS="--dataset-size 2000 --batch-size 8 --steps 8 --eval-every 4 --log-level warn"
cargo run --release --bin speed-rl -- simulate $TRACE_FLAGS --out trace_base.json
cargo run --release --bin speed-rl -- simulate $TRACE_FLAGS --out trace_traced.json \
  --trace trace_smoke.json
if ! diff -q trace_base.json trace_traced.json; then
  echo "trace smoke FAILED: --trace perturbed the run record"
  diff -u trace_base.json trace_traced.json | head -40
  exit 1
fi
cargo run --release --bin speed-rl -- simulate $TRACE_FLAGS --workers 4 --engines 2 \
  --trace trace_pipe.json
python3 - <<'EOF'
import json
for path, want in [
    ("trace_smoke.json", {"optimizer-update", "collect-batch", "evaluate"}),
    ("trace_pipe.json", {"optimizer-update", "collect-batch", "evaluate",
                         "engine-execute", "weight-publish"}),
]:
    doc = json.load(open(path))
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert spans, f"{path}: no complete spans"
    for e in spans:
        assert {"name", "cat", "pid", "tid", "ts", "dur"} <= e.keys(), f"{path}: bad span {e}"
    names = {e["name"] for e in spans}
    assert not want - names, f"{path}: missing spans {want - names}"
    assert doc["otherData"]["dropped_events"] == 0, f"{path}: dropped events"
print("trace smoke: record byte-identical; both traces Perfetto-loadable")
EOF
cargo run --release --bin speed-rl -- trace summarize trace_pipe.json
cargo run --release --bin speed-rl -- trace trace_smoke.json --format chrome \
  --out trace_reexport.json
python3 -c "import json; json.load(open('trace_reexport.json'))"
rm -f trace_base.json trace_traced.json trace_smoke.json trace_pipe.json trace_reexport.json
echo "trace smoke: analyzer and re-export OK"

echo "== chaos smoke (fault injection: empty-plan equivalence; E=3 err+stall+die run) =="
# The no-faults equivalence rail of DESIGN.md §13 at the CLI: arming the
# recovery machinery with an empty plan (--fault-plan none) must leave
# every deterministic field of the serviced record untouched. The service's
# real-time telemetry (queue waits, exec histograms) is wall-clock and
# differs between ANY two runs, so those keys are normalized out before
# the comparison; rust/tests/fault_sim.rs holds the same rail field by
# field on the library API.
rm -f chaos_plain.json chaos_none.json chaos_run.json chaos_slots.json chaos_err.log
CHAOS_FLAGS="--dataset-size 2000 --batch-size 8 --steps 8 --eval-every 4 --service --log-level warn"
cargo run --release --bin speed-rl -- simulate $CHAOS_FLAGS --out chaos_plain.json
cargo run --release --bin speed-rl -- simulate $CHAOS_FLAGS --fault-plan none --out chaos_none.json
python3 - <<'EOF'
import json
WALL = {"queue_wait_s", "ewma_gap_s", "queue_wait_hist", "exec_hist",
        "queue_wait_p95_s", "exec_p95_s"}
def norm(path):
    doc = json.load(open(path))
    for k in WALL:
        doc.get("service", {}).pop(k, None)
    return doc
plain, armed = norm("chaos_plain.json"), norm("chaos_none.json")
assert plain == armed, "--fault-plan none perturbed the run record"
svc = armed["service"]
zero = ("faults_injected", "retries", "redispatches", "quarantines", "respawns")
assert all(svc[k] == 0 for k in zero), {k: svc[k] for k in zero}
print("chaos smoke: armed-but-empty plan record identical to the plain run")
EOF
# An E=3 pipelined run under a scripted err+stall+die plan (one transient
# error, one stall past the 50ms watchdog, one hard death) must complete
# all steps, answer every worker submission exactly once, and account
# each recovery action in the service counters.
cargo run --release --bin speed-rl -- simulate $CHAOS_FLAGS --workers 3 --engines 3 \
  --fault-plan "err@0:2,stall@1:3:400,die@2:4" --exec-timeout-ms 50 --respawn \
  --out chaos_run.json
python3 - <<'EOF'
import json
doc = json.load(open("chaos_run.json"))
svc = doc["service"]
assert len(doc["steps"]) == 8, f"chaos run died early: {len(doc['steps'])} steps"
assert svc["faults_injected"] >= 3, f"scripted faults missing: {svc['faults_injected']}"
assert svc["retries"] >= 1, "the transient fault was not retried"
assert svc["quarantines"] >= 1, "neither the stalled nor the dead replica was quarantined"
assert svc["respawns"] >= 1, "no spare respawned into a quarantined slot"
# Exactly-once: worker-side counters count submissions in serviced runs;
# a lost ticket hangs the run, a duplicate desyncs these totals.
assert svc["submissions"] == doc["counters"]["calls"], (
    f"submissions lost or duplicated: {svc['submissions']:.0f} served "
    f"vs {doc['counters']['calls']:.0f} submitted")
print(f"chaos smoke: E=3 run survived {svc['faults_injected']:.0f} faults "
      f"({svc['retries']:.0f} retries, {svc['quarantines']:.0f} quarantines, "
      f"{svc['respawns']:.0f} respawns); every submission answered once")
EOF
# The same chaos plan through the slots router: slot-granular recovery
# must still complete the plan and answer every submission exactly once.
cargo run --release --bin speed-rl -- simulate $CHAOS_FLAGS --workers 3 --engines 3 \
  --batching slots --fault-plan "err@0:2,stall@1:3:400,die@2:4" --exec-timeout-ms 50 \
  --respawn --out chaos_slots.json
python3 - <<'EOF'
import json
doc = json.load(open("chaos_slots.json"))
svc = doc["service"]
assert len(doc["steps"]) == 8, f"slots chaos run died early: {len(doc['steps'])} steps"
assert svc["slots_mode"] == 1, "slots chaos leg did not run in slots mode"
assert svc["faults_injected"] >= 3, f"scripted faults missing: {svc['faults_injected']}"
assert svc["submissions"] == doc["counters"]["calls"], (
    f"slot redispatch lost or duplicated work: {svc['submissions']:.0f} served "
    f"vs {doc['counters']['calls']:.0f} submitted")
assert svc["slot_admissions"] >= svc["slot_retires"] > 0, (
    f"slot lifecycle accounting broken: {svc['slot_admissions']:.0f} admissions "
    f"vs {svc['slot_retires']:.0f} retires")
print(f"chaos smoke: slots-mode E=3 run survived {svc['faults_injected']:.0f} faults; "
      f"{svc['slot_admissions']:.0f} slot admissions, every submission answered once")
EOF
cargo run --release --bin speed-rl -- report chaos_run.json --metric faults
cargo run --release --bin speed-rl -- report chaos_run.json --metric retries
cargo run --release --bin speed-rl -- report chaos_slots.json --metric slot-occupancy
# A bogus plan must be rejected up front with the kinds and grammar quoted.
if cargo run --release --bin speed-rl -- simulate $CHAOS_FLAGS --fault-plan explode@0:0 \
    > chaos_err.log 2>&1; then
  echo "chaos smoke FAILED: bogus --fault-plan accepted"
  exit 1
fi
if ! grep -q "kind@replica:call" chaos_err.log; then
  echo "chaos smoke FAILED: --fault-plan error does not quote the grammar"
  cat chaos_err.log
  exit 1
fi
rm -f chaos_plain.json chaos_none.json chaos_run.json chaos_slots.json chaos_err.log
echo "chaos smoke: scripted-fault run recovered; bad plans rejected with grammar"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
# --all-targets: tests, benches and examples are lint-gated too, not just
# the lib/bin shipping code.
cargo clippy --all-targets -- -D warnings

echo "ci: all green"
