#!/usr/bin/env bash
# Tier-1 verification (referenced from ROADMAP.md): build, tests, format,
# lints. Run from anywhere; operates on the repository root.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release --all-targets =="
# Lib, bins, tests, benches and examples all compile-gated in one step
# (benches/examples would otherwise rot — tests alone don't build them).
cargo build --release --all-targets

echo "== cargo test -q =="
cargo test -q

echo "== speed-rl bench (coalescing smoke -> BENCH_coalesce.json) =="
# Machine-readable perf trajectory: serial vs pipelined vs
# pipelined+service on the sim scenario (mean fill %, engine calls,
# steps/sec). Reuses the release build from the first step.
cargo run --release --bin speed-rl -- bench --steps 6 --workers 4 --out BENCH_coalesce.json

echo "== speed-rl bench --mode alloc (fixed vs adaptive budgets -> BENCH_alloc.json) =="
# Fixed vs posterior-variance-proportional continuation budgets on the
# serial SPEED curriculum: rollouts spent to reach the same dapo1k bar
# (adaptive should get there on fewer rollouts).
cargo run --release --bin speed-rl -- bench --mode alloc --steps 40 --target 0.45 \
  --out BENCH_alloc.json

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "ci: all green"
