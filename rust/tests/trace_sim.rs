//! Integration: the trace spine's zero-perturbation contract on the sim
//! substrate (DESIGN.md §12).
//!
//! Rails:
//! * serial — a `--trace` run's `RunRecord` is byte-for-byte identical to
//!   an untraced one, and the exported Chrome trace JSON is well formed;
//! * pooled serial (E=2) — tracing preserves the pool degeneracy rail
//!   (pooled ≡ plain serial on the deterministic projection,
//!   `tests/pool_sim.rs`) while the timeline carries scheduler and
//!   replica rows;
//! * pipelined pooled (K=4, E=2) — a traced run completes with spans
//!   from every layer (workers, learner, scheduler, replicas) and the
//!   analyzer summarizes them. Pipelined runs are
//!   scheduling-nondeterministic (DESIGN.md §8), so the byte-exact
//!   record rail lives on the serial topologies; here the contract is
//!   structural.
//!
//! The trace collector is process-global, so every test in this file —
//! including the untraced baselines — serializes on one mutex: a
//! parallel untraced run would otherwise register its threads into
//! another test's enabled collection.

use std::collections::BTreeSet;
use std::sync::{Mutex, MutexGuard};

use speed_rl::config::RunConfig;
use speed_rl::driver;
use speed_rl::trace;
use speed_rl::util::json::Json;

static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_trace_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("speedrl_trace_{}_{name}.json", std::process::id()))
}

fn base_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.max_steps = 8;
    cfg.eval_every = 4;
    cfg.dataset_size = 2000;
    cfg.seed = seed;
    cfg
}

/// Span-name and thread-label sets from an exported Chrome trace document.
fn trace_shape(doc: &Json) -> (BTreeSet<String>, BTreeSet<String>) {
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    let mut names = BTreeSet::new();
    let mut labels = BTreeSet::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        if ph == "M" {
            if let Some(l) = ev.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()) {
                labels.insert(l.to_string());
            }
        } else if let Some(n) = ev.get("name").and_then(|n| n.as_str()) {
            names.insert(n.to_string());
        }
    }
    (names, labels)
}

#[test]
fn traced_serial_run_reproduces_untraced_record_byte_for_byte() {
    let _g = lock();
    let path = tmp_trace_path("serial");
    let untraced = driver::run_sim(&base_cfg(3)).unwrap();
    let mut cfg = base_cfg(3);
    cfg.trace = Some(path.display().to_string());
    let traced = driver::run_sim(&cfg).unwrap();
    assert_eq!(
        untraced.to_json().to_string(),
        traced.to_json().to_string(),
        "tracing perturbed the serial run record"
    );

    let doc = Json::parse_file(&path).expect("trace file parses");
    let (names, _labels) = trace_shape(&doc);
    for want in ["collect-batch", "optimizer-update", "evaluate"] {
        assert!(names.contains(want), "missing span '{want}' in {names:?}");
    }
    let summary = trace::summarize_chrome(&doc).unwrap();
    assert_eq!(summary.dropped_events, 0);
    let opt = summary.phases.iter().find(|p| p.name == "optimizer-update").unwrap();
    assert_eq!(opt.count, 8, "one optimizer-update span per step");
    assert!(opt.p50_s <= opt.p95_s && opt.p95_s <= opt.p99_s);
    // Step-0 eval plus the periodic ones at steps 4 and 8.
    let evals = summary.phases.iter().find(|p| p.name == "evaluate").unwrap();
    assert_eq!(evals.count, 3);
    std::fs::remove_file(&path).ok();
}

#[test]
fn traced_e2_pool_preserves_the_degeneracy_rail_with_replica_rows() {
    let _g = lock();
    let path = tmp_trace_path("pooled");
    let serial = driver::run_sim(&base_cfg(9)).unwrap();
    let mut cfg = base_cfg(9);
    cfg.service = true;
    cfg.engines = 2;
    cfg.trace = Some(path.display().to_string());
    let pooled = driver::run_sim(&cfg).unwrap();

    // The pool degeneracy rail with tracing on: the deterministic
    // projection must still match plain serial exactly.
    assert_eq!(serial.steps.len(), pooled.steps.len());
    for (a, b) in serial.steps.iter().zip(pooled.steps.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.train_pass_rate, b.train_pass_rate);
        assert_eq!(a.grad_norm, b.grad_norm);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.prompts_consumed, b.prompts_consumed);
        assert_eq!(a.mean_staleness, b.mean_staleness);
    }
    assert_eq!(serial.evals.len(), pooled.evals.len());
    for (a, b) in serial.evals.iter().zip(pooled.evals.iter()) {
        assert_eq!(a.benchmark, b.benchmark);
        assert_eq!(a.accuracy, b.accuracy);
    }
    assert_eq!(serial.counters.calls, pooled.counters.calls);
    assert_eq!(serial.counters.cost_s, pooled.counters.cost_s);

    // The always-on latency histograms filled in: every submission lands
    // in exactly one queue-wait bucket, every executed call (or split
    // chunk) in one exec bucket.
    let svc = pooled.service.expect("service counters");
    assert_eq!(svc.queue_wait_hist.iter().sum::<u64>(), svc.submissions);
    assert!(svc.exec_hist.iter().sum::<u64>() >= svc.calls);

    let doc = Json::parse_file(&path).expect("trace file parses");
    let (names, labels) = trace_shape(&doc);
    assert!(names.contains("engine-execute"), "{names:?}");
    assert!(names.contains("dispatch"), "{names:?}");
    assert!(labels.contains("speedrl-inference-service"), "{labels:?}");
    assert!(labels.contains("speedrl-engine-0"), "{labels:?}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn traced_pipelined_pool_run_has_spans_from_every_layer() {
    let _g = lock();
    let path = tmp_trace_path("pipelined");
    let mut cfg = base_cfg(7);
    cfg.pipeline = true;
    cfg.workers = 4;
    cfg.service = true;
    cfg.engines = 2;
    cfg.trace = Some(path.display().to_string());
    let rec = driver::run_sim(&cfg).unwrap();
    assert_eq!(rec.steps.len(), 8);
    let svc = rec.service.expect("service counters");
    assert!(svc.calls > 0);
    assert_eq!(svc.queue_wait_hist.iter().sum::<u64>(), svc.submissions);
    // The per-step p95s are upper-edge estimates over histogram deltas:
    // finite, non-negative, and present once the service saw traffic.
    assert!(rec.steps.iter().all(|s| s.service_queue_wait_p95_s >= 0.0));
    assert!(rec.steps.iter().all(|s| s.service_exec_p95_s.is_finite()));
    assert!(rec.steps.iter().any(|s| s.service_exec_p95_s > 0.0));

    let doc = Json::parse_file(&path).expect("trace file parses");
    let (names, labels) = trace_shape(&doc);
    for want in [
        "collect-batch",
        "optimizer-update",
        "weight-publish",
        "evaluate",
        "engine-execute",
        "dispatch",
        "coalesce-wait",
    ] {
        assert!(names.contains(want), "missing span '{want}' in {names:?}");
    }
    for want in [
        "learner",
        "worker-0",
        "worker-3",
        "speedrl-inference-service",
        "speedrl-engine-0",
        "speedrl-engine-1",
    ] {
        assert!(labels.contains(want), "missing thread '{want}' in {labels:?}");
    }
    let summary = trace::summarize_chrome(&doc).unwrap();
    assert!(summary.threads >= 7, "workers + learner + scheduler + replicas: {}", summary.threads);
    assert!(summary.events > 0);
    assert!(summary.wall_s > 0.0);
    std::fs::remove_file(&path).ok();
}
