//! Coordinator invariants against a *scripted* policy: every curriculum is
//! driven with a deterministic pass-rate oracle so routing, batching,
//! accounting, and trainer behavior can be asserted exactly — including the
//! pipelined producer/consumer path (serial equivalence, conservation,
//! bounded staleness).

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use speed_rl::coordinator::curriculum::{self, CurriculumKind, CurriculumSpec};
use speed_rl::coordinator::pipeline::{PipelineConfig, PipelinedTrainer};
use speed_rl::coordinator::screening::ScreeningRule;
use speed_rl::coordinator::trainer::{EvalSet, Trainer, TrainerConfig};
use speed_rl::data::dataset::{Dataset, DatasetKind};
use speed_rl::data::tasks::TaskInstance;
use speed_rl::metrics::RunRecord;
use speed_rl::policy::{
    EvalResult, ForkEngine, GenRequest, GenResult, RolloutEngine, TrainResult, Trainable,
    WeightSnapshot,
};
use speed_rl::rl::algo::{AlgoConfig, BaseAlgo};
use speed_rl::rl::update::{PromptGroup, Rollout};
use speed_rl::util::proptest::check;
use speed_rl::util::rng::Rng;

/// A policy whose pass rates are a pure function of the task level, with a
/// fully recorded call log. Logs are behind `Arc<Mutex>` so forked engines
/// (pipelined workers) share them with the learner-side instance.
struct MockPolicy {
    capacity: usize,
    rng: Rng,
    seed: u64,
    /// pass rate per difficulty level (index 1..=10)
    level_p: [f64; 11],
    /// accuracy returned by every `evaluate` call
    eval_accuracy: f64,
    /// log of (rows_used, n_requests) per call
    call_log: Arc<Mutex<Vec<(usize, usize)>>>,
    trained_groups: Arc<Mutex<Vec<Vec<(usize, usize)>>>>, // per step: (prompt_idx, n_rollouts)
    version: u64,
}

impl MockPolicy {
    fn new(seed: u64, level_p: [f64; 11]) -> MockPolicy {
        MockPolicy {
            capacity: 96,
            rng: Rng::new(seed),
            seed,
            level_p,
            eval_accuracy: 0.5,
            call_log: Arc::new(Mutex::new(Vec::new())),
            trained_groups: Arc::new(Mutex::new(Vec::new())),
            version: 0,
        }
    }

    fn p(&self, task: &TaskInstance) -> f64 {
        self.level_p[task.level as usize]
    }
}

impl RolloutEngine for MockPolicy {
    fn generate(&mut self, requests: &[GenRequest], _temperature: f32) -> anyhow::Result<GenResult> {
        let rows_used: usize = requests.iter().map(|r| r.n_samples).sum();
        assert!(rows_used <= self.capacity, "capacity violated by coordinator");
        self.call_log.lock().unwrap().push((rows_used, requests.len()));
        let groups = requests
            .iter()
            .map(|req| {
                let p = self.p(&req.task);
                (0..req.n_samples)
                    .map(|_| Rollout {
                        gen_tokens: vec![2],
                        gen_logprobs: vec![-0.3],
                        reward: if self.rng.bool(p) { 1.0 } else { 0.0 },
                    })
                    .collect()
            })
            .collect();
        Ok(GenResult { groups, cost_s: 1.0, rows_used, weight_version: self.version })
    }

    fn evaluate(&mut self, _tasks: &[TaskInstance]) -> anyhow::Result<EvalResult> {
        Ok(EvalResult { accuracy: self.eval_accuracy, cost_s: 0.1 })
    }

    fn rollout_capacity(&self) -> usize {
        self.capacity
    }

    fn gen_len(&self) -> usize {
        8
    }

    fn install(&mut self, snap: &WeightSnapshot) {
        // The scripted pass-rate landscape is stationary; only the served
        // version advances.
        self.version = snap.version;
    }

    fn serving_version(&self) -> u64 {
        self.version
    }

    fn name(&self) -> &str {
        "mock"
    }
}

impl Trainable for MockPolicy {
    fn train(&mut self, groups: &[PromptGroup], _algo: &AlgoConfig) -> anyhow::Result<TrainResult> {
        self.trained_groups
            .lock()
            .unwrap()
            .push(groups.iter().map(|g| (g.prompt_idx, g.rollouts.len())).collect());
        self.version += 1;
        Ok(TrainResult { loss: 0.0, grad_norm: 1.0, clip_frac: 0.0, cost_s: 0.5 })
    }

    fn train_capacity(&self) -> usize {
        self.capacity * 4
    }

    fn weight_version(&self) -> u64 {
        self.version
    }

    fn snapshot(&self) -> WeightSnapshot {
        WeightSnapshot { version: self.version, values: Vec::new() }
    }
}

impl ForkEngine for MockPolicy {
    fn fork_engine(&self, stream: u64) -> Box<dyn RolloutEngine + Send> {
        // Stream 0 reproduces the serial engine's RNG stream exactly (the
        // serial-equivalence rail); the logs are shared with the learner.
        let mut engine = MockPolicy::new(
            self.seed.wrapping_add(stream.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            self.level_p,
        );
        engine.capacity = self.capacity;
        engine.version = self.version;
        engine.call_log = Arc::clone(&self.call_log);
        engine.trained_groups = Arc::clone(&self.trained_groups);
        Box::new(engine)
    }
}

fn dataset() -> Dataset {
    Dataset::training(DatasetKind::SynthDapo17k, 600, 5, 20)
}

/// Larger dataset for pipeline tests so multi-worker prefetch never wraps
/// an epoch (which would legitimately repeat prompt indices).
fn big_dataset() -> Dataset {
    Dataset::training(DatasetKind::SynthDapo17k, 4000, 5, 20)
}

/// level_p where levels 1-3 are trivial (p=1), 4-6 moderate, 7-10 hopeless.
fn trimodal() -> [f64; 11] {
    [0.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.5, 0.0, 0.0, 0.0, 0.0]
}

fn run_kind(kind: CurriculumKind, steps: usize, seed: u64) -> (MockPolicy, RunRecord) {
    let mut policy = MockPolicy::new(seed, trimodal());
    let rule = ScreeningRule::new(4, 8);
    let mut cur = curriculum::make(kind, rule, 2);
    let trainer = Trainer::new(
        TrainerConfig {
            batch_size: 4,
            eval_every: 0,
            max_steps: steps,
            label: kind.name().to_string(),
            seed,
            ..Default::default()
        },
        AlgoConfig::new(BaseAlgo::Rloo),
    );
    let data = dataset();
    let evals: Vec<EvalSet> = vec![];
    let record = trainer.run(&mut policy, cur.as_mut(), &data, &evals).expect("run");
    (policy, record)
}

// ---------------------------------------------------------------------------
// Pipelined coordinator helpers
// ---------------------------------------------------------------------------

fn speed_spec() -> CurriculumSpec {
    // Worker-internal SPEED buffer stays unbounded: reference semantics.
    CurriculumSpec::fixed(CurriculumKind::Speed, ScreeningRule::new(4, 8))
}

fn trainer_cfg(steps: usize, seed: u64, label: &str) -> TrainerConfig {
    TrainerConfig {
        batch_size: 4,
        eval_every: 0,
        max_steps: steps,
        label: label.to_string(),
        seed,
        ..Default::default()
    }
}

fn run_serial_speed(steps: usize, seed: u64) -> (MockPolicy, RunRecord) {
    let mut policy = MockPolicy::new(seed, trimodal());
    let mut cur = speed_spec().build();
    let trainer = Trainer::new(trainer_cfg(steps, seed, "serial"), AlgoConfig::new(BaseAlgo::Rloo));
    let record = trainer.run(&mut policy, cur.as_mut(), &big_dataset(), &[]).expect("serial run");
    (policy, record)
}

fn run_pipelined_speed(
    steps: usize,
    seed: u64,
    workers: usize,
    buffer_cap: usize,
) -> (MockPolicy, RunRecord) {
    let mut policy = MockPolicy::new(seed, trimodal());
    let trainer = PipelinedTrainer::new(
        trainer_cfg(steps, seed, "pipelined"),
        AlgoConfig::new(BaseAlgo::Rloo),
        PipelineConfig { workers, enabled: true, buffer_cap, ..Default::default() },
    );
    let record = trainer.run(&mut policy, speed_spec(), &big_dataset(), &[]).expect("pipelined run");
    (policy, record)
}

// ---------------------------------------------------------------------------
// Serial coordinator invariants (scripted oracle)
// ---------------------------------------------------------------------------

#[test]
fn speed_trains_only_on_moderate_prompts_with_full_n() {
    let (policy, _) = run_kind(CurriculumKind::Speed, 8, 1);
    let data = dataset();
    let trained = policy.trained_groups.lock().unwrap();
    assert_eq!(trained.len(), 8);
    for step_groups in trained.iter() {
        assert_eq!(step_groups.len(), 4, "batch size must be exact");
        for (idx, n) in step_groups {
            assert_eq!(*n, 12, "qualified prompts must carry N_init+N_cont rollouts");
            let level = data.instances[*idx].level;
            // With p=1.0 prompts all screening rollouts pass (rejected) and
            // p=0 prompts all fail (rejected) => only moderate survive.
            assert!((4..=6).contains(&level), "trained on level {level}");
        }
    }
}

#[test]
fn uniform_trains_on_everything_sampled() {
    let (policy, _) = run_kind(CurriculumKind::Uniform, 6, 2);
    let trained = policy.trained_groups.lock().unwrap();
    for step_groups in trained.iter() {
        // DAPO-off baseline keeps uniform-reward groups too, minus the
        // algo-level filter (Rloo keeps everything).
        assert_eq!(step_groups.len(), 4);
        for (_, n) in step_groups {
            assert_eq!(*n, 12);
        }
    }
    // exactly one inference call per step: 4 prompts x 12 rollouts = 48 rows
    let calls = policy.call_log.lock().unwrap();
    assert_eq!(calls.len(), 6);
    assert!(calls.iter().all(|(rows, reqs)| *rows == 48 && *reqs == 4));
}

#[test]
fn dapo_filter_rejects_uniform_groups_and_resamples() {
    let (policy, rec) = run_kind(CurriculumKind::DapoFilter, 6, 3);
    let data = dataset();
    let trained = policy.trained_groups.lock().unwrap();
    for step_groups in trained.iter() {
        for (idx, _) in step_groups {
            let level = data.instances[*idx].level;
            assert!((4..=6).contains(&level), "DAPO trained on uniform group (level {level})");
        }
    }
    // it must have screened more prompts than it kept
    assert!(rec.counters.prompts_screened > rec.counters.prompts_accepted);
    assert!(rec.counters.prompts_accepted >= 6 * 4 - 4); // close to B per step
}

#[test]
fn naive_two_call_issues_more_calls_than_prefetched_speed() {
    let (naive_policy, _) = run_kind(CurriculumKind::SpeedNaive, 8, 4);
    let (speed_policy, _) = run_kind(CurriculumKind::Speed, 8, 4);
    let naive_calls = naive_policy.call_log.lock().unwrap().len();
    let speed_calls = speed_policy.call_log.lock().unwrap().len();
    assert!(
        naive_calls > speed_calls,
        "pre-fetch batching must reduce engine invocations: naive {naive_calls} vs speed {speed_calls}"
    );
}

#[test]
fn speed_calls_stay_within_capacity_and_high_utilization() {
    let (policy, _) = run_kind(CurriculumKind::Speed, 10, 5);
    let calls = policy.call_log.lock().unwrap();
    let total_rows: usize = calls.iter().map(|(r, _)| *r).sum();
    let util = total_rows as f64 / (calls.len() * 96) as f64;
    assert!(util > 0.85, "prefetch batcher utilization {util:.2} too low");
}

#[test]
fn variance_max_trains_on_highest_variance_pool_members() {
    let (policy, _) = run_kind(CurriculumKind::VarianceMax, 4, 6);
    let data = dataset();
    let trained = policy.trained_groups.lock().unwrap();
    for step_groups in trained.iter() {
        for (idx, _) in step_groups {
            let level = data.instances[*idx].level;
            assert!((4..=6).contains(&level), "variance-max picked level {level}");
        }
    }
}

#[test]
fn trainer_time_accounting_sums_phases() {
    let (_, rec) = run_kind(CurriculumKind::Speed, 5, 7);
    let last = rec.steps.last().unwrap();
    assert!((last.time_s - (last.inference_s + last.update_s)).abs() < 1e-9);
    // mock costs: train contributes 0.5 per step
    assert!((last.update_s - 0.5 * 5.0).abs() < 1e-9);
    assert!(last.inference_s > 0.0);
}

#[test]
fn trainer_is_deterministic_given_seed() {
    let (_, a) = run_kind(CurriculumKind::Speed, 6, 9);
    let (_, b) = run_kind(CurriculumKind::Speed, 6, 9);
    let pa: Vec<usize> = a.steps.iter().map(|s| s.prompts_consumed).collect();
    let pb: Vec<usize> = b.steps.iter().map(|s| s.prompts_consumed).collect();
    assert_eq!(pa, pb);
    assert_eq!(a.counters.rollouts, b.counters.rollouts);
}

#[test]
fn property_speed_batches_exact_and_qualified() {
    // Across random pass-rate landscapes, SPEED's trained batches are
    // always exactly B groups of N rollouts whose screening slice was
    // non-uniform.
    check("speed-batch-property", 10, |rng| {
        let mut level_p = [0.0f64; 11];
        for l in 1..=10 {
            level_p[l] = match rng.range_usize(0, 2) {
                0 => 0.0,
                1 => 1.0,
                _ => 0.2 + 0.6 * rng.f64(),
            };
        }
        // ensure at least one moderate level exists
        level_p[5] = 0.5;
        let mut policy = MockPolicy::new(rng.next_u64(), level_p);
        let rule = ScreeningRule::new(4, 8);
        let mut cur = curriculum::make(CurriculumKind::Speed, rule, 2);
        let trainer = Trainer::new(
            TrainerConfig {
                batch_size: 3,
                eval_every: 0,
                max_steps: 4,
                label: "prop".into(),
                seed: rng.next_u64(),
                ..Default::default()
            },
            AlgoConfig::new(BaseAlgo::Rloo),
        );
        let data = dataset();
        trainer.run(&mut policy, cur.as_mut(), &data, &[]).map_err(|e| e.to_string())?;
        let trained = policy.trained_groups.lock().unwrap();
        for step_groups in trained.iter() {
            if step_groups.len() != 3 {
                return Err(format!("batch size {}", step_groups.len()));
            }
            for (_, n) in step_groups {
                if *n != 12 {
                    return Err(format!("rollouts {n}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prompts_consumed_monotone_and_counted() {
    let (_, rec) = run_kind(CurriculumKind::Speed, 6, 11);
    let mut prev = 0;
    for s in &rec.steps {
        assert!(s.prompts_consumed >= prev);
        prev = s.prompts_consumed;
    }
    assert!(prev > 0);
}

#[test]
fn mock_policy_histogram_sanity() {
    // The mock's trimodal landscape yields the expected screening split.
    let mut hist: HashMap<&'static str, usize> = HashMap::new();
    let data = dataset();
    for t in &data.instances {
        let bucket = match t.level {
            1..=3 => "easy",
            4..=6 => "mid",
            _ => "hard",
        };
        *hist.entry(bucket).or_default() += 1;
    }
    assert!(hist["mid"] > 50);
    assert!(hist["easy"] > 20);
    assert!(hist["hard"] > 50);
}

#[test]
fn trainer_stops_at_target() {
    // A policy that always evaluates at 0.9 must trip a 0.8 target at the
    // first evaluation after a step.
    let mut policy = MockPolicy::new(1, trimodal());
    policy.eval_accuracy = 0.9;
    let rule = ScreeningRule::new(4, 8);
    let mut cur = curriculum::make(CurriculumKind::Speed, rule, 2);
    let trainer = Trainer::new(
        TrainerConfig {
            batch_size: 2,
            eval_every: 1,
            max_steps: 50,
            stop_at_target: Some(("bench".to_string(), 0.8)),
            label: "stop".into(),
            ..Default::default()
        },
        AlgoConfig::new(BaseAlgo::Rloo),
    );
    let data = dataset();
    let evals = vec![EvalSet { name: "bench".into(), tasks: data.instances[..4].to_vec() }];
    let rec = trainer.run(&mut policy, cur.as_mut(), &data, &evals).unwrap();
    assert_eq!(rec.steps.len(), 1, "must stop after the first evaluated step");
}

#[test]
fn trainer_respects_time_budget() {
    let mut policy = MockPolicy::new(2, trimodal());
    let rule = ScreeningRule::new(4, 8);
    let mut cur = curriculum::make(CurriculumKind::Uniform, rule, 2);
    let trainer = Trainer::new(
        TrainerConfig {
            batch_size: 2,
            eval_every: 0,
            max_steps: 1000,
            max_seconds: 5.0, // each mock step costs 1.0 (gen) + 0.5 (train)
            label: "budget".into(),
            ..Default::default()
        },
        AlgoConfig::new(BaseAlgo::Rloo),
    );
    let data = dataset();
    let rec = trainer.run(&mut policy, cur.as_mut(), &data, &[]).unwrap();
    assert!(rec.steps.len() < 1000);
    let last = rec.steps.last().unwrap();
    assert!(last.time_s >= 5.0 && last.time_s < 8.0, "time {}", last.time_s);
}

#[test]
fn reinforce_baseline_algorithms_run_through_trainer() {
    for algo in [BaseAlgo::Grpo, BaseAlgo::Reinforce, BaseAlgo::ReinforcePlusPlus] {
        let mut policy = MockPolicy::new(3, trimodal());
        let rule = ScreeningRule::new(4, 8);
        let mut cur = curriculum::make(CurriculumKind::Uniform, rule, 2);
        let trainer = Trainer::new(
            TrainerConfig {
                batch_size: 2,
                eval_every: 0,
                max_steps: 3,
                label: algo.name().into(),
                ..Default::default()
            },
            AlgoConfig::new(algo),
        );
        let data = dataset();
        let rec = trainer.run(&mut policy, cur.as_mut(), &data, &[]).unwrap();
        assert_eq!(rec.steps.len(), 3, "{} failed", algo.name());
    }
}

// ---------------------------------------------------------------------------
// Pipelined coordinator: concurrency invariants
// ---------------------------------------------------------------------------

#[test]
fn pipeline_disabled_reproduces_serial_record_bit_for_bit() {
    let (_, serial) = run_serial_speed(6, 41);
    let mut policy = MockPolicy::new(41, trimodal());
    let trainer = PipelinedTrainer::new(
        trainer_cfg(6, 41, "serial"),
        AlgoConfig::new(BaseAlgo::Rloo),
        PipelineConfig { workers: 1, enabled: false, buffer_cap: 16, ..Default::default() },
    );
    let piped = trainer.run(&mut policy, speed_spec(), &big_dataset(), &[]).unwrap();
    assert_eq!(serial.steps.len(), piped.steps.len());
    for (a, b) in serial.steps.iter().zip(piped.steps.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.inference_s, b.inference_s);
        assert_eq!(a.update_s, b.update_s);
        assert_eq!(a.train_pass_rate, b.train_pass_rate);
        assert_eq!(a.grad_norm, b.grad_norm);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.clip_frac, b.clip_frac);
        assert_eq!(a.prompts_consumed, b.prompts_consumed);
        assert_eq!(a.buffer_len, b.buffer_len);
        assert_eq!(a.mean_staleness, b.mean_staleness);
    }
    assert_eq!(serial.counters.calls, piped.counters.calls);
    assert_eq!(serial.counters.rollouts, piped.counters.rollouts);
    assert_eq!(serial.counters.cost_s, piped.counters.cost_s);
}

#[test]
fn pipeline_one_worker_matches_serial_trained_stream() {
    // With one worker whose engine forks the serial RNG stream (stream 0)
    // and a stationary scripted policy, the pipelined path must train on
    // exactly the serial sequence of batches and issue exactly the serial
    // sequence of inference calls — only timing/staleness bookkeeping may
    // differ (the worker prefetches ahead of the learner).
    let (serial_policy, serial) = run_serial_speed(8, 21);
    let (piped_policy, piped) = run_pipelined_speed(8, 21, 1, 16);

    assert_eq!(
        *serial_policy.trained_groups.lock().unwrap(),
        *piped_policy.trained_groups.lock().unwrap(),
        "trained batch stream diverged"
    );
    assert_eq!(
        *serial_policy.call_log.lock().unwrap(),
        *piped_policy.call_log.lock().unwrap(),
        "inference call stream diverged"
    );
    assert_eq!(serial.steps.len(), piped.steps.len());
    for (a, b) in serial.steps.iter().zip(piped.steps.iter()) {
        assert_eq!(a.train_pass_rate, b.train_pass_rate);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.grad_norm, b.grad_norm);
        assert_eq!(a.clip_frac, b.clip_frac);
    }
    assert_eq!(serial.counters.calls, piped.counters.calls);
    assert_eq!(serial.counters.rows_used, piped.counters.rows_used);
    assert_eq!(serial.counters.rows_capacity, piped.counters.rows_capacity);
    assert_eq!(serial.counters.rollouts, piped.counters.rollouts);
    assert_eq!(serial.counters.prompts_screened, piped.counters.prompts_screened);
    assert_eq!(serial.counters.prompts_accepted, piped.counters.prompts_accepted);
    assert!((serial.counters.cost_s - piped.counters.cost_s).abs() < 1e-9);
    // total time (virtual accounting) agrees: same inference + update costs
    assert!((serial.total_time() - piped.total_time()).abs() < 1e-9);
}

#[test]
fn pipeline_four_workers_conserve_groups_and_bound_staleness() {
    let steps = 12;
    let b = 4;
    let cap = 8; // two batches of headroom -> tight staleness bound
    let (policy, rec) = run_pipelined_speed(steps, 31, 4, cap);
    let data = big_dataset();

    // (1) exact consumption: every step trained on exactly B full-N groups
    let trained = policy.trained_groups.lock().unwrap();
    assert_eq!(trained.len(), steps);
    let mut seen = HashSet::new();
    for step_groups in trained.iter() {
        assert_eq!(step_groups.len(), b, "batch size must be exact");
        for (idx, n) in step_groups {
            assert_eq!(*n, 12, "qualified prompts must carry N_init+N_cont rollouts");
            let level = data.instances[*idx].level;
            assert!((4..=6).contains(&level), "trained on level {level}");
            // (2) no duplicated groups: the shared loader hands each prompt
            // out once (dataset is large enough that no epoch wraps)
            assert!(seen.insert(*idx), "prompt {idx} trained twice");
        }
    }
    assert_eq!(seen.len(), steps * b, "groups lost or duplicated");

    // (3) conservation against the screening accounting: everything trained
    // was accepted; surplus acceptances stay buffered, never invented
    assert!(rec.counters.prompts_accepted as usize >= steps * b);

    // (4) bounded staleness: backpressure caps the buffer at `cap` groups,
    // so groups wait at most ~cap/B learner steps (+ in-flight production)
    assert!(rec.mean_staleness() <= cap as f64, "staleness {}", rec.mean_staleness());
    for s in &rec.steps {
        assert!(s.buffer_len <= cap, "buffer overflowed its bound: {}", s.buffer_len);
    }

    // (5) per-worker counters merged: four workers' calls all accounted
    assert!(rec.counters.calls >= steps as u64, "missing per-worker call accounting");
    assert!(rec.counters.busy_s > 0.0, "engine busy-time not recorded");
}
